#!/usr/bin/env python
"""Failure drill: leader pod dies mid-commit; a new leader is elected,
the committed checkpoint record survives, observers keep serving reads.

    PYTHONPATH=src python examples/failover_drill.py
"""
from repro.configs.bwraft_kv import CONFIG
from repro.coord.coordinator import ConsensusCoordinator
from repro.coord.elastic import ElasticObserverPool


def main():
    coord = ConsensusCoordinator(CONFIG, seed=1)
    lid = coord.wait_for_leader()
    print(f"leader: node {lid}")
    rec = coord.commit_checkpoint(100, "deadbeefcafe0123")
    print(f"checkpoint step=100 committed (rev {rec.revision})")

    pool = ElasticObserverPool(CONFIG, seed=1)
    pool.set_committed(100)
    pool.add_replicas(3)
    pool.route(24)
    print(f"serving: {pool.serve_tick()} reads via {len(pool.alive)} "
          f"observers")

    print(f"\n!!! killing leader node {lid}")
    coord.kill_pod(lid)
    new_lid = coord.wait_for_leader()
    print(f"new leader elected: node {new_lid}")
    got = coord.last_committed_checkpoint()
    assert got and got[0] == 100, got
    print(f"committed checkpoint survived failover: step={got[0]} "
          f"digest_tag={got[1]:03x}")

    pool.revoke_random(0.5)
    pool.route(24)
    print(f"after 50% observer revocation: {pool.serve_tick()} reads "
          f"served by {len(pool.alive)} survivors "
          f"(+{pool.rerouted} rerouted)")
    print("OK")


if __name__ == "__main__":
    main()
