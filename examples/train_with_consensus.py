#!/usr/bin/env python
"""End-to-end training driver: a reduced llama config trained for a few
hundred steps with BW-Raft-committed checkpoints, a simulated pod failure
(elastic data parallelism), and restart-from-committed.

    PYTHONPATH=src python examples/train_with_consensus.py
"""
import shutil

from repro.launch.train import main as train_main

CKPT = "/tmp/repro_example_ckpt"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=== phase 1: train 200 steps, kill pod 1 at step 60 ===")
    train_main(["--arch", "llama3.2-1b", "--steps", "200",
                "--ckpt-every", "50", "--ckpt-dir", CKPT,
                "--kill-at", "60", "--batch", "8", "--seq", "64"])
    print("\n=== phase 2: restart from the consensus-committed checkpoint "
          "and continue to 260 ===")
    train_main(["--arch", "llama3.2-1b", "--steps", "260",
                "--ckpt-every", "50", "--ckpt-dir", CKPT,
                "--resume", "--batch", "8", "--seq", "64"])
    print("\nOK — restart path restored the digest-checked committed step")


if __name__ == "__main__":
    raise SystemExit(main())
