#!/usr/bin/env python
"""The paper's headline experiment: scale-out throughput + cost on spot
markets — BW-Raft vs original Raft vs Multi-Raft (Figs. 7/8).

    PYTHONPATH=src python examples/spot_market_scaleout.py [--epochs 6]

``--trace <name>`` replays a committed sample market trace instead of the
synthetic walk (DESIGN.md §10): the BW-Raft member leases its
secretaries/observers against real per-site price moves and preemption
events, while the on-demand baselines are market-blind — the paper's
Fig. 8 story on a real market.

    PYTHONPATH=src python examples/spot_market_scaleout.py --trace aws-us-east

``--warning-ticks W`` grants BW-Raft's spot nodes an EC2-style advance
warning — a revocation signal W ticks before the kill lands, degraded
through in-graph (DESIGN.md §12) — and ``--bid-policy hazard`` switches
the member from the static init-time bid to per-epoch `HazardAwareBid`
updates (bid up on calm sites, shed on hot ones; pair with ``--trace``
so the hazard is a real market's).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import scaled_cluster, run_systems
from repro.market import HazardAwareBid, available_traces, load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--trace", default=None, choices=available_traces(),
                    help="replay a committed sample market trace instead "
                         "of the synthetic walk (DESIGN.md §10)")
    ap.add_argument("--warning-ticks", type=int, default=0,
                    help="advance-warning window W in ticks "
                         "(DESIGN.md §12); 0 = unwarned kills")
    ap.add_argument("--bid-policy", default="static",
                    choices=("static", "hazard"),
                    help="spot bidding: 'static' keeps the init-time "
                         "1.5x-mean bid, 'hazard' recalibrates per epoch "
                         "from the revocation hazard (DESIGN.md §12)")
    args = ap.parse_args()
    if args.trace is not None:
        print(f"market: replaying trace '{args.trace}'")
    if args.warning_ticks:
        print(f"revocation warning: {args.warning_ticks} ticks")
    if args.bid_policy == "hazard":
        print("bidding: per-epoch hazard-aware recalibration")
    print(f"{'F':>4} {'system':>10} {'goodput':>9} {'w_lat p95':>10} "
          f"{'cost/epoch':>11} {'cost/kop':>9}")
    for f_per_site in (2, 8):
        cfg = scaled_cluster(f_per_site)
        trace = None
        if args.trace is not None:
            trace = load(args.trace,
                         ticks=args.epochs * cfg.period_ticks)
        policy = None
        if args.bid_policy == "hazard":
            mean = (trace.fit_to(cfg.num_sites, trace.ticks).price.mean(1)
                    if trace is not None else
                    [s.spot_price_mean for s in cfg.sites])
            policy = HazardAwareBid(mean_price=mean,
                                    window_ticks=cfg.period_ticks)
        bw, og, mr = run_systems(cfg, write_rate=4.0 * f_per_site,
                                 read_rate=12.0 * f_per_site,
                                 epochs=args.epochs,
                                 shards=max(f_per_site // 2, 2),
                                 market="process" if trace is None
                                 else "trace",
                                 trace=trace,
                                 warning_ticks=args.warning_ticks,
                                 bid_policy=policy,
                                 bid_on_trace=trace is not None
                                 and args.bid_policy == "hazard")
        for name, r in (("bwraft", bw), ("original", og),
                        ("multiraft", mr)):
            print(f"{4*f_per_site:>4} {name:>10} {r.goodput:>9.0f} "
                  f"{r.write_lat_p95 * 10:>8.0f}ms "
                  f"${r.cost:>10.4f} ${1000 * r.cost / max(r.goodput, 1):>8.5f}")
    print("\nBW-Raft keeps goodput at scale on ~84% cheaper spot capacity;"
          "\nMulti-Raft matches throughput only by doubling on-demand nodes.")


if __name__ == "__main__":
    main()
