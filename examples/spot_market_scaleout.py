#!/usr/bin/env python
"""The paper's headline experiment: scale-out throughput + cost on spot
markets — BW-Raft vs original Raft vs Multi-Raft (Figs. 7/8).

    PYTHONPATH=src python examples/spot_market_scaleout.py [--epochs 6]

``--trace <name>`` replays a committed sample market trace instead of the
synthetic walk (DESIGN.md §10): the BW-Raft member leases its
secretaries/observers against real per-site price moves and preemption
events, while the on-demand baselines are market-blind — the paper's
Fig. 8 story on a real market.

    PYTHONPATH=src python examples/spot_market_scaleout.py --trace aws-us-east
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import scaled_cluster, run_systems
from repro.market import available_traces, load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--trace", default=None, choices=available_traces(),
                    help="replay a committed sample market trace instead "
                         "of the synthetic walk (DESIGN.md §10)")
    args = ap.parse_args()
    if args.trace is not None:
        print(f"market: replaying trace '{args.trace}'")
    print(f"{'F':>4} {'system':>10} {'goodput':>9} {'w_lat p95':>10} "
          f"{'cost/epoch':>11} {'cost/kop':>9}")
    for f_per_site in (2, 8):
        cfg = scaled_cluster(f_per_site)
        trace = None
        if args.trace is not None:
            trace = load(args.trace,
                         ticks=args.epochs * cfg.period_ticks)
        bw, og, mr = run_systems(cfg, write_rate=4.0 * f_per_site,
                                 read_rate=12.0 * f_per_site,
                                 epochs=args.epochs,
                                 shards=max(f_per_site // 2, 2),
                                 market="process" if trace is None
                                 else "trace",
                                 trace=trace)
        for name, r in (("bwraft", bw), ("original", og),
                        ("multiraft", mr)):
            print(f"{4*f_per_site:>4} {name:>10} {r.goodput:>9.0f} "
                  f"{r.write_lat_p95 * 10:>8.0f}ms "
                  f"${r.cost:>10.4f} ${1000 * r.cost / max(r.goodput, 1):>8.5f}")
    print("\nBW-Raft keeps goodput at scale on ~84% cheaper spot capacity;"
          "\nMulti-Raft matches throughput only by doubling on-demand nodes.")


if __name__ == "__main__":
    main()
