#!/usr/bin/env python
"""End-to-end serving driver: a small LM served with batched requests
through the elastic observer pool (replicas on revocable spot capacity,
scaled online by the paper's Algorithm 1).

    PYTHONPATH=src python examples/elastic_serving.py
"""
from repro.launch.serve import main as serve_main


def main():
    return serve_main(["--arch", "smollm-360m", "--requests", "48",
                       "--batch", "8", "--prompt-len", "32",
                       "--gen-len", "8", "--revoke-p", "0.15"])


if __name__ == "__main__":
    raise SystemExit(main())
