#!/usr/bin/env python
"""Traced failover drill (DESIGN.md §14): kill the leader with the
flight recorder armed, then read the story back three ways — the ASCII
timeline, the exact event ledger, and a Perfetto artifact you can drop
into https://ui.perfetto.dev.

The recorder runs INSIDE the compiled scan: events land in
device-resident ring buffers and cross to the host once per drain,
so arming it costs neither recompiles nor per-tick transfers.

    PYTHONPATH=src python examples/trace_failover.py [OUT.json]
"""
import sys
from collections import Counter

from repro.configs.bwraft_kv import CONFIG
from repro.market import kill_nodes, run_chaos
from repro.trace import EVENT_NAMES, timeline

TICKS = 160
KILL_TICK = 20


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "trace_failover.json"
    faults = kill_nodes([0], KILL_TICK, n_nodes=CONFIG.max_nodes,
                        ticks=TICKS, name="leader-kill-traced")
    rep = run_chaos(CONFIG, faults, ticks=TICKS, seed=0, spot_bid=10.0,
                    check=False, trace_on=True, trace_capacity=4096,
                    trace_out=out)

    print(f"drill: {TICKS} ticks, node 0 killed at tick {KILL_TICK}")
    print(f"killed={rep.killed_total} "
          f"max_leaderless_span={rep.max_leaderless_span} "
          f"leader_uptime={rep.leader_uptime:.3f}")
    print(f"events decoded: {len(rep.events)} "
          f"(dropped: {rep.events_dropped})")
    by_code = Counter(e.code for e in rep.events)
    for code, n in sorted(by_code.items()):
        print(f"  {EVENT_NAMES[code]:<14} x{n}")

    # the trace must tell the same story the harness probed per tick
    assert rep.trace_leader_match, \
        "trace-replayed leader timeline diverged from the probe"
    print("\ntrace-replayed leader timeline == per-tick probe: OK\n")

    print(timeline.render(rep.events, ticks=TICKS))
    print(f"\nPerfetto artifact -> {out}  (open in ui.perfetto.dev; "
          f"leader tenures are the spans on track 9999)")


if __name__ == "__main__":
    main()
