#!/usr/bin/env python
"""Quickstart: a BW-Raft cluster serving a strongly-consistent KV store.

    PYTHONPATH=src python examples/quickstart.py

Spins up the paper's 4-site geo-distributed cluster in-process, elects a
leader, leases spot secretaries/observers, then does consistent puts/gets
through the BW-KV client API (Listing 1) while spot instances fail.
"""
import numpy as np

from repro.configs.bwraft_kv import CONFIG
from repro.core.runtime import BWRaftSim
from repro.core import state as SM
from repro.kvstore.service import BWKVService


def main():
    print("=== BW-Raft quickstart ===")
    sim = BWRaftSim(CONFIG, write_rate=2.0, read_rate=8.0, seed=0)
    svc = BWKVService(sim)

    svc._step(120)
    lid = int(SM.leader_id(sim.state, sim.static))
    print(f"leader elected: node {lid} "
          f"(site {CONFIG.sites[sim.static['site'][lid]].name})")

    sim._lease(3, 4)
    roles = np.asarray(sim.state["role"])
    print(f"leased {int((roles == SM.SECRETARY).sum())} secretaries, "
          f"{int((roles == SM.OBSERVER).sum())} observers on spot slots")

    r = svc.put("paper/title", 2022)
    print(f"put(paper/title)=2022 committed at revision {r.revision} "
          f"in {r.latency_ticks} ticks ({r.latency_ticks * 10} ms simulated)")
    v, rev = svc.get("paper/title")
    print(f"get(paper/title) -> {v} @ readindex {rev}")

    # kill every spot node — Property 3.4: consensus unaffected
    sim.set_rates(phi=1.0)
    svc._step(5)
    sim.set_rates(phi=0.0)
    r2 = svc.put("paper/venue", 42)
    v2, _ = svc.get("paper/venue")
    print(f"after revoking ALL spot instances: put/get still works -> {v2} "
          f"(BW-Raft degraded to plain Raft, then re-leases)")
    print("OK")


if __name__ == "__main__":
    main()
