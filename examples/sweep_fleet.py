#!/usr/bin/env python
"""A 32-cluster parameter sweep in ONE compiled program.

    PYTHONPATH=src python examples/sweep_fleet.py [--backend pallas]

Sweeps the paper cluster over an 8 x 4 grid of spot kill rates (phi) and
write rates — 32 independent BW-Raft clusters — with `FleetSim`.  All 32
clusters advance together inside a single jitted, vmapped tick-scan: the
sweep grid enters as batched jit *arguments*, so the whole figure-shaped
experiment costs exactly ONE compilation of the epoch function
(DESIGN.md §7).  The script asserts that via `FleetSim.compile_count`.

Epochs run on the device-resident digest pipeline (DESIGN.md §7.1): the
state pytree never leaves the device — per epoch only a few-KB digest per
cluster is fetched (printed below; compare with the device state size).
`benchmarks/perf_fleet.py` quantifies the speedup vs the PR-1
host-marshalling path and records it in BENCH_fleet.json.

`--backend pallas` runs the same sweep through the Pallas kernel layer
(raft_tick + leader fan-out + grouped digest reduction + anti-entropy
sync; DESIGN.md §8; interpret mode off-TPU) — trajectories are
bit-identical, only execution differs; `benchmarks/perf_tick.py` is the
measured comparison.  `--backend auto` (the library default) resolves
per platform: pallas on TPU, xla everywhere else — the resolved choice
is printed and asserted below.
"""
import argparse
import itertools
import time

from repro.configs.bwraft_kv import CONFIG
from repro.core.fleet import FleetSim
from repro.core.runtime import BWRaftSim
from repro.core.state import pytree_nbytes
from repro.kernels import BACKENDS, resolve_backend

PHIS = [0.0, 0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2]
WRITE_RATES = [4.0, 8.0, 16.0, 32.0]
EPOCHS = 3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="auto",
                    help="tick hot-op implementation (DESIGN.md §8); "
                         "'auto' resolves to pallas on TPU, xla elsewhere")
    args = ap.parse_args()
    resolved = resolve_backend(args.backend)
    print(f"=== BW-Raft fleet sweep: 8 phis x 4 write rates = 32 clusters "
          f"(backend={args.backend} -> {resolved}) ===")
    fleet = FleetSim.from_sweep(
        CONFIG, {"phi": PHIS, "write_rate": WRITE_RATES},
        read_rate=32.0, seed=0, backend=args.backend)
    assert fleet.shapes.B == 32, fleet.shapes
    assert fleet.backend == resolved, (fleet.backend, resolved)

    t0 = time.perf_counter()
    reports = fleet.run(EPOCHS)
    batched_s = time.perf_counter() - t0

    assert fleet.compile_count == 1, (
        f"expected exactly one jit compilation of the batched epoch "
        f"function, got {fleet.compile_count}")
    print(f"ran {fleet.shapes.B} clusters x {EPOCHS} epochs "
          f"({fleet.shapes.B * EPOCHS * fleet.shapes.T} cluster-ticks) in "
          f"{batched_s:.1f}s with {fleet.compile_count} compile")
    print(f"device->host per epoch: {fleet.d2h_bytes // EPOCHS} B of "
          f"digests vs {pytree_nbytes(fleet.state)} B of device-resident "
          f"state (never fetched; DESIGN.md §7.1)")

    print(f"\n{'phi':>5} | " + " | ".join(
        f"w={int(w):>2} goodput" for w in WRITE_RATES))
    grid = itertools.product(PHIS, WRITE_RATES)
    by_cell = {cell: reps[-1] for cell, reps in zip(grid, reports)}
    for phi in PHIS:
        cells = [f"{by_cell[(phi, w)].goodput:>12.0f}"
                 for w in WRITE_RATES]
        print(f"{phi:>5.2f} | " + " | ".join(cells))

    # one sequential point for scale: same cluster, same epochs, 1/32 of
    # the work — every additional point would pay this again
    t0 = time.perf_counter()
    BWRaftSim(CONFIG, write_rate=8.0, read_rate=32.0, phi=0.05,
              seed=0).run(EPOCHS)
    solo_s = time.perf_counter() - t0
    print(f"\nsequential single cluster: {solo_s:.1f}s -> 32 points "
          f"~{32 * solo_s:.0f}s sequential vs {batched_s:.1f}s batched "
          f"({32 * solo_s / max(batched_s, 1e-9):.1f}x)")
    print("OK")


if __name__ == "__main__":
    main()
