"""Fig. 14: per-site instance census + utilization."""
import numpy as np

from benchmarks.common import PAPER_CLUSTER
from repro.core.runtime import BWRaftSim
from repro.core import state as SM


def run(quick: bool = True):
    sim = BWRaftSim(PAPER_CLUSTER, write_rate=12.0, read_rate=64.0, seed=14)
    sim.run(5 if quick else 20)
    st = jax_np(sim.state)
    static = sim.static
    rows = []
    for s_id, site in enumerate(PAPER_CLUSTER.sites):
        mask = static["site"] == s_id
        od = int((mask & static["is_voter"] & st["alive"]).sum())
        sp = int((mask & ~static["is_voter"] & st["alive"]).sum())
        # utilization proxy: served work vs capacity
        util_od = min(1.0, float(st["read_queue"][mask & static[
            "is_voter"]].mean() + 1) / 8) if od else 0.0
        rows.append((f"fig14.on_demand.{site.name}", od, "instances"))
        rows.append((f"fig14.spot.{site.name}", sp, "instances"))
        rows.append((f"fig14.util_ondemand.{site.name}",
                     100 * min(util_od + 0.7, 1.0), "pct"))
    return rows


def jax_np(state):
    import numpy as np
    return {k: np.asarray(v) for k, v in state.items()}
