"""Fig. 11: YCSB-style mixed workloads + leader resource usage."""
import numpy as np

from benchmarks.common import PAPER_CLUSTER
from repro.core.runtime import BWRaftSim

# YCSB-ish mixes: (name, write_ratio)
MIXES = [("A_update_heavy", 0.5), ("B_read_mostly", 0.05),
         ("C_read_only", 0.0)]


def run(quick: bool = True):
    rows = []
    total = 48.0
    for name, wr in MIXES:
        for mode in ["bwraft", "raft"]:
            sim = BWRaftSim(PAPER_CLUSTER, mode=mode,
                            write_rate=total * wr,
                            read_rate=total * (1 - wr), seed=8)
            r = sim.run(4 if quick else 12)[-1]
            rows.append((f"fig11.throughput.{name}.{mode}", r.goodput,
                         "ops_per_epoch"))
        # leader work proxy: committed writes x fan-out paths
        import numpy as np
        st = sim.state
        rows.append((f"fig11.leader_work.{name}",
                     float(np.asarray(st["leader_work"]).max()), "msg_units"))
    return rows
