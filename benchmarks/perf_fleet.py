#!/usr/bin/env python
"""Fleet epoch-pipeline benchmark: digest path vs host-marshalling path.

Measures the three epoch-loop implementations on one sweep grid
(DESIGN.md §7.1):

  host        PR-1 reference, op for op: the original tick formulations
              (`step.tick(reference=True)` — scatter window adopt,
              O(L·N) commit count, A sequential apply scatters), full
              state pytree + T-stacked per-tick metrics pulled to host
              every epoch, compaction as a second dispatch, no buffer
              donation.
  device      digest pipeline: in-scan metric reduction, in-graph
              compaction, donated state — a few-KB digest per member is
              the only device→host traffic.
  device-scan the multi-epoch fast path: the whole run is ONE dispatch
              (eligible here because the grid is fixed-role/unmanaged).

Emits ``BENCH_fleet.json`` with ticks/sec, per-epoch wall time, per-epoch
device→host transfer bytes, and compile counts, and **fails** (exit 1)
when the digest pipeline regresses above fixed ceilings — per-member
per-epoch transfer bytes or total compiled programs — so CI catches
pipeline regressions (`.github/workflows/ci.yml` runs ``--smoke``).

It also measures the **sharded Multi-Raft baseline** (DESIGN.md §9): a
B-system x S-shard grid run as ONE grouped fleet — in-graph 2PC
coupling, in-graph group-digest reduction, ONE compiled dispatch per
epoch (asserted via `CountingJit`) — against the frozen sequential
`MultiRaftSim` reference, which pays B*S dispatches per epoch plus a
host round trip per shard.  The `multiraft` block in the JSON records
the dispatch-count and D2H win.

  PYTHONPATH=src python benchmarks/perf_fleet.py [--smoke] [--out PATH]

The full run (default) is the acceptance configuration: a 32-member
fleet, 5 epochs, manage off — it also asserts the ≥3X epoch-loop
speedup of the single-dispatch path over the host path — plus the
shards=4 x B=8 grouped Multi-Raft sweep.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.configs.bwraft_kv import CONFIG
from repro.core import fleet as fleet_mod
from repro.core import multiraft
from repro.core.fleet import FleetSim
from repro.core.state import pytree_nbytes

# hard ceilings enforced on the digest pipeline (CI regression gates):
# per-member per-epoch device->host bytes must stay O(digest) — the
# digest is ~(T + HIST_TAIL + 2N + S + a dozen scalars) * 4 bytes
# ≈ 1.5 KB for the paper cluster (plus the per-group rows of a grouped
# fleet) — and the process must not accumulate compiled programs beyond
# one per (pipeline, static shape, group count).
D2H_CEILING_BYTES_PER_MEMBER_EPOCH = 4096
# host + device + device-scan for the sweep grid, grouped device +
# grouped device-scan for the Multi-Raft baseline (+2 slack)
COMPILE_CEILING = 7

PHIS = [0.0, 0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2]
WRITE_RATES = [4.0, 8.0, 16.0, 32.0]
PRELEASE = (2, 6)


def build_fleet(b: int, pipeline: str) -> FleetSim:
    phis = PHIS[:max(b // len(WRITE_RATES), 1)]
    fleet = FleetSim.from_sweep(
        CONFIG, {"phi": phis, "write_rate": WRITE_RATES},
        pipeline=pipeline, read_rate=32.0, seed=0,
        manage_resources=False, prelease=PRELEASE)
    assert fleet.shapes.B == b, fleet.shapes
    return fleet


def measure(b: int, epochs: int, pipeline: str, *,
            single_dispatch: bool) -> dict:
    """Wall time + transfer bytes for a warm (pre-compiled) run: one
    throwaway fleet pays the compile, a fresh fleet at the same static
    shape reuses the cached program (DESIGN.md §7)."""
    build_fleet(b, pipeline).run(epochs, single_dispatch=single_dispatch)
    fleet = build_fleet(b, pipeline)
    t0 = time.perf_counter()
    fleet.run(epochs, single_dispatch=single_dispatch)
    wall_s = time.perf_counter() - t0
    ticks = b * epochs * fleet.shapes.T
    return {
        "pipeline": pipeline + ("-scan" if single_dispatch else ""),
        "wall_s": wall_s,
        "epoch_wall_s": wall_s / epochs,
        "ticks_per_sec": ticks / wall_s,
        "d2h_bytes_per_epoch": fleet.d2h_bytes / epochs,
        "d2h_bytes_per_member_epoch": fleet.d2h_bytes / epochs / b,
    }


def build_multiraft_fleet(systems: int, shards: int) -> FleetSim:
    """`systems` Multi-Raft instances x `shards` shards each, every shard
    a grouped member of ONE fleet (distinct group_id per system)."""
    specs = []
    for g in range(systems):
        specs += multiraft.shard_specs(
            CONFIG, shards=shards, write_rate=8.0 + 2.0 * g,
            read_rate=32.0, cross_shard_frac=0.1, seed=g, group_id=g)
    return FleetSim(specs)


def measure_multiraft(systems: int, shards: int, epochs: int) -> dict:
    """The sharded-baseline win (DESIGN.md §9): one grouped dispatch per
    epoch for all `systems * shards` shard Rafts + in-graph 2PC + group
    digests, vs the sequential reference's one dispatch per shard per
    epoch (B*S total) with a host round trip each."""
    build_multiraft_fleet(systems, shards).run(              # warm compile
        1, single_dispatch=False)
    fleet = build_multiraft_fleet(systems, shards)
    t0 = time.perf_counter()
    fleet.run(epochs, single_dispatch=False)               # 1 dispatch/epoch
    grouped_wall = time.perf_counter() - t0
    assert fleet.compile_count == 1, \
        f"grouped Multi-Raft sweep must be ONE compiled program, " \
        f"got {fleet.compile_count}"

    def build_seq():
        return [multiraft.MultiRaftSim(
                    CONFIG, shards=shards, write_rate=8.0 + 2.0 * g,
                    read_rate=32.0, cross_shard_frac=0.1, seed=g,
                    engine="sequential")
                for g in range(systems)]
    for sim in build_seq():                                # warm compile
        sim.run_epoch()
    sims = build_seq()
    t0 = time.perf_counter()
    for _ in range(epochs):
        for sim in sims:
            sim.run_epoch()
    seq_wall = time.perf_counter() - t0

    return {
        "systems": systems, "shards": shards,
        "members": systems * shards, "epochs": epochs,
        "grouped_wall_s": grouped_wall,
        "sequential_wall_s": seq_wall,
        "speedup_grouped_vs_sequential": seq_wall / grouped_wall,
        "dispatches_per_epoch_grouped": 1,
        "dispatches_per_epoch_sequential": systems * shards,
        "d2h_bytes_per_epoch": fleet.d2h_bytes / epochs,
        "d2h_bytes_per_member_epoch":
            fleet.d2h_bytes / epochs / (systems * shards),
        "compile_count": fleet.compile_count,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (ceiling checks only, no "
                         "speedup assertion)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)

    b, epochs = (8, 3) if args.smoke else (32, 5)
    print(f"=== fleet epoch pipeline: B={b}, {epochs} epochs, "
          f"manage off ===")

    runs = [measure(b, epochs, "host", single_dispatch=False),
            measure(b, epochs, "device", single_dispatch=False),
            measure(b, epochs, "device", single_dispatch=True)]
    host, device, scan = runs
    for r in runs:
        print(f"{r['pipeline']:>12}: {r['epoch_wall_s']*1e3:8.1f} ms/epoch"
              f"  {r['ticks_per_sec']:>10.0f} ticks/s"
              f"  {r['d2h_bytes_per_epoch']:>12.0f} B/epoch D2H")

    mr_systems, mr_shards = (4, 2) if args.smoke else (8, 4)
    mr = measure_multiraft(mr_systems, mr_shards, epochs)
    print(f"multiraft B={mr_systems} x S={mr_shards}: grouped "
          f"{mr['grouped_wall_s']*1e3/epochs:.1f} ms/epoch (1 dispatch) vs "
          f"sequential {mr['sequential_wall_s']*1e3/epochs:.1f} ms/epoch "
          f"({mr['dispatches_per_epoch_sequential']} dispatches): "
          f"{mr['speedup_grouped_vs_sequential']:.1f}X")

    state_bytes = pytree_nbytes(build_fleet(b, "device").state)
    result = {
        "config": {"B": b, "epochs": epochs, "T": CONFIG.period_ticks,
                   "cluster": CONFIG.name, "smoke": args.smoke},
        "runs": runs,
        "speedup_device_vs_host":
            host["epoch_wall_s"] / device["epoch_wall_s"],
        "speedup_scan_vs_host":
            host["epoch_wall_s"] / scan["epoch_wall_s"],
        "d2h_reduction_vs_host":
            host["d2h_bytes_per_epoch"] / scan["d2h_bytes_per_epoch"],
        "device_state_bytes": state_bytes,
        "multiraft": mr,
        "compile_count_total": fleet_mod.total_compile_count(),
        "ceilings": {
            "d2h_bytes_per_member_epoch":
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH,
            "compile_count_total": COMPILE_CEILING,
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"speedup vs host: device {result['speedup_device_vs_host']:.1f}X"
          f", single-dispatch {result['speedup_scan_vs_host']:.1f}X; "
          f"D2H reduced {result['d2h_reduction_vs_host']:.0f}X; "
          f"{result['compile_count_total']} compiles -> {args.out}")

    failures = []
    for r in runs[1:]:
        if (r["d2h_bytes_per_member_epoch"] >
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH):
            failures.append(
                f"{r['pipeline']}: {r['d2h_bytes_per_member_epoch']:.0f} "
                f"D2H bytes/member/epoch exceeds ceiling "
                f"{D2H_CEILING_BYTES_PER_MEMBER_EPOCH}")
    if mr["d2h_bytes_per_member_epoch"] > D2H_CEILING_BYTES_PER_MEMBER_EPOCH:
        failures.append(
            f"multiraft grouped: {mr['d2h_bytes_per_member_epoch']:.0f} "
            f"D2H bytes/member/epoch exceeds ceiling "
            f"{D2H_CEILING_BYTES_PER_MEMBER_EPOCH}")
    if result["compile_count_total"] > COMPILE_CEILING:
        failures.append(f"{result['compile_count_total']} compiled programs "
                        f"exceeds ceiling {COMPILE_CEILING}")
    if not args.smoke and result["speedup_scan_vs_host"] < 3.0:
        failures.append(f"single-dispatch speedup "
                        f"{result['speedup_scan_vs_host']:.2f}X < 3X")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
