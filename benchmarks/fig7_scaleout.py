"""Fig. 7: performance + cost as the workload scales out.

The whole grid — every follower count x {bwraft, original, multiraft
shards, bwraft + a 50X digest-observer rack} — runs as ONE FleetSim:
the smaller clusters are padded to the largest topology's static shape,
so the entire figure costs a single jit compile (DESIGN.md §7) instead
of one per (load, system) point.  Each point's Multi-Raft shards form
one device-coupled group (distinct `group_id` per point, ragged shard
counts included — DESIGN.md §9), so the baseline's 2PC tail latencies
are measured in the same dispatch.  The `bwraft_obs` member carries
`n_observers = 50 x voters` digest-tier slots (DESIGN.md §13) — the
paper's 50X node claim rendered as a figure row, in the same program.
"""
from benchmarks import common
from benchmarks.common import (collect_systems, run_systems,
                               scaled_cluster, system_specs)
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim


def _voters(cfg) -> int:
    return sum(1 + s.followers for s in cfg.sites)


def _obs_spec(cfg, w: float, seed: int = 0) -> MemberSpec:
    return MemberSpec(cfg=cfg, mode="bwraft", write_rate=w,
                      read_rate=w * 3, seed=seed,
                      n_observers=50 * _voters(cfg),
                      staleness_bound=12, ae_interval=4)


def run(quick: bool = True):
    rows = []
    loads = [(2, 8.0), (4, 24.0)] if quick else \
        [(2, 8.0), (4, 24.0), (8, 48.0), (12, 96.0)]
    epochs = 4 if quick else 10
    points = [(f, w, scaled_cluster(f), max(f // 2, 2)) for f, w in loads]

    if common.USE_FLEET:
        specs, spans = [], []
        for gid, (f, w, cfg, shards) in enumerate(points):
            spans.append((len(specs), gid))
            specs += system_specs(cfg, write_rate=w, read_rate=w * 3,
                                  shards=shards, group_id=gid)
        obs_lo = len(specs)
        specs += [_obs_spec(cfg, w) for f, w, cfg, shards in points]
        fleet = FleetSim(specs)
        fleet.run(epochs)
        results = [collect_systems(fleet, lo, group_id=gid)
                   for lo, gid in spans]
        obs_results = [fleet.members[obs_lo + i].reports[-1]
                       for i in range(len(points))]
    else:
        results = [run_systems(cfg, write_rate=w, read_rate=w * 3,
                               epochs=epochs, shards=shards)
                   for f, w, cfg, shards in points]
        obs_results = []
        for f, w, cfg, shards in points:
            spec = _obs_spec(cfg, w)
            obs_results.append(BWRaftSim(
                cfg, mode="bwraft", write_rate=w, read_rate=w * 3,
                n_observers=spec.n_observers,
                staleness_bound=spec.staleness_bound,
                ae_interval=spec.ae_interval).run(epochs)[-1])

    for (f_per_site, w, cfg, shards), (bw, og, mr), ob in zip(
            points, results, obs_results):
        scale = 4 * f_per_site
        for name, r in [("bwraft", bw), ("original", og),
                        ("multiraft", mr), ("bwraft_obs", ob)]:
            rows.append((f"fig7.goodput.F{scale}.{name}", r.goodput,
                         "ops_per_epoch"))
            rows.append((f"fig7.cost.F{scale}.{name}", r.cost * 1e6,
                         "usd_per_epoch_x1e6"))
        rows.append((f"fig7.obs_reads.F{scale}", ob.obs_reads_served,
                     "reads_per_epoch"))
        rows.append((f"fig7.obs_stale_p99.F{scale}", ob.obs_stale_p99,
                     "ticks"))
        rows.append((f"fig7.n_obs.F{scale}", 50 * _voters(cfg),
                     "digest_observers"))
    return rows
