"""Fig. 7: performance + cost as the workload scales out.

The whole grid — every follower count x {bwraft, original, multiraft
shards} — runs as ONE FleetSim: the smaller clusters are padded to the
largest topology's static shape, so the entire figure costs a single jit
compile (DESIGN.md §7) instead of one per (load, system) point.  Each
point's Multi-Raft shards form one device-coupled group (distinct
`group_id` per point, ragged shard counts included — DESIGN.md §9), so
the baseline's 2PC tail latencies are measured in the same dispatch.
"""
from benchmarks import common
from benchmarks.common import (collect_systems, run_systems,
                               scaled_cluster, system_specs)
from repro.core.fleet import FleetSim


def run(quick: bool = True):
    rows = []
    loads = [(2, 8.0), (4, 24.0)] if quick else \
        [(2, 8.0), (4, 24.0), (8, 48.0), (12, 96.0)]
    epochs = 4 if quick else 10
    points = [(f, w, scaled_cluster(f), max(f // 2, 2)) for f, w in loads]

    if common.USE_FLEET:
        specs, spans = [], []
        for gid, (f, w, cfg, shards) in enumerate(points):
            spans.append((len(specs), gid))
            specs += system_specs(cfg, write_rate=w, read_rate=w * 3,
                                  shards=shards, group_id=gid)
        fleet = FleetSim(specs)
        fleet.run(epochs)
        results = [collect_systems(fleet, lo, group_id=gid)
                   for lo, gid in spans]
    else:
        results = [run_systems(cfg, write_rate=w, read_rate=w * 3,
                               epochs=epochs, shards=shards)
                   for f, w, cfg, shards in points]

    for (f_per_site, w, cfg, shards), (bw, og, mr) in zip(points, results):
        scale = 4 * f_per_site
        for name, r in [("bwraft", bw), ("original", og),
                        ("multiraft", mr)]:
            rows.append((f"fig7.goodput.F{scale}.{name}", r.goodput,
                         "ops_per_epoch"))
            rows.append((f"fig7.cost.F{scale}.{name}", r.cost * 1e6,
                         "usd_per_epoch_x1e6"))
    return rows
