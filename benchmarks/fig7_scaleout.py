"""Fig. 7: performance + cost as the workload scales out."""
import numpy as np

from benchmarks.common import Row, run_systems, scaled_cluster


def run(quick: bool = True):
    rows = []
    loads = [(2, 8.0), (4, 24.0)] if quick else \
        [(2, 8.0), (4, 24.0), (8, 48.0), (12, 96.0)]
    for f_per_site, w in loads:
        cfg = scaled_cluster(f_per_site)
        bw, og, mr = run_systems(cfg, write_rate=w, read_rate=w * 3,
                                 epochs=4 if quick else 10,
                                 shards=max(f_per_site // 2, 2))
        scale = 4 * f_per_site
        for name, r in [("bwraft", bw), ("original", og),
                        ("multiraft", mr)]:
            rows.append((f"fig7.goodput.F{scale}.{name}", r.goodput,
                         f"ops_per_epoch"))
            rows.append((f"fig7.cost.F{scale}.{name}", r.cost * 1e6,
                         f"usd_per_epoch_x1e6"))
    return rows
