#!/usr/bin/env python
"""Revocation-robustness benchmark: warning windows, chaos drills,
hazard-aware bidding (DESIGN.md §12).

Measures and GATES the §12 robustness contract:

  golden      W=0 + the static init-time bid must replay the committed
              pre-§12 golden trajectories (`tests/data/
              closed_loop_golden.json`) bit-identically — solo managed
              AND the fixed-role fleet.  The §12 plumbing is strictly
              additive; divergence exits 1.
  chaos       deterministic fault drills (leader kill, warned mass-site
              revocation, warning-then-reprieve) replayed through
              `core/invariants.py`: every paper safety property must
              hold, and recovery ticks are recorded per drill.
  sweep       a traces x W x bid-policy fleet must compile ONE tick
              program (W, schedules and bids are cfg_c data —
              CountingJit-asserted) under the same D2H digest ceiling
              `perf_market.py` enforces.
  retention   goodput retention vs a kill-free replay of the SAME
              price series, swept over the warning window W under the
              committed AWS trace (and the hot synthetic walk): must be
              monotonically non-decreasing in W with a net improvement
              — more warning never hurts, and reprieves/degradation
              must eventually pay.

Emits ``BENCH_faults.json``; CI runs ``--smoke`` and uploads it
(`.github/workflows/ci.yml`).

  PYTHONPATH=src python benchmarks/perf_faults.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.bwraft_kv import CONFIG
from repro.core import fleet as fleet_mod
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim
from repro.market import (HazardAwareBid, MarketTrace, kill_nodes, load,
                          mass_kill, run_chaos, warning_then_reprieve)

GOLDEN = pathlib.Path(__file__).parent.parent / "tests" / "data" / \
    "closed_loop_golden.json"

# same digest ceiling perf_fleet.py / perf_market.py enforce (§7.1)
D2H_CEILING_BYTES_PER_MEMBER_EPOCH = 4096
# the retention sweep's warning grid straddles the committed AWS
# trace's revocation-run lengths (21/22/32 ticks), so the larger
# windows convert sustained signals into reprieves
W_GRID = (0, 10, 25, 40)
RETENTION_READ_RATE = 240.0      # capacity-bound: observers carry reads


def _golden_matches(g, reports, state) -> bool:
    for i, grep in enumerate(g["reports"]):
        for k, v in grep.items():
            got = getattr(reports[i], k)
            ok = (repr(float(got)) == v if isinstance(v, str)
                  else int(got) == v)
            if not ok:
                return False
    for k, leaf in g["state"].items():
        arr = np.asarray(state[k])
        if list(arr.shape) != leaf["shape"] \
                or str(arr.dtype) != leaf["dtype"] \
                or hashlib.sha256(arr.tobytes()).hexdigest() \
                != leaf["sha256"]:
            return False
    return True


def golden_gate() -> dict:
    """The W=0/static-bid gate: both committed golden recipes replayed
    through the §12-bearing code must match bit for bit."""
    golden = json.loads(GOLDEN.read_text())
    solo = BWRaftSim(CONFIG, write_rate=8.0, read_rate=32.0, phi=0.02,
                     seed=0)
    solo_ok = _golden_matches(golden["solo_managed"], solo.run(2),
                              solo.state)
    fleet = FleetSim([
        MemberSpec(cfg=CONFIG, write_rate=6.0, read_rate=24.0, seed=1,
                   manage_resources=False, prelease=(2, 6)),
        MemberSpec(cfg=CONFIG, mode="raft", write_rate=12.0,
                   read_rate=12.0, seed=2, manage_resources=False)])
    fleet.run(3)
    g = golden["fleet_fixed"]
    fleet_ok = all(
        _golden_matches({"reports": gm, "state": {}}, member_reports, {})
        for member_reports, gm in zip(fleet.reports, g["reports"])) \
        and _golden_matches({"reports": [], "state": g["state"]}, [],
                            fleet.state)
    return {"solo_managed": solo_ok, "fleet_fixed": fleet_ok,
            "bit_identical": solo_ok and fleet_ok}


def chaos_block(ticks: int = 120) -> dict:
    """The three canonical drills, market silenced (spot_bid=10.0) so
    the scripted schedule is the only fault source."""
    N = CONFIG.max_nodes
    reprieved = 4
    drills = {
        "leader_kill": (kill_nodes([0], 20, n_nodes=N, ticks=ticks), 0),
        "mass_kill_warned": (mass_kill(30, n_nodes=N, ticks=ticks,
                                       spare=(0, 1, 2), warning_ticks=3),
                             3),
        "warning_then_reprieve": (warning_then_reprieve(
            [reprieved], 20, n_nodes=N, ticks=ticks, warning_ticks=8), 8),
    }
    out = {}
    for name, (faults, w) in drills.items():
        rep = run_chaos(CONFIG, faults, warning_ticks=w, ticks=ticks,
                        seed=0, spot_bid=10.0, check=False)
        out[name] = {
            "warning_ticks": w, "first_kill_tick": rep.first_kill_tick,
            "killed": rep.killed_total,
            "recovery_ticks": rep.recovery_ticks,
            "max_leaderless_span": rep.max_leaderless_span,
            "leader_uptime": rep.leader_uptime,
            "safety_ok": rep.safety_error is None,
        }
        if name == "warning_then_reprieve":
            # the §12 reprieve contract: the signal drops one tick short
            # of landing, so THIS node must survive the whole drill
            # (other kill counts can still come from election secretary
            # drops, a §6 rule, so total `killed` is not the gate)
            out[name]["reprieved_node_survived"] = bool(
                all(snap["alive"][reprieved] for snap in rep.trace))
    return out


def sweep_block(epochs: int) -> dict:
    """traces x W x bid-policy fleet: ONE compiled tick program for the
    whole grid — windows, schedules and per-epoch bids are all cfg_c
    data at fixed shapes."""
    T = epochs * CONFIG.period_ticks
    specs = []
    for tname in ("aws-us-east", "google-evict"):
        trace = load(tname, ticks=T)
        mean = trace.fit_to(CONFIG.num_sites, T).price.mean(axis=1)
        for w in (0, 25):
            for policy in (None, HazardAwareBid(
                    mean_price=mean, window_ticks=CONFIG.period_ticks)):
                specs.append(MemberSpec(
                    cfg=CONFIG, write_rate=8.0, read_rate=32.0,
                    seed=len(specs), market="trace", trace=trace,
                    warning_ticks=w, bid_policy=policy,
                    bid_on_trace=policy is not None))
    before = fleet_mod.total_compile_count()
    FleetSim(specs).run(epochs)                        # warm compile
    compiles = fleet_mod.total_compile_count() - before
    fleet = FleetSim(specs)
    t0 = time.perf_counter()
    fleet.run(epochs)
    wall_s = time.perf_counter() - t0
    return {
        "B": len(specs), "epochs": epochs,
        "axes": {"traces": 2, "W": [0, 25], "bid_policy":
                 ["static", "hazard"]},
        "wall_s": wall_s,
        "ticks_per_sec": len(specs) * epochs * fleet.shapes.T / wall_s,
        "d2h_bytes_per_member_epoch":
            fleet.d2h_bytes / epochs / len(specs),
        "compile_count": compiles,
    }


def _retention_run(trace, warning_ticks, epochs) -> float:
    sim = BWRaftSim(CONFIG, write_rate=12.0,
                    read_rate=RETENTION_READ_RATE, seed=12,
                    manage_resources=False, market="trace", trace=trace,
                    warning_ticks=warning_ticks)
    sim.run(1)
    sim.lease_fixed(4, 8)
    return float(sum(r.goodput for r in sim.run(epochs - 1)))


def retention_block(epochs: int) -> dict:
    """Goodput retention vs W: each W member replays the SAME committed
    trace; the baseline replays the same price series with the
    revocation columns stripped (a kill-free twin).  The fig13 recipe —
    stabilize, wire (4, 8) once, never re-lease — so retention is
    purely 'how much longer did the warned complement survive'."""
    out = {}
    T = epochs * CONFIG.period_ticks
    aws = load("aws-us-east", ticks=T)
    grids = {"aws-us-east": aws}
    # the synthetic hot walk, exported so the same replay path runs it:
    # strictly harder than the committed trace (kills all epochs long)
    from repro.market import export_walk_trace
    grids["hot-walk"] = export_walk_trace(CONFIG, seed=12, epochs=epochs,
                                          spot_price_vol=2.0)
    for name, trace in grids.items():
        nokill = MarketTrace(trace.name, trace.price,
                             np.zeros_like(trace.revoked))
        base = _retention_run(nokill, 0, epochs)
        rows = {}
        for w in W_GRID:
            g = _retention_run(trace, w, epochs)
            rows[str(w)] = {"goodput": g,
                            "retention": g / max(base, 1.0)}
        out[name] = {"baseline_goodput": base, "W": rows}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep grid for CI (gates still apply)")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args(argv)

    # the retention/chaos grids are pinned (they gate committed traces);
    # only the compile-sweep shrinks under --smoke
    sweep_epochs = 2 if args.smoke else 5
    epochs = 5
    print("=== revocation robustness (DESIGN.md §12) ===")

    golden = golden_gate()
    print(f"golden gate (W=0, static bid): "
          f"bit_identical={golden['bit_identical']}")

    chaos = chaos_block()
    for name, row in chaos.items():
        print(f"{name:>22}: first_kill={row['first_kill_tick']:>3} "
              f"killed={row['killed']:>2} "
              f"recovery={row['recovery_ticks']:>3} ticks "
              f"safety_ok={row['safety_ok']}")

    sweep = sweep_block(sweep_epochs)
    print(f"sweep: B={sweep['B']} {sweep['compile_count']} compile(s), "
          f"{sweep['ticks_per_sec']:.0f} ticks/s, "
          f"{sweep['d2h_bytes_per_member_epoch']:.0f} D2H B/member/epoch")

    retention = retention_block(epochs)
    for name, block in retention.items():
        r = [block["W"][str(w)]["retention"] for w in W_GRID]
        print(f"retention[{name}]: " + "  ".join(
            f"W={w}:{v:.4f}" for w, v in zip(W_GRID, r)))

    result = {
        "config": {"cluster": CONFIG.name, "epochs": epochs,
                   "sweep_epochs": sweep_epochs, "W_grid": list(W_GRID),
                   "retention_read_rate": RETENTION_READ_RATE,
                   "smoke": args.smoke},
        "golden": golden,
        "chaos": chaos,
        "sweep": sweep,
        "retention": retention,
        "ceilings": {
            "d2h_bytes_per_member_epoch":
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH,
            "compile_count_per_sweep": 1,
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    failures = []
    if not golden["bit_identical"]:
        failures.append("W=0/static-bid replay diverged from the golden "
                        "trajectories (§12 golden gate)")
    for name, row in chaos.items():
        if not row["safety_ok"]:
            failures.append(f"chaos drill {name} violated a safety "
                            f"property")
    if not chaos["warning_then_reprieve"]["reprieved_node_survived"]:
        failures.append("reprieve drill killed the reprieved node "
                        "(hold <= W must never land)")
    if sweep["compile_count"] != 1:
        failures.append(f"fault sweep compiled {sweep['compile_count']} "
                        f"programs (must be exactly 1)")
    if (sweep["d2h_bytes_per_member_epoch"] >
            D2H_CEILING_BYTES_PER_MEMBER_EPOCH):
        failures.append(
            f"sweep: {sweep['d2h_bytes_per_member_epoch']:.0f} D2H "
            f"bytes/member/epoch exceeds ceiling "
            f"{D2H_CEILING_BYTES_PER_MEMBER_EPOCH}")
    aws = [retention["aws-us-east"]["W"][str(w)]["retention"]
           for w in W_GRID]
    if any(b < a for a, b in zip(aws, aws[1:])):
        failures.append(f"aws retention not monotone in W: {aws}")
    if not aws[-1] > aws[0]:
        failures.append(f"aws retention never improves with W: {aws}")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
