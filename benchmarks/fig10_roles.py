"""Fig. 10: goodput/latency vs number of secretaries / observers."""
import numpy as np

from benchmarks.common import PAPER_CLUSTER, tick_ms
from repro.core.runtime import BWRaftSim


def run(quick: bool = True):
    rows = []
    for n_obs in ([1, 2] if quick else [0, 1, 2, 4, 8]):
        sim = BWRaftSim(PAPER_CLUSTER, write_rate=2.0, read_rate=64.0,
                        seed=6, manage_resources=False)
        sim._lease(1, n_obs)
        r = sim.run(4 if quick else 10)[-1]
        rows.append((f"fig10.read_goodput.obs{n_obs}", r.reads_served,
                     "reads_per_epoch"))
        rows.append((f"fig10.read_latency.obs{n_obs}",
                     tick_ms(r.read_lat_mean) * 1e3, "us"))
    for n_sec in ([1, 2] if quick else [0, 1, 2, 4]):
        sim = BWRaftSim(PAPER_CLUSTER, write_rate=24.0, read_rate=8.0,
                        seed=6, manage_resources=False)
        sim._lease(n_sec, 1)
        r = sim.run(4 if quick else 10)[-1]
        rows.append((f"fig10.write_goodput.sec{n_sec}", r.writes_committed,
                     "writes_per_epoch"))
        rows.append((f"fig10.write_latency.sec{n_sec}",
                     tick_ms(np.nan_to_num(r.write_lat_mean)) * 1e3, "us"))
    return rows
