"""Fig. 8: overall goodput + expense comparison (headline numbers).

The Multi-Raft baseline runs as a device-coupled shard group on the
fleet path (DESIGN.md §9): its write p95/p99 and the 2PC prepare/abort
census below are measured in-graph, not synthesized post hoc."""
from benchmarks.common import PAPER_CLUSTER, run_systems


def run(quick: bool = True):
    bw, og, mr = run_systems(PAPER_CLUSTER, write_rate=12.0, read_rate=48.0,
                             epochs=5 if quick else 20)
    rows = []
    for name, r in [("bwraft", bw), ("original", og), ("multiraft", mr)]:
        rows.append((f"fig8.goodput.{name}", r.goodput, "ops_per_epoch"))
        rows.append((f"fig8.cost.{name}", r.cost * 1e6, "usd_x1e6"))
        rows.append((f"fig8.cost_per_kop.{name}",
                     1e9 * r.cost / max(r.goodput, 1), "usd_per_kop_x1e6"))
        # read-path tail, recovered exactly from the device-resident
        # read histogram (DESIGN.md §11)
        rows.append((f"fig8.read_lat_p95.{name}", r.read_lat_p95,
                     "ticks_p95"))
    rows.append(("fig8.two_pc_prepares.multiraft", mr.two_pc_prepares,
                 "prepares_per_epoch"))
    rows.append(("fig8.two_pc_aborts.multiraft", mr.two_pc_aborts,
                 "aborts_per_epoch"))
    rows.append(("fig8.goodput_gain_vs_original",
                 bw.goodput / max(og.goodput, 1), "x"))
    rows.append(("fig8.cost_saving_vs_multiraft",
                 100 * (1 - (bw.cost / max(bw.goodput, 1)) /
                        (mr.cost / max(mr.goodput, 1))), "pct_per_op"))
    return rows
