#!/usr/bin/env python
"""Open-loop SLO-goodput serving benchmark (DESIGN.md §11).

Measures and GATES the serving surface:

  gate        a `ConstantRate` open-loop plan at the closed-loop scalar
              rates must reproduce the closed-loop run **bit-identically**
              — states and reports — because the per-tick rate lookup
              selects the same Poisson intensity and the key draw is
              untouched.  Divergence exits 1 (the serving analogue of
              `perf_market.py`'s replay gate).
  sweep       a B-member open-loop fleet — diurnal curves, flash-crowd
              bursts, Zipfian keys, a DIFFERENT plan per member — must
              compile ONE program and run `run(E)` as ONE dispatch
              (CountingJit-asserted via `fleet.total_compile_count`),
              with per-member-epoch device→host bytes under the same
              digest ceiling `perf_fleet.py` enforces.  The full grid
              simulates ~1M requests per epoch in that one dispatch;
              arrived/served request volumes are recorded.
  comparison  the headline: BW-Raft vs original Raft vs Multi-Raft under
              the SAME open-loop plan (shards at `shard_workload`-divided
              intensity), scored by **goodput under a p95 deadline** —
              requests served within `P95_DEADLINE_TICKS`, read straight
              off the unit-bin read/write digest histograms
              (`runtime.goodput_under_deadline`; the Multi-Raft write
              side deduplicates cross-shard prepares by 1/(1+chi), the
              same arithmetic as its report counts).

Emits ``BENCH_serving.json``; CI runs ``--smoke`` and uploads it
(`.github/workflows/ci.yml`).

  PYTHONPATH=src python benchmarks/perf_serving.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.bwraft_kv import CONFIG
from repro.core import fleet as fleet_mod
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim, goodput_under_deadline
from repro.workload import (ConstantRate, DiurnalRate, FlashCrowd, OpenLoop,
                            ZipfianKeys)
from benchmarks.common import system_specs, tick_ms

# the serving SLO: a request is good if it completes within this many
# ticks (1 tick = 10 ms — a 300 ms deadline, see `common.tick_ms`)
P95_DEADLINE_TICKS = 30
# same digest ceiling perf_fleet.py / perf_market.py enforce (§7.1)
D2H_CEILING_BYTES_PER_MEMBER_EPOCH = 4096

_REPORT_FIELDS = ("reads_arrived", "writes_arrived", "reads_served",
                  "writes_committed", "killed", "n_secretaries",
                  "n_observers", "leader_changes", "no_leader_ticks",
                  "cost")


def closed_loop_gate(epochs: int) -> dict:
    """§11 coexistence invariant on the paper cluster, manager ON: a
    flat open-loop plan at the closed-loop rates must match the
    closed-loop run bit for bit (same Poisson intensity per tick, key
    draw untouched)."""
    kw = dict(write_rate=8.0, read_rate=32.0, phi=0.02, seed=0)
    closed = BWRaftSim(CONFIG, **kw)
    closed_reports = closed.run(epochs)
    plan = OpenLoop(write=ConstantRate(8.0), read=ConstantRate(32.0),
                    ticks=CONFIG.period_ticks)
    opened = BWRaftSim(CONFIG, **kw, arrivals=plan)
    open_reports = opened.run(epochs)

    state_ok = all(np.array_equal(np.asarray(closed.state[k]),
                                  np.asarray(opened.state[k]))
                   for k in closed.state)
    reports_ok = all(
        getattr(a, f) == getattr(b, f)
        for a, b in zip(closed_reports, open_reports)
        for f in _REPORT_FIELDS)
    return {"epochs": epochs, "cluster": CONFIG.name,
            "managed": True, "phi": 0.02,
            "bit_identical": bool(state_ok and reports_ok),
            "state_identical": bool(state_ok),
            "reports_identical": bool(reports_ok)}


def _member_plan(i: int, read_rate: float, write_rate: float) -> OpenLoop:
    """A distinct diurnal + flash-crowd plan per member: phase-shifted
    day/night curve, burst windows offset per member."""
    writes = DiurnalRate(write_rate, amplitude=0.5,
                         phase=0.3 * i)
    reads = FlashCrowd(DiurnalRate(read_rate, amplitude=0.5,
                                   phase=0.3 * i),
                       mult=4.0, every_ticks=50, burst_ticks=5,
                       offset=7 * i)
    return OpenLoop(write=writes, read=reads,
                    ticks=2 * CONFIG.period_ticks)


def _sweep_fleet(b: int, read_rate: float, write_rate: float) -> FleetSim:
    specs = [MemberSpec(
        cfg=CONFIG, write_rate=write_rate, read_rate=read_rate,
        seed=i, manage_resources=False, prelease=(2, 6),
        arrivals=_member_plan(i, read_rate, write_rate),
        keypop=ZipfianKeys(1.1)) for i in range(b)]
    return FleetSim(specs)


def measure_sweep(b: int, epochs: int, read_rate: float,
                  write_rate: float) -> dict:
    """Warm-compile then time a B-member open-loop single-dispatch run;
    report wall time, request volumes, D2H bytes, and the compile delta
    (must be exactly 1 program for the whole run)."""
    before = fleet_mod.total_compile_count()
    _sweep_fleet(b, read_rate, write_rate).run(epochs)    # warm compile
    compiles = fleet_mod.total_compile_count() - before
    fleet = _sweep_fleet(b, read_rate, write_rate)
    assert fleet.single_dispatch_eligible
    t0 = time.perf_counter()
    reports = fleet.run(epochs)
    wall_s = time.perf_counter() - t0
    arrived = sum(r.reads_arrived + r.writes_arrived
                  for m in reports for r in m)
    served = sum(r.reads_served + r.writes_committed
                 for m in reports for r in m)
    return {
        "B": b, "epochs": epochs,
        "read_rate": read_rate, "write_rate": write_rate,
        "wall_s": wall_s,
        "epoch_wall_s": wall_s / epochs,
        "ticks_per_sec": b * epochs * fleet.shapes.T / wall_s,
        "requests_arrived_per_epoch": arrived / epochs,
        "requests_served_per_epoch": served / epochs,
        "requests_per_sec": arrived / wall_s,
        "d2h_bytes_per_member_epoch": fleet.d2h_bytes / epochs / b,
        "dispatches_per_run": 1,
        "compile_count": compiles,
    }


def _slo_row(read_hist, write_hist, rep, deadline: int,
             write_dedup: float = 1.0) -> dict:
    """Score one system's epoch from its digest histograms: goodput
    under the deadline (reads + deduplicated writes) next to the
    arrival volume and the read/write tails."""
    good_r = goodput_under_deadline(read_hist, deadline)
    good_w = int(goodput_under_deadline(write_hist, deadline) / write_dedup)
    arrived = int(rep.reads_arrived + rep.writes_arrived)
    return {
        "goodput_under_deadline": good_r + good_w,
        "good_reads": good_r, "good_writes": good_w,
        "requests_arrived": arrived,
        "slo_attainment": (good_r + good_w) / max(arrived, 1),
        "read_lat_p95": rep.read_lat_p95,
        "read_lat_p99": rep.read_lat_p99,
        "write_lat_p95": rep.write_lat_p95,
        "cost": rep.cost,
    }


def serving_comparison(epochs: int, *, write_rate: float = 16.0,
                       read_rate: float = 48.0, shards: int = 2,
                       deadline: int = P95_DEADLINE_TICKS) -> dict:
    """BW-Raft vs original Raft vs Multi-Raft under the same open-loop
    plan, scored by goodput under the p95 deadline — one batched fleet,
    histograms straight off the last epoch's digest."""
    plan = OpenLoop(write=DiurnalRate(write_rate, amplitude=0.5),
                    read=FlashCrowd(DiurnalRate(read_rate, amplitude=0.5),
                                    mult=4.0),
                    ticks=2 * CONFIG.period_ticks)
    chi = 0.1
    specs = system_specs(CONFIG, write_rate=write_rate,
                         read_rate=read_rate, shards=shards, group_id=0,
                         arrivals=plan, keypop=ZipfianKeys(1.1))
    fleet = FleetSim(specs)
    fleet.run(epochs)
    dg, gdg = fleet.last_digest, fleet.last_group_digest
    bw = fleet.members[0].reports[-1]
    og = fleet.members[1].reports[-1]
    mr = fleet.group_reports[0][-1]
    return {
        "deadline_ticks": deadline,
        "deadline_ms": tick_ms(deadline),
        "plan": {"write": f"diurnal({write_rate})",
                 "read": f"flashcrowd(diurnal({read_rate}))",
                 "keys": "zipfian(1.1)",
                 "ticks": 2 * CONFIG.period_ticks},
        "bwraft": _slo_row(dg["read_lat_hist"][0], dg["write_lat_hist"][0],
                           bw, deadline),
        "original": _slo_row(dg["read_lat_hist"][1],
                             dg["write_lat_hist"][1], og, deadline),
        "multiraft": _slo_row(gdg["read_lat_hist"][0],
                              gdg["write_lat_hist"][0], mr, deadline,
                              write_dedup=1 + chi),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    if args.smoke:
        b, epochs, read_rate, write_rate = 4, 2, 48.0, 8.0
    else:
        b, epochs, read_rate, write_rate = 32, 5, 300.0, 20.0
    print(f"=== open-loop serving surface: B={b}, {epochs} epochs ===")

    gate = closed_loop_gate(epochs)
    print(f"closed-loop gate (flat plan, managed, phi=0.02): "
          f"bit_identical={gate['bit_identical']}")

    sweep = measure_sweep(b, epochs, read_rate, write_rate)
    print(f"open-loop sweep: {sweep['epoch_wall_s']*1e3:8.1f} ms/epoch"
          f"  {sweep['requests_arrived_per_epoch']:>12.0f} reqs/epoch"
          f"  {sweep['compile_count']} compile(s), "
          f"{sweep['dispatches_per_run']} dispatch/run")

    comparison = serving_comparison(epochs)
    for label in ("bwraft", "original", "multiraft"):
        row = comparison[label]
        print(f"{label:>10}: goodput@{comparison['deadline_ms']:.0f}ms "
              f"{row['goodput_under_deadline']:>7d} "
              f"({100*row['slo_attainment']:.1f}% of arrivals)  "
              f"read p99 {row['read_lat_p99']:.0f} ticks  "
              f"cost ${row['cost']:.4f}")

    result = {
        "config": {"B": b, "epochs": epochs, "T": CONFIG.period_ticks,
                   "read_rate": read_rate, "write_rate": write_rate,
                   "cluster": CONFIG.name, "smoke": args.smoke},
        "gate": gate,
        "sweep": sweep,
        "comparison": comparison,
        "ceilings": {
            "d2h_bytes_per_member_epoch":
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH,
            "compile_count_per_sweep": 1,
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    failures = []
    if not gate["bit_identical"]:
        failures.append("flat open-loop plan diverged from the "
                        "closed-loop run (§11 coexistence invariant)")
    if sweep["compile_count"] != 1:
        failures.append(f"open-loop sweep compiled "
                        f"{sweep['compile_count']} programs "
                        f"(must be exactly 1)")
    if (sweep["d2h_bytes_per_member_epoch"] >
            D2H_CEILING_BYTES_PER_MEMBER_EPOCH):
        failures.append(
            f"{sweep['d2h_bytes_per_member_epoch']:.0f} D2H "
            f"bytes/member/epoch exceeds ceiling "
            f"{D2H_CEILING_BYTES_PER_MEMBER_EPOCH}")
    for label in ("bwraft", "original", "multiraft"):
        if comparison[label]["goodput_under_deadline"] <= 0:
            failures.append(f"{label}: zero goodput under the "
                            f"{P95_DEADLINE_TICKS}-tick deadline")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
