"""Fig. 6: read/write latency snapshots over epochs (3 systems).

Multi-Raft runs on the grouped fleet engine (measured 2PC latency,
DESIGN.md §9) unless `--sequential` selects the frozen host reference.
"""
import numpy as np

from benchmarks import common
from benchmarks.common import PAPER_CLUSTER, Row, run_systems, tick_ms
from repro.core.runtime import BWRaftSim
from repro.core.multiraft import MultiRaftSim


def run(quick: bool = True):
    epochs = 6 if quick else 60
    rows = []
    bw = BWRaftSim(PAPER_CLUSTER, write_rate=8.0, read_rate=48.0, seed=2)
    og = BWRaftSim(PAPER_CLUSTER, mode="raft", write_rate=8.0,
                   read_rate=48.0, seed=2)
    mr = MultiRaftSim(PAPER_CLUSTER, shards=2, write_rate=8.0,
                      read_rate=48.0, seed=2,
                      engine="fleet" if common.USE_FLEET
                      else "sequential")
    bw_r, og_r, mr_r = bw.run(epochs), og.run(epochs), mr.run(epochs)
    tail = max(epochs // 2, 1)
    for name, rs in [("bwraft", bw_r), ("original", og_r),
                     ("multiraft", mr_r)]:
        rlat = np.nanmean([r.read_lat_mean for r in rs[-tail:]])
        wlat = np.nanmean([r.write_lat_mean for r in rs[-tail:]])
        rows.append((f"fig6.read_latency.{name}", tick_ms(rlat) * 1e3,
                     f"{tick_ms(rlat):.0f}ms_mean_read"))
        rows.append((f"fig6.write_latency.{name}", tick_ms(wlat) * 1e3,
                     f"{tick_ms(wlat):.0f}ms_mean_write"))
    return rows
