"""Fig. 13: impact of the spot failure rate phi."""
from benchmarks.common import PAPER_CLUSTER
from repro.core.runtime import BWRaftSim


def run(quick: bool = True):
    rows = []
    phis = [0.0, 0.05] if quick else [0.0, 0.01, 0.05, 0.1, 0.2]
    for phi in phis:
        sim = BWRaftSim(PAPER_CLUSTER, write_rate=12.0, read_rate=48.0,
                        phi=phi, seed=12)
        r = sim.run(5 if quick else 15)[-1]
        rows.append((f"fig13.goodput.phi{int(phi*100)}", r.goodput,
                     "ops_per_epoch"))
        rows.append((f"fig13.killed.phi{int(phi*100)}", r.killed,
                     "revocations_per_epoch"))
        rows.append((f"fig13.secretaries.phi{int(phi*100)}",
                     r.n_secretaries, "alive"))
    return rows
