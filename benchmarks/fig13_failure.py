"""Fig. 13: impact of the spot failure rate phi — and the §12 warning
window W.

The kill-rate grid runs as one `FleetSim` over the phi axis: phi is a
per-member jit argument, so every point shares the single compiled
batched epoch (DESIGN.md §7).  The grid is *fixed-role*: one epoch to
stabilize leadership (the first election stops any earlier secretaries,
paper Step 1), then a full spot complement is wired ONCE
(`lease_fixed`) and never re-leased — the remaining epochs show raw phi
attrition on a provisioned cluster (kills summed over the run, survivor
counts in `n_secretaries`) and run as ONE device dispatch via the
multi-epoch scan (DESIGN.md §7.1).  The manager's ability to re-lease
under churn is exercised separately (fig14, tests/test_system.py).

Each phi point also reports recovery (leaderless ticks over the run)
and goodput retention vs the phi=0 member.  A second fixed-role grid
sweeps the advance-warning window W (DESIGN.md §12) at a hot market
(`spot_price_vol=2.0`, so price-over-bid revocations actually fire):
W is cfg_c data, so the whole W axis shares one compiled program; a
longer warning delays every kill and degrades warned relays gracefully,
so retention vs the calm-market member recovers with W.
"""
from benchmarks import common
from benchmarks.common import PAPER_CLUSTER
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim

FIXED_ROLES = (4, 8)    # provisioned complement the phi axis erodes
HOT_VOL = 2.0           # W-grid market: hot enough to cross the bid


def _fixed_role_reports(specs, epochs):
    """The fig13 recipe: stabilize one epoch, wire FIXED_ROLES once,
    then one multi-epoch dispatch (fleet) or per-member loop."""
    if common.USE_FLEET:
        fleet = FleetSim(specs)
        assert fleet.single_dispatch_eligible
        fleet.run(1)                            # leadership stabilizes
        fleet.lease_fixed(*FIXED_ROLES)
        return fleet.run(epochs - 1)            # ONE dispatch
    out = []
    for spec in specs:
        sim = BWRaftSim(spec.cfg, mode=spec.mode,
                        write_rate=spec.write_rate,
                        read_rate=spec.read_rate, phi=spec.phi,
                        seed=spec.seed, manage_resources=False,
                        spot_price_vol=spec.spot_price_vol,
                        warning_ticks=spec.warning_ticks)
        sim.run(1)
        sim.lease_fixed(*FIXED_ROLES)
        out.append(sim.run(epochs - 1))
    return out


def run(quick: bool = True):
    rows = []
    phis = [0.0, 0.05] if quick else [0.0, 0.01, 0.05, 0.1, 0.2]
    warns = [0, 5] if quick else [0, 2, 5, 10, 20]
    epochs = 5 if quick else 15

    reports = _fixed_role_reports(
        [MemberSpec(cfg=PAPER_CLUSTER, write_rate=12.0, read_rate=48.0,
                    phi=phi, seed=12, manage_resources=False)
         for phi in phis], epochs)

    # retention compares RUN-SUMMED goodput (kills erode a fixed-role
    # cluster permanently, so "how long the complement survived" is the
    # signal — the last epoch alone saturates once everything is dead)
    base_goodput = max(sum(r.goodput for r in reports[0]), 1)   # phi=0
    for phi, reps in zip(phis, reports):
        rows.append((f"fig13.goodput.phi{int(phi*100)}", reps[-1].goodput,
                     "ops_per_epoch"))
        rows.append((f"fig13.killed.phi{int(phi*100)}",
                     sum(r.killed for r in reps), "revocations_per_run"))
        rows.append((f"fig13.secretaries.phi{int(phi*100)}",
                     reps[-1].n_secretaries, "alive"))
        rows.append((f"fig13.recovery.phi{int(phi*100)}",
                     sum(r.no_leader_ticks for r in reps),
                     "leaderless_ticks_per_run"))
        rows.append((f"fig13.retention.phi{int(phi*100)}",
                     sum(r.goodput for r in reps) / base_goodput,
                     "frac_of_phi0"))

    # W grid (DESIGN.md §12): same fixed-role recipe on a hot market,
    # plus one calm-market member (vol=0: the walk never leaves the
    # mean, no revocations) as the retention baseline.  Read rate is
    # pushed into the capacity-bound regime so the observers actually
    # carry goodput — that is where losing them (and getting them back
    # via warnings/reprieves) moves retention.
    w_read_rate = 240.0
    w_reports = _fixed_role_reports(
        [MemberSpec(cfg=PAPER_CLUSTER, write_rate=12.0,
                    read_rate=w_read_rate, seed=12,
                    manage_resources=False, spot_price_vol=0.0)]
        + [MemberSpec(cfg=PAPER_CLUSTER, write_rate=12.0,
                      read_rate=w_read_rate, seed=12,
                      manage_resources=False,
                      spot_price_vol=HOT_VOL, warning_ticks=w)
           for w in warns], epochs)

    calm_goodput = max(sum(r.goodput for r in w_reports[0]), 1)
    for w, reps in zip(warns, w_reports[1:]):
        rows.append((f"fig13.goodput.W{w}", reps[-1].goodput,
                     "ops_per_epoch"))
        rows.append((f"fig13.killed.W{w}", sum(r.killed for r in reps),
                     "revocations_per_run"))
        rows.append((f"fig13.recovery.W{w}",
                     sum(r.no_leader_ticks for r in reps),
                     "leaderless_ticks_per_run"))
        rows.append((f"fig13.retention.W{w}",
                     sum(r.goodput for r in reps) / calm_goodput,
                     "frac_of_calm"))
    return rows
