"""Fig. 13: impact of the spot failure rate phi.

The kill-rate grid runs as one `FleetSim.sweep` over the phi axis: phi is
a per-member jit argument, so every point shares the single compiled
batched epoch (DESIGN.md §7).
"""
from benchmarks import common
from benchmarks.common import PAPER_CLUSTER
from repro.core.fleet import FleetSim
from repro.core.runtime import BWRaftSim


def run(quick: bool = True):
    rows = []
    phis = [0.0, 0.05] if quick else [0.0, 0.01, 0.05, 0.1, 0.2]
    epochs = 5 if quick else 15

    if common.USE_FLEET:
        reports = FleetSim.sweep(PAPER_CLUSTER, {"phi": phis},
                                 epochs=epochs, write_rate=12.0,
                                 read_rate=48.0, seed=12)
        finals = [reps[-1] for reps in reports]
    else:
        finals = [BWRaftSim(PAPER_CLUSTER, write_rate=12.0, read_rate=48.0,
                            phi=phi, seed=12).run(epochs)[-1]
                  for phi in phis]

    for phi, r in zip(phis, finals):
        rows.append((f"fig13.goodput.phi{int(phi*100)}", r.goodput,
                     "ops_per_epoch"))
        rows.append((f"fig13.killed.phi{int(phi*100)}", r.killed,
                     "revocations_per_epoch"))
        rows.append((f"fig13.secretaries.phi{int(phi*100)}",
                     r.n_secretaries, "alive"))
    return rows
