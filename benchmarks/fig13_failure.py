"""Fig. 13: impact of the spot failure rate phi.

The kill-rate grid runs as one `FleetSim` over the phi axis: phi is a
per-member jit argument, so every point shares the single compiled
batched epoch (DESIGN.md §7).  The grid is *fixed-role*: one epoch to
stabilize leadership (the first election stops any earlier secretaries,
paper Step 1), then a full spot complement is wired ONCE
(`lease_fixed`) and never re-leased — the remaining epochs show raw phi
attrition on a provisioned cluster (kills summed over the run, survivor
counts in `n_secretaries`) and run as ONE device dispatch via the
multi-epoch scan (DESIGN.md §7.1).  The manager's ability to re-lease
under churn is exercised separately (fig14, tests/test_system.py).
"""
from benchmarks import common
from benchmarks.common import PAPER_CLUSTER
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim

FIXED_ROLES = (4, 8)    # provisioned complement the phi axis erodes


def run(quick: bool = True):
    rows = []
    phis = [0.0, 0.05] if quick else [0.0, 0.01, 0.05, 0.1, 0.2]
    epochs = 5 if quick else 15

    if common.USE_FLEET:
        fleet = FleetSim([MemberSpec(cfg=PAPER_CLUSTER, write_rate=12.0,
                                     read_rate=48.0, phi=phi, seed=12,
                                     manage_resources=False)
                          for phi in phis])
        assert fleet.single_dispatch_eligible
        fleet.run(1)                            # leadership stabilizes
        fleet.lease_fixed(*FIXED_ROLES)
        reports = fleet.run(epochs - 1)         # ONE dispatch
    else:
        reports = []
        for phi in phis:
            sim = BWRaftSim(PAPER_CLUSTER, write_rate=12.0, read_rate=48.0,
                            phi=phi, seed=12, manage_resources=False)
            sim.run(1)
            sim.lease_fixed(*FIXED_ROLES)
            reports.append(sim.run(epochs - 1))

    for phi, reps in zip(phis, reports):
        rows.append((f"fig13.goodput.phi{int(phi*100)}", reps[-1].goodput,
                     "ops_per_epoch"))
        rows.append((f"fig13.killed.phi{int(phi*100)}",
                     sum(r.killed for r in reps), "revocations_per_run"))
        rows.append((f"fig13.secretaries.phi{int(phi*100)}",
                     reps[-1].n_secretaries, "alive"))
    return rows
