"""Fig. 12: impact of the R/W ratio alpha on goodput + expense.

All alpha points share one topology (the paper cluster), so the sweep is
a single FleetSim: per-alpha write/read rates are just batched jit
arguments — zero recompiles across the grid (DESIGN.md §7).
"""
from benchmarks import common
from benchmarks.common import PAPER_CLUSTER
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim


def run(quick: bool = True):
    rows = []
    total = 64.0
    alphas = [0.5, 0.9] if quick else [0.1, 0.3, 0.5, 0.7, 0.9, 0.99]
    epochs = 5 if quick else 15

    if common.USE_FLEET:
        specs = [MemberSpec(cfg=PAPER_CLUSTER,
                            write_rate=total * (1 - alpha),
                            read_rate=total * alpha, seed=10)
                 for alpha in alphas]
        finals = [reps[-1] for reps in FleetSim(specs).run(epochs)]
    else:
        finals = [BWRaftSim(PAPER_CLUSTER, write_rate=total * (1 - alpha),
                            read_rate=total * alpha, seed=10)
                  .run(epochs)[-1] for alpha in alphas]

    for alpha, r in zip(alphas, finals):
        rows.append((f"fig12.goodput.alpha{int(alpha*100)}", r.goodput,
                     "ops_per_epoch"))
        rows.append((f"fig12.cost.alpha{int(alpha*100)}", r.cost * 1e6,
                     "usd_x1e6"))
    return rows
