"""Fig. 12: impact of the R/W ratio alpha on goodput + expense."""
from benchmarks.common import PAPER_CLUSTER
from repro.core.runtime import BWRaftSim


def run(quick: bool = True):
    rows = []
    total = 64.0
    alphas = [0.5, 0.9] if quick else [0.1, 0.3, 0.5, 0.7, 0.9, 0.99]
    for alpha in alphas:
        sim = BWRaftSim(PAPER_CLUSTER, write_rate=total * (1 - alpha),
                        read_rate=total * alpha, seed=10)
        r = sim.run(5 if quick else 15)[-1]
        rows.append((f"fig12.goodput.alpha{int(alpha*100)}", r.goodput,
                     "ops_per_epoch"))
        rows.append((f"fig12.cost.alpha{int(alpha*100)}", r.cost * 1e6,
                     "usd_x1e6"))
    return rows
