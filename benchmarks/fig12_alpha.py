"""Fig. 12: impact of the R/W ratio alpha on goodput + expense.

All alpha points share one topology (the paper cluster), so the sweep is
a single FleetSim: per-alpha write/read rates are just batched jit
arguments — zero recompiles across the grid (DESIGN.md §7).  The grid is
a *fixed-role* sweep: one epoch to stabilize leadership, then a static
secretary/observer complement is wired ONCE (`lease_fixed`) and no
member manages per epoch, so the remaining epochs run as ONE device
dispatch (the multi-epoch scan, DESIGN.md §7.1) with only the per-epoch
digests crossing to host.
"""
from benchmarks import common
from benchmarks.common import PAPER_CLUSTER
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim

# fixed spot complement for the sweep: secretaries absorb the write
# fan-out, observers absorb the read traffic the alpha axis shifts around
FIXED_ROLES = (2, 8)


def run(quick: bool = True):
    rows = []
    total = 64.0
    alphas = [0.5, 0.9] if quick else [0.1, 0.3, 0.5, 0.7, 0.9, 0.99]
    epochs = 5 if quick else 15

    if common.USE_FLEET:
        fleet = FleetSim([MemberSpec(cfg=PAPER_CLUSTER,
                                     write_rate=total * (1 - alpha),
                                     read_rate=total * alpha, seed=10,
                                     manage_resources=False)
                          for alpha in alphas])
        assert fleet.single_dispatch_eligible
        fleet.run(1)                            # leadership stabilizes
        fleet.lease_fixed(*FIXED_ROLES)
        finals = [reps[-1] for reps in fleet.run(epochs - 1)]
    else:
        finals = []
        for alpha in alphas:
            sim = BWRaftSim(PAPER_CLUSTER, write_rate=total * (1 - alpha),
                            read_rate=total * alpha, seed=10,
                            manage_resources=False)
            sim.run(1)
            sim.lease_fixed(*FIXED_ROLES)
            finals.append(sim.run(epochs - 1)[-1])

    for alpha, r in zip(alphas, finals):
        rows.append((f"fig12.goodput.alpha{int(alpha*100)}", r.goodput,
                     "ops_per_epoch"))
        rows.append((f"fig12.cost.alpha{int(alpha*100)}", r.cost * 1e6,
                     "usd_x1e6"))
    return rows
