#!/usr/bin/env python
"""Consensus-tick kernel benchmark: pallas vs xla vs reference.

Times the FOUR Pallas kernel families (DESIGN.md §8) and the
end-to-end protocol tick on every formulation the repo carries:

  per kernel    each Pallas op against its frozen `ref.py` twin, at
                the paper cluster's shapes:
                  raft_tick       log_match_append / commit_majority /
                                  apply_last_wins
                  leader_fanout   fused budgeted AppendEntries fan-out
                  group_digest    blockwise masked group reduction
                  ae_sync         fused anti-entropy round
  end to end    a jitted T-tick scan of `step.tick` on
                backend="pallas", backend="xla" (the PR-2 fast path),
                and reference=True (the PR-1 baseline).

Before timing, every kernel family is checked **bit-identical**
against its ref twin on random operands, and the three end-to-end
trajectories are checked bit-identical from the same seed — the run
FAILS (exit 1) if any output or state leaf diverges, so CI catches
kernel-contract regressions even on machines where the timings
themselves are noise.

Emits ``BENCH_tick.json``.  Interpret-mode caveat: off-TPU the pallas
numbers measure the Pallas *interpreter* traced into XLA, not kernel
speed (DESIGN.md §8).  Every timing block therefore carries an
explicit ``"interpreted": true/false`` field — when it is true the
pallas ratios are NOT kernel speedups and no perf ceiling is enforced.

  PYTHONPATH=src python benchmarks/perf_tick.py [--smoke] [--out PATH]

``--smoke`` shrinks the cluster and iteration counts for CI.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.bwraft_kv import CONFIG
from repro.core import state as state_mod
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.runtime import make_cfg_arrays
from repro.kernels.ae_sync import ops as ae_ops
from repro.kernels.ae_sync import ref as ae_ref
from repro.kernels.group_digest import ops as gd_ops
from repro.kernels.group_digest import ref as gd_ref
from repro.kernels.leader_fanout import ops as lf_ops
from repro.kernels.leader_fanout import ref as lf_ref
from repro.kernels.raft_tick import ops as rt_ops
from repro.kernels.raft_tick import ref as rt_ref

SMOKE_CONFIG = ClusterConfig(
    name="bwraft-kv-smoke",
    sites=(SiteConfig("s0", followers=2, rtt_intra=1, rtt_inter=6,
                      on_demand_price=0.0416, spot_price_mean=0.0125),
           SiteConfig("s1", followers=1, rtt_intra=1, rtt_inter=8,
                      on_demand_price=0.0416, spot_price_mean=0.0125)),
    period_ticks=40, max_log=256, key_space=128,
    max_secretaries=2, max_observers=4)


def _timeit(fn, *args, iters: int, warmup: int = 1) -> float:
    """Median wall seconds per call of a jitted fn (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _kernel_inputs(cfg: ClusterConfig, static, seed: int = 0):
    """Plausible operands at the cluster's real shapes (the equivalence
    itself is enforced on full trajectories below and in tests)."""
    rng = np.random.default_rng(seed)
    N, L, K = static["N"], cfg.max_log, cfg.key_space
    A, W = static["max_apply"], static["max_ship"]
    mk = lambda hi, sh: jnp.asarray(rng.integers(0, hi, sh), jnp.int32)
    return {
        "log_match": dict(
            log_term=mk(3, (N, L)), log_key=mk(K, (N, L)),
            log_val=mk(2**20, (N, L)), ldr_term=mk(3, (L,)),
            ldr_key=mk(K, (L,)), ldr_val=mk(2**20, (L,)),
            log_len=mk(L + 1, (N,)), app_from_len=mk(L + 1, (N,)),
            app_upto=mk(L + 1, (N,)),
            due=jnp.asarray(rng.random(N) < 0.5)),
        "commit": dict(
            match_len=mk(L + 1, (N,)),
            voter_alive=jnp.asarray(static["is_voter"]),
            ldr_term=mk(3, (L,)), ldr_cur_term=jnp.int32(1),
            majority=jnp.int32(static["majority"])),
        "apply": dict(
            kv=mk(2**20, (N, K)), keys=mk(K, (N, A)),
            vals=mk(2**20, (N, A)),
            valid=jnp.asarray(rng.random((N, A)) < 0.7)),
        "W": W,
    }


def _wide_inputs(cfg: ClusterConfig, static, seed: int = 1):
    """Random operands for the PR-9 families, at the cluster's real
    shapes (property sweeps live in tests/test_wide_kernels.py)."""
    rng = np.random.default_rng(seed)
    N, L = static["N"], cfg.max_log
    # the tick static carries no digest-tier slots; provision some so
    # the ae_sync family benches at a real observer width
    static_o = state_mod.build_static(
        cfg, n_obs_digest=max(cfg.max_observers, 2))
    O = len(static_o["dobs_site"])
    i32 = lambda a: jnp.asarray(a, jnp.int32)
    mk = lambda lo, hi, sh: i32(rng.integers(lo, hi, sh))
    fanout = dict(
        role=mk(0, 6, (N,)), alive=jnp.asarray(rng.random(N) < 0.8),
        warn_timer=mk(-1, 5, (N,)), sec_of=mk(-1, N, (N,)),
        match_len=mk(0, L + 1, (N,)), app_arrive_t=mk(-1, 40, (N,)),
        app_from_len=mk(0, L + 1, (N,)), app_upto=mk(0, L + 1, (N,)),
        app_term=mk(0, 4, (N,)), app_commit=mk(0, L + 1, (N,)),
        rtt=jnp.asarray(static["rtt"], jnp.int32),
        lid_c=jnp.int32(0), has_leader=jnp.asarray(True),
        tick=jnp.int32(7), ldr_len=jnp.int32(L), ldr_term=jnp.int32(2),
        ldr_commit=jnp.int32(L // 2))
    B, G, H = 32, 5, 64
    group = dict(
        gids=mk(0, G + 1, (B,)),            # == G rows drop (ragged)
        int_mat=mk(0, 2**20, (B, 2 * H + 9)),
        flt_mat=jnp.asarray(
            rng.standard_normal((B, 3)) * 100.0, jnp.float32))
    ae = dict(
        dobs_alive=mk(0, 2, (O,)), dobs_fol=mk(-1, N, (O,)),
        dobs_applied=mk(0, L, (O,)), dobs_term=mk(0, 4, (O,)),
        dobs_digest=jnp.asarray(
            rng.integers(0, 2**32, O, dtype=np.uint32)),
        dobs_synced_t=mk(-1, 40, (O,)), ae_phase=mk(0, 4, (O,)),
        dobs_site=i32(static_o["dobs_site"]),
        alive=jnp.asarray(rng.random(N) < 0.8),
        is_voter=jnp.asarray(static["is_voter"]),
        applied_len=mk(0, L + 1, (N,)), term=mk(0, 4, (N,)),
        applied_digest=jnp.asarray(
            rng.integers(0, 2**32, N, dtype=np.uint32)),
        site=i32(static["site"]),
        site_rtt=jnp.asarray(static_o["site_rtt"], jnp.int32),
        tick=jnp.int32(12), ae_interval=jnp.int32(4))
    return {"leader_fanout": fanout, "group_digest": group,
            "ae_sync": ae}


def bench_kernels(cfg: ClusterConfig, static, iters: int):
    """raft_tick ops vs ref twins; returns timing blocks (the raft_tick
    family's bit-identity gate is the trajectory check in bench_tick)."""
    inp = _kernel_inputs(cfg, static)
    W = inp["W"]
    interpret = rt_ops.use_interpret()
    # positional arg tuples (dict pytrees re-order under jit)
    pairs = {
        "log_match_append": (
            jax.jit(lambda *a: rt_ops.log_match_append(*a, w=W)),
            jax.jit(lambda *a: rt_ref.log_match_append_ref(*a, w=W)),
            tuple(inp["log_match"].values())),
        "commit_majority": (
            jax.jit(rt_ops.commit_majority),
            jax.jit(rt_ref.commit_majority_ref),
            tuple(inp["commit"].values())),
        "apply_last_wins": (
            jax.jit(rt_ops.apply_last_wins),
            jax.jit(rt_ref.apply_last_wins_ref),
            tuple(inp["apply"].values())),
    }
    out = {}
    for name, (pallas_fn, ref_fn, args_t) in pairs.items():
        p_ms = _timeit(pallas_fn, *args_t, iters=iters) * 1e3
        r_ms = _timeit(ref_fn, *args_t, iters=iters) * 1e3
        out[name] = {"pallas_ms": p_ms, "ref_ms": r_ms,
                     "pallas_vs_ref": r_ms / max(p_ms, 1e-12),
                     "interpreted": interpret}
    return out


def bench_wide_kernels(cfg: ClusterConfig, static, iters: int):
    """PR-9 families (fan-out / digest reduction / anti-entropy) vs ref
    twins; returns (timing blocks, equal: bool) — the bit-identity gate
    compares every output array exactly."""
    inp = _wide_inputs(cfg, static)
    interpret = rt_ops.use_interpret()
    knobs = dict(msg_budget=static["msg_budget"],
                 max_ship=static["max_ship"],
                 entries_per_msg=static["entries_per_msg"])
    G = 5
    u2i = lambda v: jax.lax.bitcast_convert_type(v, jnp.int32)

    def ae_ref_fn(*a):
        # ref twin works on int32 digest views (ops.py owns the bitcast)
        (da, df, dap, dt, dg, ds, ph, dsi, al, iv, apl, tm, adg, st,
         srtt, tick, itv) = a
        out = ae_ref.ae_sync_ref(da, df, dap, dt, u2i(dg), ds, ph, dsi,
                                 al, iv, apl, tm, u2i(adg), st, srtt,
                                 tick, itv)
        return (out[0], out[1],
                jax.lax.bitcast_convert_type(out[2], jnp.uint32), out[3])

    pairs = {
        "leader_fanout": (
            lambda *a: lf_ops.leader_fanout(*a, **knobs),
            jax.jit(lambda *a: lf_ref.leader_fanout_ref(*a, **knobs)),
            tuple(inp["leader_fanout"].values())),
        "group_digest": (
            lambda *a: gd_ops.group_reduce(*a, n_groups=G),
            jax.jit(lambda *a: gd_ref.group_reduce_ref(*a, n_groups=G)),
            tuple(inp["group_digest"].values())),
        "ae_sync": (
            ae_ops.ae_sync,
            jax.jit(ae_ref_fn),
            tuple(inp["ae_sync"].values())),
    }
    out, equal = {}, True
    for name, (pallas_fn, ref_fn, args_t) in pairs.items():
        got = jax.tree.map(np.asarray, pallas_fn(*args_t))
        want = jax.tree.map(np.asarray, ref_fn(*args_t))
        fam_eq = all(np.array_equal(g, w) for g, w in zip(got, want))
        equal &= fam_eq
        p_ms = _timeit(pallas_fn, *args_t, iters=iters) * 1e3
        r_ms = _timeit(ref_fn, *args_t, iters=iters) * 1e3
        out[name] = {"pallas_ms": p_ms, "ref_ms": r_ms,
                     "pallas_vs_ref": r_ms / max(p_ms, 1e-12),
                     "bit_identical": fam_eq, "interpreted": interpret}
    return out, equal


def bench_tick(cfg: ClusterConfig, static, T: int, iters: int):
    """End-to-end T-tick scans; returns (timing blocks, equal: bool)."""
    cfg_c = make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0, phi=0.02)
    state0 = state_mod.init_state(cfg, static)
    rngs = jax.random.split(jax.random.PRNGKey(0), T)
    interpret = rt_ops.use_interpret()

    def scan_fn(reference, backend):
        def body(c, r):
            s, _ = step_mod.tick(c, static, cfg_c, r, reference=reference,
                                 backend=backend)
            return s, None
        return jax.jit(lambda s: jax.lax.scan(body, s, rngs)[0])

    variants = {"xla": (scan_fn(False, "xla"), False),
                "pallas": (scan_fn(False, "pallas"), interpret),
                "reference": (scan_fn(True, "xla"), False)}
    finals, timings = {}, {}
    for name, (fn, interp) in variants.items():
        finals[name] = jax.tree.map(np.asarray, fn(state0))
        timings[name] = {
            "ms_per_tick": _timeit(fn, state0, iters=iters) * 1e3 / T,
            "interpreted": interp}
    equal = all(
        np.array_equal(finals["xla"][k], finals[v][k])
        for v in ("pallas", "reference") for k in finals["xla"])
    timings["speedup_xla_vs_reference"] = \
        timings["reference"]["ms_per_tick"] / timings["xla"]["ms_per_tick"]
    timings["pallas_vs_xla"] = \
        timings["xla"]["ms_per_tick"] / timings["pallas"]["ms_per_tick"]
    return timings, equal


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small cluster + few iters for CI (equivalence "
                         "gates only, timings informational)")
    ap.add_argument("--out", default="BENCH_tick.json")
    args = ap.parse_args(argv)

    cfg = SMOKE_CONFIG if args.smoke else CONFIG
    static = state_mod.build_static(cfg)
    T = cfg.period_ticks
    k_iters, t_iters = (3, 2) if args.smoke else (10, 3)
    interpret = rt_ops.use_interpret()
    print(f"=== pallas kernel layer: {cfg.name} N={static['N']} "
          f"L={cfg.max_log} K={cfg.key_space} T={T} "
          f"(pallas {'interpret' if interpret else 'compiled'}) ===")

    kernels = bench_kernels(cfg, static, k_iters)
    wide, wide_equal = bench_wide_kernels(cfg, static, k_iters)
    kernels.update(wide)
    for name, r in kernels.items():
        gate = "" if r.get("bit_identical", True) else "  DIVERGED"
        print(f"{name:>18}: pallas {r['pallas_ms']:8.2f} ms   "
              f"ref {r['ref_ms']:8.2f} ms{gate}")

    tick, equal = bench_tick(cfg, static, T, t_iters)
    print(f"{'tick (end-to-end)':>18}: "
          f"xla {tick['xla']['ms_per_tick']:.3f} ms/tick   "
          f"pallas {tick['pallas']['ms_per_tick']:.3f}   "
          f"reference {tick['reference']['ms_per_tick']:.3f}")
    print(f"trajectories bit-identical: {equal}   "
          f"wide kernels bit-identical: {wide_equal}")

    result = {
        "config": {"cluster": cfg.name, "N": int(static["N"]),
                   "L": cfg.max_log, "K": cfg.key_space,
                   "W": int(static["max_ship"]),
                   "A": int(static["max_apply"]), "T": T,
                   "smoke": args.smoke,
                   "jax_backend": jax.default_backend(),
                   "interpret": interpret},
        "kernels": kernels,
        "tick": tick,
        "equivalence": {
            "pallas_equals_xla_equals_reference": equal,
            "wide_kernels_equal_ref": wide_equal},
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    if not equal or not wide_equal:
        print("FAIL: a kernel formulation diverged from its twin",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
