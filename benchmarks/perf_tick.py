#!/usr/bin/env python
"""Consensus-tick kernel benchmark: pallas vs xla vs reference.

Times the three raft_tick hot ops (DESIGN.md §8) and the end-to-end
protocol tick on every formulation the repo carries:

  per kernel    the Pallas op (`kernels/raft_tick/ops.py`) against its
                PR-1 `ref.py` twin, at the paper cluster's shapes.
  end to end    a jitted T-tick scan of `step.tick` on
                backend="pallas", backend="xla" (the PR-2 fast path),
                and reference=True (the PR-1 baseline).

Before timing, the three end-to-end trajectories are checked
**bit-identical** from the same seed — the run FAILS (exit 1) if any
state leaf diverges, so CI catches kernel-contract regressions even on
machines where the timings themselves are noise.

Emits ``BENCH_tick.json``.  Interpret-mode caveat: off-TPU the pallas
numbers measure the Pallas *interpreter* traced into XLA, not kernel
speed (DESIGN.md §8); the JSON records which mode ran (`"interpret"`),
and no perf ceiling is enforced on interpret timings.

  PYTHONPATH=src python benchmarks/perf_tick.py [--smoke] [--out PATH]

``--smoke`` shrinks the cluster and iteration counts for CI.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.bwraft_kv import CONFIG
from repro.core import state as state_mod
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.runtime import make_cfg_arrays
from repro.kernels.raft_tick import ops as rt_ops
from repro.kernels.raft_tick import ref as rt_ref

SMOKE_CONFIG = ClusterConfig(
    name="bwraft-kv-smoke",
    sites=(SiteConfig("s0", followers=2, rtt_intra=1, rtt_inter=6,
                      on_demand_price=0.0416, spot_price_mean=0.0125),
           SiteConfig("s1", followers=1, rtt_intra=1, rtt_inter=8,
                      on_demand_price=0.0416, spot_price_mean=0.0125)),
    period_ticks=40, max_log=256, key_space=128,
    max_secretaries=2, max_observers=4)


def _timeit(fn, *args, iters: int, warmup: int = 1) -> float:
    """Median wall seconds per call of a jitted fn (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _kernel_inputs(cfg: ClusterConfig, static, seed: int = 0):
    """Plausible operands at the cluster's real shapes (the equivalence
    itself is enforced on full trajectories below and in tests)."""
    rng = np.random.default_rng(seed)
    N, L, K = static["N"], cfg.max_log, cfg.key_space
    A, W = static["max_apply"], static["max_ship"]
    mk = lambda hi, sh: jnp.asarray(rng.integers(0, hi, sh), jnp.int32)
    return {
        "log_match": dict(
            log_term=mk(3, (N, L)), log_key=mk(K, (N, L)),
            log_val=mk(2**20, (N, L)), ldr_term=mk(3, (L,)),
            ldr_key=mk(K, (L,)), ldr_val=mk(2**20, (L,)),
            log_len=mk(L + 1, (N,)), app_from_len=mk(L + 1, (N,)),
            app_upto=mk(L + 1, (N,)),
            due=jnp.asarray(rng.random(N) < 0.5)),
        "commit": dict(
            match_len=mk(L + 1, (N,)),
            voter_alive=jnp.asarray(static["is_voter"]),
            ldr_term=mk(3, (L,)), ldr_cur_term=jnp.int32(1),
            majority=jnp.int32(static["majority"])),
        "apply": dict(
            kv=mk(2**20, (N, K)), keys=mk(K, (N, A)),
            vals=mk(2**20, (N, A)),
            valid=jnp.asarray(rng.random((N, A)) < 0.7)),
        "W": W,
    }


def bench_kernels(cfg: ClusterConfig, static, iters: int) -> dict:
    inp = _kernel_inputs(cfg, static)
    W = inp["W"]
    # positional arg tuples (dict pytrees re-order under jit)
    pairs = {
        "log_match_append": (
            jax.jit(lambda *a: rt_ops.log_match_append(*a, w=W)),
            jax.jit(lambda *a: rt_ref.log_match_append_ref(*a, w=W)),
            tuple(inp["log_match"].values())),
        "commit_majority": (
            jax.jit(rt_ops.commit_majority),
            jax.jit(rt_ref.commit_majority_ref),
            tuple(inp["commit"].values())),
        "apply_last_wins": (
            jax.jit(rt_ops.apply_last_wins),
            jax.jit(rt_ref.apply_last_wins_ref),
            tuple(inp["apply"].values())),
    }
    out = {}
    for name, (pallas_fn, ref_fn, args_t) in pairs.items():
        p_ms = _timeit(pallas_fn, *args_t, iters=iters) * 1e3
        r_ms = _timeit(ref_fn, *args_t, iters=iters) * 1e3
        out[name] = {"pallas_ms": p_ms, "ref_ms": r_ms,
                     "pallas_vs_ref": r_ms / max(p_ms, 1e-12)}
    return out


def bench_tick(cfg: ClusterConfig, static, T: int, iters: int):
    """End-to-end T-tick scans; returns (timings, equal: bool)."""
    cfg_c = make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0, phi=0.02)
    state0 = state_mod.init_state(cfg, static)
    rngs = jax.random.split(jax.random.PRNGKey(0), T)

    def scan_fn(reference, backend):
        def body(c, r):
            s, _ = step_mod.tick(c, static, cfg_c, r, reference=reference,
                                 backend=backend)
            return s, None
        return jax.jit(lambda s: jax.lax.scan(body, s, rngs)[0])

    variants = {"xla": scan_fn(False, "xla"),
                "pallas": scan_fn(False, "pallas"),
                "reference": scan_fn(True, "xla")}
    finals, timings = {}, {}
    for name, fn in variants.items():
        finals[name] = jax.tree.map(np.asarray, fn(state0))
        timings[f"{name}_ms_per_tick"] = \
            _timeit(fn, state0, iters=iters) * 1e3 / T
    equal = all(
        np.array_equal(finals["xla"][k], finals[v][k])
        for v in ("pallas", "reference") for k in finals["xla"])
    timings["speedup_xla_vs_reference"] = \
        timings["reference_ms_per_tick"] / timings["xla_ms_per_tick"]
    timings["pallas_vs_xla"] = \
        timings["xla_ms_per_tick"] / timings["pallas_ms_per_tick"]
    return timings, equal


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small cluster + few iters for CI (equivalence "
                         "gate only, timings informational)")
    ap.add_argument("--out", default="BENCH_tick.json")
    args = ap.parse_args(argv)

    cfg = SMOKE_CONFIG if args.smoke else CONFIG
    static = state_mod.build_static(cfg)
    T = cfg.period_ticks
    k_iters, t_iters = (3, 2) if args.smoke else (10, 3)
    interpret = rt_ops.use_interpret()
    print(f"=== raft_tick kernels: {cfg.name} N={static['N']} "
          f"L={cfg.max_log} K={cfg.key_space} T={T} "
          f"(pallas {'interpret' if interpret else 'compiled'}) ===")

    kernels = bench_kernels(cfg, static, k_iters)
    for name, r in kernels.items():
        print(f"{name:>18}: pallas {r['pallas_ms']:8.2f} ms   "
              f"ref {r['ref_ms']:8.2f} ms")

    tick, equal = bench_tick(cfg, static, T, t_iters)
    print(f"{'tick (end-to-end)':>18}: xla {tick['xla_ms_per_tick']:.3f} "
          f"ms/tick   pallas {tick['pallas_ms_per_tick']:.3f}   "
          f"reference {tick['reference_ms_per_tick']:.3f}")
    print(f"trajectories bit-identical: {equal}")

    result = {
        "config": {"cluster": cfg.name, "N": int(static["N"]),
                   "L": cfg.max_log, "K": cfg.key_space,
                   "W": int(static["max_ship"]),
                   "A": int(static["max_apply"]), "T": T,
                   "smoke": args.smoke,
                   "jax_backend": jax.default_backend(),
                   "interpret": interpret},
        "kernels": kernels,
        "tick": tick,
        "equivalence": {"pallas_equals_xla_equals_reference": equal},
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    if not equal:
        print("FAIL: pallas/xla/reference trajectories diverged",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
