import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (assignment §g): derive the three roofline terms per
(arch x shape x mesh) from compiled artifacts.

Accounting (DESIGN.md §5): XLA's HLO cost analysis counts scan bodies
once, so this harness lowers *unrolled* programs at depth L0 = one
repeating period and L1 = two periods, and extrapolates
    cost(L) = c(L0) + (L - L0)/P * (c(L1) - c(L0)).
Training costs are measured per microbatch (grad+opt with the microbatch
slice) plus a separate optimizer-only program so the grad-accumulation
step total is  mb * c_micro - (mb-1) * c_opt  (exact).  Collective wire
bytes come from the unrolled HLO text (launch/hlo_stats.py).

Terms (per device, seconds):
    compute    = HLO_flops / 197e12        (TPU v5e bf16 peak)
    memory     = HLO_bytes / 819e9         (HBM bandwidth)
    collective = wire_bytes / 50e9         (per-link ICI)
MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N_active·B (decode).

``--raft`` instead rooflines the consensus hot paths of the widened
Pallas kernel layer (DESIGN.md §8): the leader fan-out and the grouped
digest reduction, lowered from their XLA formulations at the paper
cluster / fleet shapes — bytes, FLOPs, arithmetic intensity, and where
each lands against the TPU v5e ridge point.

Usage: python -m benchmarks.roofline [--arch A --shape S] [--all]
       [--json out.json] [--profile train_sp] [--microbatches N]
       [--raft] ...
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import numpy as np
import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES_BY_NAME, shape_applicable
from repro.launch import hlo_stats
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh, HW
from repro.models.common import abstract_tree, param_count
from repro.optim import adamw
from repro.sharding import axes as axes_mod

CHIPS = 256


def model_flops(cfg, shape) -> float:
    """Assignment formula: 6ND dense / 6·N_active·D MoE (per step, global)."""
    runcfg = S.default_runcfg(cfg, shape)
    n_total = param_count(S.param_specs(cfg, runcfg))
    n_active = n_total
    if cfg.moe_num_experts:
        from repro.models.moe import padded_experts
        E = padded_experts(cfg.moe_num_experts)
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
        routed = E * per_expert * n_moe_layers
        used = cfg.moe_top_k * per_expert * n_moe_layers
        n_active = n_total - routed + used
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 token


def _lower_cost(step, args, shs, donate, mesh):
    with mesh:
        compiled = jax.jit(step, in_shardings=shs,
                           donate_argnums=donate).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    txt = compiled.as_text()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire": float(hlo_stats.total_collective_bytes(txt)),
        "colls": hlo_stats.collective_stats(txt),
    }


def _opt_cost(cfg, runcfg, mesh, rules):
    """Optimizer-only program (adamw update with zero grads)."""
    ps = S.param_specs(cfg, runcfg)
    opt = adamw.abstract_opt_state(ps, S.DTYPES[runcfg.opt_state_dtype])
    log = axes_mod.PruneLog()
    sh = (axes_mod.tree_shardings(ps, rules, mesh, prune_log=log),
          axes_mod.tree_shardings(ps, rules, mesh),
          axes_mod.tree_shardings(opt, rules, mesh))

    def opt_step(params, grads, opt_state):
        return adamw.adamw_update(params, grads, opt_state,
                                  lr=1e-3, grad_clip=1.0)

    args = (abstract_tree(ps), abstract_tree(ps), abstract_tree(opt))
    return _lower_cost(opt_step, args, sh, (0, 2), mesh)


def analyse_cell(arch: str, shape_name: str, *, runcfg_overrides=None,
                 verbose=True):
    cfg_full = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg_full, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": why}
    mesh = make_production_mesh()
    overrides = dict(runcfg_overrides or {})
    mb = overrides.pop("num_microbatches", None)
    runcfg = S.default_runcfg(cfg_full, shape, scan_layers=False,
                              unroll_attn=True, num_microbatches=1,
                              **overrides)
    if mb is None:
        mb = S.default_runcfg(cfg_full, shape).num_microbatches \
            if shape.kind == "train" else 1
    rules = S.resolve_rules(cfg_full, runcfg.sharding_profile)

    P = cfg_full.layer_period
    L0, L1 = P, 2 * P
    t0 = time.time()
    costs = []
    for L in (L0, L1):
        cfg = cfg_full.with_layers(L)
        if shape.kind == "train":
            # per-microbatch slice
            micro = dataclasses.replace(shape,
                                        global_batch=shape.global_batch // mb)
            from repro.launch.dryrun import input_specs
            kind, args, shs, donate, rc, _, _ = input_specs(
                arch, shape_name, mesh=mesh, runcfg=runcfg)
            # rebuild with reduced depth + microbatch slice
            c = _cell_cost(cfg, micro, runcfg, mesh)
        else:
            c = _cell_cost(cfg, shape, runcfg, mesh)
        costs.append(c)
    c0, c1 = costs
    L_full = cfg_full.num_layers
    scale = (L_full - L0) / (L1 - L0)

    def extrap(key):
        return c0[key] + scale * (c1[key] - c0[key])

    flops = extrap("flops")
    nbytes = extrap("bytes")
    wire = extrap("wire")
    if shape.kind == "train" and mb > 1:
        co = _opt_cost(cfg_full, runcfg, mesh, rules)
        flops = mb * flops - (mb - 1) * co["flops"]
        nbytes = mb * nbytes - (mb - 1) * co["bytes"]
        wire = mb * wire - (mb - 1) * co["wire"]

    compute_t = flops / HW["peak_flops_bf16"]
    memory_t = nbytes / HW["hbm_gbps"]
    coll_t = wire / HW["ici_link_gbps"]
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg_full, shape) / CHIPS
    rec = {
        "arch": arch, "shape": shape_name, "status": "OK",
        "mesh": "16x16", "profile": runcfg.sharding_profile,
        "microbatches": mb,
        "flops_per_dev": flops, "bytes_per_dev": nbytes,
        "collective_bytes_per_dev": wire,
        "compute_s": compute_t, "memory_s": memory_t,
        "collective_s": coll_t,
        "bottleneck": bottleneck,
        "model_flops_per_dev": mf,
        "useful_flops_frac": mf / max(flops, 1e-9),
        "roofline_fraction": compute_t / max(max(terms.values()), 1e-12),
        "analyse_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(f"[{arch} x {shape_name}] compute={compute_t*1e3:8.2f}ms "
              f"memory={memory_t*1e3:8.2f}ms coll={coll_t*1e3:8.2f}ms "
              f"-> {bottleneck}-bound  useful={rec['useful_flops_frac']:.2f} "
              f"roofline_frac={rec['roofline_fraction']:.2f}")
    return rec


def _cell_cost(cfg, shape, runcfg, mesh):
    """Lower one program for a (possibly depth-reduced) cfg and shape."""
    from repro.launch.dryrun import input_specs as _  # noqa — shared logic
    rules = S.resolve_rules(cfg, runcfg.sharding_profile)
    log = axes_mod.PruneLog()

    def shardings(t):
        return axes_mod.tree_shardings(t, rules, mesh, prune_log=log)

    bspecs = S.batch_specs(cfg, shape)
    if shape.kind != "train":
        bspecs.pop("labels", None)
    batch = abstract_tree(bspecs)
    batch_sh = shardings(bspecs)
    if shape.kind == "train":
        st = S.train_state_specs(cfg, runcfg)
        step, _r = S.make_train_step(cfg, runcfg, mesh)
        return _lower_cost(step, (abstract_tree(st), batch),
                           (shardings(st), batch_sh), (0,), mesh)
    if shape.kind == "prefill":
        ps = S.param_specs(cfg, runcfg)
        step, _r = S.make_prefill_step(cfg, runcfg, mesh)
        return _lower_cost(step, (abstract_tree(ps), batch),
                           (shardings(ps), batch_sh), (), mesh)
    ps = S.param_specs(cfg, runcfg)
    ds = S.decode_state_specs(cfg, shape, runcfg)
    step, _r = S.make_decode_step(cfg, runcfg, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), np.int32)
    tok_sh = axes_mod.tree_shardings(
        {"t": S.batch_specs(cfg, shape)["tokens"]._replace(
            shape=(shape.global_batch, 1))}, rules, mesh)["t"]
    return _lower_cost(step, (abstract_tree(ps), abstract_tree(ds), tok),
                       (shardings(ps), shardings(ds), tok_sh), (1,), mesh)


def _raft_cost(fn, *args):
    """flops / bytes-accessed for one jitted consensus op."""
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def analyse_raft_kernels(verbose=True):
    """Roofline terms for the §8 fan-out and digest-reduction paths.

    Lowers the XLA formulations (the kernels' bit-identical twins, so
    the operand traffic is the same) at the paper cluster's node count
    and the B=32 fleet's digest shapes, and reports bytes, FLOPs,
    arithmetic intensity, and the v5e ridge-point verdict — integer
    select/reduce work this sparse is memory-bound, which is the
    argument for fusing it (one pass, no gather/scatter HLO)."""
    import jax.numpy as jnp
    from repro.configs.bwraft_kv import CONFIG as RAFT_CONFIG
    from repro.core import state as raft_state
    from repro.kernels.group_digest import ref as gd_ref
    from repro.kernels.leader_fanout import ref as lf_ref

    rng = np.random.default_rng(0)
    static = raft_state.build_static(RAFT_CONFIG)
    N, L = static["N"], RAFT_CONFIG.max_log
    mk = lambda lo, hi, sh: jnp.asarray(rng.integers(lo, hi, sh),
                                        jnp.int32)
    fan_args = (mk(0, 6, (N,)), jnp.asarray(rng.random(N) < 0.9),
                mk(-1, 5, (N,)), mk(-1, N, (N,)), mk(0, L + 1, (N,)),
                mk(-1, 40, (N,)), mk(0, L + 1, (N,)), mk(0, L + 1, (N,)),
                mk(0, 4, (N,)), mk(0, L + 1, (N,)),
                jnp.asarray(static["rtt"], jnp.int32), jnp.int32(0),
                jnp.asarray(True), jnp.int32(7), jnp.int32(L),
                jnp.int32(2), jnp.int32(L // 2))
    knobs = dict(msg_budget=static["msg_budget"],
                 max_ship=static["max_ship"],
                 entries_per_msg=static["entries_per_msg"])
    B, G, H = 32, 8, 64
    grp_args = (mk(0, G + 1, (B,)), mk(0, 2**20, (B, 2 * H + 9)),
                jnp.asarray(rng.standard_normal((B, 3)), jnp.float32))

    ridge = HW["peak_flops_bf16"] / HW["hbm_gbps"]   # FLOPs per byte
    records = []
    for name, cost, shape in (
            ("leader_fanout",
             _raft_cost(lambda *a: lf_ref.leader_fanout_ref(*a, **knobs),
                        *fan_args),
             f"N={N} rtt={N}x{N}"),
            ("group_digest",
             _raft_cost(lambda *a: gd_ref.group_reduce_ref(*a, n_groups=G),
                        *grp_args),
             f"B={B} G={G} F={2 * H + 9}+3")):
        ai = cost["flops"] / max(cost["bytes"], 1e-9)
        rec = {"kernel": name, "status": "OK", "shape": shape,
               "flops": cost["flops"], "bytes": cost["bytes"],
               "arith_intensity": ai, "ridge_flops_per_byte": ridge,
               "bound": "memory" if ai < ridge else "compute",
               "memory_s": cost["bytes"] / HW["hbm_gbps"],
               "compute_s": cost["flops"] / HW["peak_flops_bf16"]}
        records.append(rec)
        if verbose:
            print(f"[raft {name:>14}] {shape:<22} "
                  f"flops={cost['flops']:12.0f} bytes={cost['bytes']:10.0f} "
                  f"AI={ai:7.3f} ridge={ridge:.0f} -> {rec['bound']}-bound")
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--profile", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--raft", action="store_true",
                    help="roofline the consensus fan-out and digest-"
                         "reduction paths instead of the model cells")
    args = ap.parse_args(argv)

    if args.raft:
        records = analyse_raft_kernels()
        if args.json:
            with open(args.json, "w") as f:
                json.dump(records, f, indent=1, default=str)
        print(f"{len(records)} raft kernels analysed")
        return 0

    overrides = {}
    if args.profile:
        overrides["sharding_profile"] = args.profile
    if args.microbatches:
        overrides["num_microbatches"] = args.microbatches
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.attn_chunk:
        overrides["attn_chunk_q"] = args.attn_chunk
        overrides["attn_chunk_k"] = args.attn_chunk

    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = sorted(SHAPES_BY_NAME) if (args.all or not args.shape) \
        else (args.shape,)
    records = []
    for a in archs:
        for s in shapes:
            try:
                records.append(analyse_cell(a, s,
                                            runcfg_overrides=overrides))
            except Exception as e:
                traceback.print_exc()
                records.append({"arch": a, "shape": s, "status": "FAIL",
                                "error": f"{type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, default=str)
    n_fail = sum(r["status"] == "FAIL" for r in records)
    print(f"{len(records)} cells, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
