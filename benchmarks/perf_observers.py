#!/usr/bin/env python
"""Digest-tier observer scale-out benchmark (DESIGN.md §13).

Measures and GATES the paper's 50X-node claim — observers are massive,
cheap, and near-stateless, so BW-Raft scales to ~50X the nodes of
original Raft:

  invariance  a run with a digest tier attached (O > 0) must leave every
              dense voter-core leaf — logs, terms, roles, commit/apply
              indices, the rolling applied digest, the KV image, RNG-fed
              kill/price streams — bit-identical to the O = 0 run at the
              same seed.  The tier only ever *adds* digest-shaped state
              and redistributes reads; divergence exits 1.
  curve       per-tick wall cost and read-staleness percentiles vs.
              observer count, N_obs from 0 into the thousands.  Every
              point is an unmanaged single-member fleet whose `run(E)`
              collapses into ONE compiled dispatch (CountingJit-asserted,
              §7.1); per-tick cost must stay SUBLINEAR in N_obs (the
              tier is one fused `(O,)` gather/where pass, not O copies
              of the dense tick).
  sweep       `n_observers` is a sweep axis like phi or write_rate: a
              mixed-width fleet (0 … N_max observers, padded to one
              static shape) must compile ONE program, run as ONE
              dispatch, and stay under the §7.1 digest D2H ceiling.
  staleness   every digest-tier read is served within the configured
              bound: the per-member `obs_stale_p99` read off the device
              staleness histogram must be <= `staleness_bound`.

The headline gate: N_obs >= 50 x the voter count of the paper cluster,
in one compiled dispatch.

Emits ``BENCH_observers.json``; CI runs ``--smoke`` and uploads it
(`.github/workflows/ci.yml`).

  PYTHONPATH=src python benchmarks/perf_observers.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.bwraft_kv import CONFIG
from repro.core import fleet as fleet_mod
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim

# same digest ceiling perf_fleet.py / perf_serving.py enforce (§7.1)
D2H_CEILING_BYTES_PER_MEMBER_EPOCH = 4096
STALENESS_BOUND = 12
AE_INTERVAL = 4

# the dense voter core: every leaf that must stay bit-identical when a
# digest tier rides along (DESIGN.md §13 equivalence invariant).  The
# tier is allowed to move ONLY read serving (read_queue and the counters
# and histograms downstream of it) and cost (digest observers lease spot
# capacity); everything else — consensus, logs, applied state, RNG
# streams — is core.
_NON_CORE = ("read_queue", "reads_served", "read_lat_hist",
             "read_lat_sum", "read_lat_max", "cost_accrued")


def _is_core_leaf(name: str) -> bool:
    return (not name.startswith("dobs_") and not name.startswith("obs_")
            and name not in _NON_CORE)


def voter_core_invariance(epochs: int, n_obs: int) -> dict:
    """O = 0 vs O = `n_obs` at the same seed: every core leaf equal."""
    kw = dict(write_rate=8.0, read_rate=48.0, phi=0.05, seed=7,
              manage_resources=False, prelease=(2, 8))
    base = BWRaftSim(CONFIG, **kw)
    base.run(epochs)
    tier = BWRaftSim(CONFIG, **kw, n_observers=n_obs,
                     staleness_bound=STALENESS_BOUND,
                     ae_interval=AE_INTERVAL)
    reports = tier.run(epochs)
    diverged = [k for k in base.state if _is_core_leaf(k)
                and not np.array_equal(np.asarray(base.state[k]),
                                       np.asarray(tier.state[k]))]
    rep = reports[-1]
    return {"epochs": epochs, "n_observers": n_obs,
            "core_leaves_checked": sum(_is_core_leaf(k)
                                       for k in base.state),
            "diverged_leaves": diverged,
            "core_bit_identical": not diverged,
            "obs_reads_served": rep.obs_reads_served,
            "tier_served_reads": rep.obs_reads_served > 0}


def _point_fleet(n_obs: int, seed: int = 0) -> FleetSim:
    spec = MemberSpec(cfg=CONFIG, mode="bwraft", write_rate=8.0,
                      read_rate=64.0, phi=0.02, seed=seed,
                      manage_resources=False, prelease=(2, 8),
                      n_observers=n_obs,
                      staleness_bound=STALENESS_BOUND,
                      ae_interval=AE_INTERVAL)
    return FleetSim([spec])


def measure_point(n_obs: int, epochs: int) -> dict:
    """One scale-out point: warm-compile, then time `run(epochs)` as one
    dispatch; report per-tick wall cost and the staleness tail."""
    before = fleet_mod.total_compile_count()
    _point_fleet(n_obs).run(epochs)                       # warm compile
    compiles = fleet_mod.total_compile_count() - before
    fleet = _point_fleet(n_obs)
    assert fleet.single_dispatch_eligible
    t0 = time.perf_counter()
    reports = fleet.run(epochs)
    wall_s = time.perf_counter() - t0
    rep = reports[0][-1]
    ticks = epochs * fleet.shapes.T
    return {
        "n_obs": n_obs, "epochs": epochs,
        "wall_s": wall_s,
        "tick_wall_us": wall_s / ticks * 1e6,
        "obs_reads_served": rep.obs_reads_served,
        "obs_rerouted": rep.obs_rerouted,
        "obs_stale_p95": rep.obs_stale_p95,
        "obs_stale_p99": rep.obs_stale_p99,
        "n_obs_digest_alive": rep.n_obs_digest,
        "reads_served": rep.reads_served,
        "compile_count": compiles,
        "dispatches_per_run": 1,
        "d2h_bytes_per_member_epoch": fleet.d2h_bytes / epochs,
    }


def measure_mixed_sweep(widths, epochs: int) -> dict:
    """`n_observers` as a sweep axis: one fleet, one program, one
    dispatch for members of every width (padded to max(widths))."""
    def build():
        return FleetSim([
            MemberSpec(cfg=CONFIG, mode="bwraft", write_rate=8.0,
                       read_rate=64.0, phi=0.02, seed=3 + i,
                       manage_resources=False, prelease=(2, 8),
                       n_observers=o, staleness_bound=STALENESS_BOUND,
                       ae_interval=AE_INTERVAL)
            for i, o in enumerate(widths)])
    before = fleet_mod.total_compile_count()
    build().run(epochs)                                   # warm compile
    compiles = fleet_mod.total_compile_count() - before
    fleet = build()
    assert fleet.single_dispatch_eligible
    t0 = time.perf_counter()
    reports = fleet.run(epochs)
    wall_s = time.perf_counter() - t0
    rows = [{"n_obs": o,
             "obs_reads_served": m[-1].obs_reads_served,
             "obs_stale_p99": m[-1].obs_stale_p99,
             "n_obs_digest_alive": m[-1].n_obs_digest}
            for o, m in zip(widths, reports)]
    return {
        "widths": list(widths), "epochs": epochs,
        "wall_s": wall_s,
        "compile_count": compiles,
        "dispatches_per_run": 1,
        "d2h_bytes_per_member_epoch":
            fleet.d2h_bytes / epochs / len(widths),
        "members": rows,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI")
    ap.add_argument("--out", default="BENCH_observers.json")
    args = ap.parse_args(argv)

    voters = sum(1 + s.followers for s in CONFIG.sites)
    target = 50 * voters
    if args.smoke:
        epochs, widths = 2, [0, 56, target]
    else:
        epochs, widths = 3, [0, 56, target, 896, 1792, 3584]
    n_max = max(widths)
    print(f"=== digest-tier scale-out: V={voters} voters, "
          f"N_obs up to {n_max} ({n_max / voters:.0f}x), "
          f"{epochs} epochs ===")

    inv = voter_core_invariance(epochs, target)
    print(f"voter-core invariance (O=0 vs O={target}): "
          f"bit_identical={inv['core_bit_identical']} "
          f"({inv['core_leaves_checked']} leaves)"
          + (f"  DIVERGED: {inv['diverged_leaves']}"
             if inv["diverged_leaves"] else ""))

    curve = [measure_point(o, epochs) for o in widths]
    for row in curve:
        print(f"  N_obs {row['n_obs']:>5d}: "
              f"{row['tick_wall_us']:>8.1f} us/tick  "
              f"obs reads {row['obs_reads_served']:>6d}  "
              f"stale p99 {row['obs_stale_p99']:>5.1f}  "
              f"({row['compile_count']} compile, 1 dispatch)")

    lo = next(r for r in curve if r["n_obs"] > 0)
    hi = curve[-1]
    n_ratio = hi["n_obs"] / lo["n_obs"]
    wall_ratio = hi["tick_wall_us"] / lo["tick_wall_us"]
    print(f"sublinearity: N_obs x{n_ratio:.1f} -> "
          f"tick cost x{wall_ratio:.2f}")

    sweep = measure_mixed_sweep(widths, epochs)
    print(f"mixed-width sweep ({len(widths)} members): "
          f"{sweep['compile_count']} compile(s), 1 dispatch, "
          f"{sweep['d2h_bytes_per_member_epoch']:.0f} D2H B/member/epoch")

    result = {
        "config": {"cluster": CONFIG.name, "voters": voters,
                   "T": CONFIG.period_ticks, "epochs": epochs,
                   "staleness_bound": STALENESS_BOUND,
                   "ae_interval": AE_INTERVAL,
                   "target_50x": target, "n_obs_max": n_max,
                   "smoke": args.smoke},
        "invariance": inv,
        "curve": curve,
        "sublinearity": {"n_ratio": n_ratio, "wall_ratio": wall_ratio},
        "mixed_sweep": sweep,
        "ceilings": {
            "d2h_bytes_per_member_epoch":
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH,
            "compile_count_per_point": 1,
            "staleness_p99": STALENESS_BOUND,
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    failures = []
    if not inv["core_bit_identical"]:
        failures.append(f"digest tier perturbed the dense voter core: "
                        f"{inv['diverged_leaves']} (§13 equivalence)")
    if not inv["tier_served_reads"]:
        failures.append("digest tier served zero reads in the "
                        "invariance run")
    if n_max < target:
        failures.append(f"N_obs max {n_max} below the 50X target "
                        f"{target}")
    if wall_ratio >= n_ratio:
        failures.append(f"per-tick cost superlinear in N_obs: "
                        f"x{wall_ratio:.2f} wall for x{n_ratio:.1f} "
                        f"observers")
    for row in curve:
        if row["compile_count"] != 1:
            failures.append(f"N_obs={row['n_obs']} compiled "
                            f"{row['compile_count']} programs "
                            f"(must be exactly 1)")
        if (row["d2h_bytes_per_member_epoch"] >
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH):
            failures.append(f"N_obs={row['n_obs']}: "
                            f"{row['d2h_bytes_per_member_epoch']:.0f} "
                            f"D2H bytes/member/epoch over ceiling")
        if row["n_obs"] > 0 and not (
                row["obs_stale_p99"] <= STALENESS_BOUND):
            failures.append(f"N_obs={row['n_obs']}: staleness p99 "
                            f"{row['obs_stale_p99']} over bound "
                            f"{STALENESS_BOUND}")
    if sweep["compile_count"] != 1:
        failures.append(f"mixed-width sweep compiled "
                        f"{sweep['compile_count']} programs "
                        f"(must be exactly 1)")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
