"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Consensus benchmarks run inline
(1 CPU device) and, by default, drive their sweep grids through the
batched fleet simulator (`core/fleet.FleetSim`): every (system, load)
point in a figure is one member of a single vmapped program, so a grid
costs one jit compile instead of one per point (DESIGN.md §7).  The
roofline/dry-run benchmarks need 512 host devices and run as subprocesses
(their results are also cached under results/).

  PYTHONPATH=src python -m benchmarks.run [--full] [--sequential]
                                          [--with-roofline] [--only NAME]

--sequential falls back to the pre-fleet one-BWRaftSim-per-point path
(same seeds; identical results at equal static shapes) — useful for
A/B-ing the batched path or isolating a fleet regression.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time

MODULES = [
    "fig6_snapshots", "fig7_scaleout", "fig8_overall", "fig9_cdf",
    "fig10_roles", "fig11_ycsb", "fig12_alpha", "fig13_failure",
    "fig14_sites",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow)")
    ap.add_argument("--sequential", action="store_true",
                    help="one BWRaftSim per grid point instead of one "
                         "batched FleetSim per figure")
    ap.add_argument("--with-roofline", action="store_true",
                    help="also run one roofline cell as a subprocess")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import common
    common.USE_FLEET = not args.sequential

    rows = []
    mods = [m for m in MODULES if not args.only or args.only in m]
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            out = mod.run(quick=not args.full)
        except Exception as e:  # pragma: no cover
            print(f"# {name} FAILED: {e}", file=sys.stderr)
            raise
        dt = (time.perf_counter() - t0) * 1e6
        rows.extend(out)
        rows.append((f"{name}.wall", dt / max(len(out), 1), "us_per_row"))

    if common.USE_FLEET:
        from repro.core import fleet
        rows.append(("fleet.compiled_epoch_programs",
                     float(fleet.total_compile_count()), "count"))

    if args.with_roofline:
        cmd = [sys.executable, "-m", "benchmarks.roofline",
               "--arch", "llama3.2-1b", "--shape", "decode_32k"]
        t0 = time.perf_counter()
        subprocess.run(cmd, check=True)
        rows.append(("roofline.llama_decode.wall",
                     (time.perf_counter() - t0) * 1e6, "us"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
