"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

from repro.configs.bwraft_kv import CONFIG as PAPER_CLUSTER
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.runtime import BWRaftSim
from repro.core.multiraft import MultiRaftSim

Row = Tuple[str, float, str]


def scaled_cluster(f_per_site: int) -> ClusterConfig:
    sites = tuple(SiteConfig(n, followers=f_per_site, rtt_intra=1,
                             rtt_inter=r, on_demand_price=0.0416,
                             spot_price_mean=0.0125)
                  for n, r in [("eu-frankfurt", 8), ("asia-singapore", 10),
                               ("us-east", 6), ("us-west", 7)])
    return ClusterConfig(name=f"scale{f_per_site}", sites=sites)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def tick_ms(ticks: float) -> float:
    """Convert sim ticks to milliseconds (1 tick = 10 ms, DESIGN.md §3)."""
    return ticks * 10.0


def run_systems(cfg, *, write_rate, read_rate, epochs, seed=0, phi=0.0,
                shards=2):
    """(bwraft, raft, multiraft) steady-state reports."""
    bw = BWRaftSim(cfg, mode="bwraft", write_rate=write_rate,
                   read_rate=read_rate, phi=phi, seed=seed)
    og = BWRaftSim(cfg, mode="raft", write_rate=write_rate,
                   read_rate=read_rate, phi=phi, seed=seed)
    mr = MultiRaftSim(cfg, shards=shards, write_rate=write_rate,
                      read_rate=read_rate, seed=seed)
    return bw.run(epochs)[-1], og.run(epochs)[-1], mr.run(epochs)[-1]
