"""Shared benchmark helpers.

By default the consensus benchmarks drive their grids through
`core/fleet.FleetSim`: every (system, load) point in a figure becomes one
member of a single batched program, so a whole grid costs one jit compile
and one vmapped scan per epoch (DESIGN.md §7).  Epochs run on the
device-resident digest pipeline (DESIGN.md §7.1) — only a few-KB digest
per member crosses to host per epoch, and unmanaged fixed-role grids
(fig12/fig13) collapse a whole run into one dispatch via the multi-epoch
scan.  `benchmarks.run --sequential` flips `USE_FLEET` off to fall back
to one-`BWRaftSim`-per-point (useful for A/B-ing the two paths — same
seeds, same results at equal shapes).  `benchmarks/perf_fleet.py`
measures the digest pipeline against the host-marshalling reference and
emits `BENCH_fleet.json`.
"""
from __future__ import annotations

import json
import math
import time
from typing import Callable, List, Tuple

from repro.configs.bwraft_kv import CONFIG as PAPER_CLUSTER
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.runtime import BWRaftSim
from repro.core import multiraft
from repro.core.fleet import FleetSim, MemberSpec

Row = Tuple[str, float, str]

# toggled by `python -m benchmarks.run --sequential`
USE_FLEET = True


def scaled_cluster(f_per_site: int) -> ClusterConfig:
    sites = tuple(SiteConfig(n, followers=f_per_site, rtt_intra=1,
                             rtt_inter=r, on_demand_price=0.0416,
                             spot_price_mean=0.0125)
                  for n, r in [("eu-frankfurt", 8), ("asia-singapore", 10),
                               ("us-east", 6), ("us-west", 7)])
    return ClusterConfig(name=f"scale{f_per_site}", sites=sites)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def validate_bench_schema(doc, *, name: str = "BENCH") -> List[str]:
    """Shared schema check for every committed ``BENCH_*.json``
    (DESIGN.md §14): returns a list of problems, empty when valid.

    The contract all seven benchmark emitters share:
      - top level is a dict with a dict-valued ``config`` block (the
        reproduction recipe — cluster name, epochs, smoke flag, ...);
      - when a ``ceilings`` block is present it is a non-empty dict of
        numeric gates (the values the emitter exits 1 against);
      - every ``interpreted`` flag (the Pallas-interpret escape hatch,
        DESIGN.md §8) is a bool — a truthy string would silently pass
        CI on an interpreter fallback;
      - no float anywhere in the tree is infinite.  NaN is allowed: it
        is the repo-wide in-band "no samples" value (the NaN policy of
        `core/multiraft.py` — an empty latency histogram reports NaN,
        not 0), but an infinity is always an emitter bug (an unguarded
        division), never a domain value.
    """
    problems: List[str] = []
    if not isinstance(doc, dict):
        return [f"{name}: top level must be a dict, got {type(doc).__name__}"]
    if not isinstance(doc.get("config"), dict):
        problems.append(f"{name}: missing dict-valued 'config' block")
    if "ceilings" in doc:
        ceil = doc["ceilings"]
        if not isinstance(ceil, dict) or not ceil:
            problems.append(f"{name}: 'ceilings' must be a non-empty dict")
        else:
            for k, v in ceil.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    problems.append(
                        f"{name}: ceiling {k!r} must be numeric, got {v!r}")

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "interpreted" and not isinstance(v, bool):
                    problems.append(
                        f"{name}: {path}.{k} must be a bool, got {v!r}")
                walk(v, f"{path}.{k}")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}[{i}]")
        elif isinstance(node, float) and math.isinf(node):
            problems.append(f"{name}: infinite float at {path}")

    walk(doc, name)
    return problems


def validate_bench_file(path) -> List[str]:
    """`validate_bench_schema` over a committed BENCH file; unparseable
    JSON is itself a schema problem."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as exc:
        return [f"{path}: unreadable BENCH json ({exc})"]
    return validate_bench_schema(doc, name=str(path))


def tick_ms(ticks: float) -> float:
    """Convert sim ticks to milliseconds (1 tick = 10 ms, DESIGN.md §2)."""
    return ticks * 10.0


def system_specs(cfg, *, write_rate, read_rate, seed=0, phi=0.0,
                 shards=2, group_id=0, market="process",
                 trace=None, arrivals=None, keypop=None,
                 warning_ticks=0, bid_policy=None, bid_on_trace=False,
                 n_observers=0, staleness_bound=16, ae_interval=4
                 ) -> List[MemberSpec]:
    """Fleet members for one (bwraft, raft, multiraft-shards) comparison
    point: 2 + `shards` members, batched into whatever FleetSim they join.
    The shard members carry the group identity `group_id` (DESIGN.md §9),
    so the fleet runs the 2PC coupling in-graph and reports the shards as
    one grouped Multi-Raft system (`FleetSim.group_reports[group_id]`);
    comparison points sharing a fleet must use distinct group ids.
    `market`/`trace` select the BW-Raft member's spot market
    (DESIGN.md §10) — the on-demand baselines lease no spot nodes, so
    the market only moves the spot consumer.  `arrivals`/`keypop`
    (DESIGN.md §11) put every system under the SAME open-loop plan: the
    whole-system members replay it as is, the shards at the
    `shard_workload`-divided intensity.  `warning_ticks`/`bid_policy`/
    `bid_on_trace` (DESIGN.md §12) harden the BW-Raft member's spot
    consumption — advance-warned degradation and per-epoch hazard-aware
    bids; the on-demand baselines have no spot exposure to harden.
    `n_observers`/`staleness_bound`/`ae_interval` attach the digest-tier
    observer rack (DESIGN.md §13) to the BW-Raft member only — the
    scale-out claim under comparison is BW-Raft's; the dense baselines
    stay dense."""
    return ([MemberSpec(cfg=cfg, mode="bwraft", write_rate=write_rate,
                        read_rate=read_rate, phi=phi, seed=seed,
                        market=market, trace=trace,
                        arrivals=arrivals, keypop=keypop,
                        warning_ticks=warning_ticks, bid_policy=bid_policy,
                        bid_on_trace=bid_on_trace,
                        n_observers=n_observers,
                        staleness_bound=staleness_bound,
                        ae_interval=ae_interval),
             MemberSpec(cfg=cfg, mode="raft", write_rate=write_rate,
                        read_rate=read_rate, phi=phi, seed=seed,
                        arrivals=arrivals, keypop=keypop)]
            + multiraft.shard_specs(cfg, shards=shards,
                                    write_rate=write_rate,
                                    read_rate=read_rate, seed=seed,
                                    group_id=group_id,
                                    arrivals=arrivals, keypop=keypop))


def collect_systems(fleet, lo, *, group_id):
    """Inverse of `system_specs`: the comparison point whose members
    start at slot `lo` becomes (bwraft, raft, grouped-multiraft) final
    reports — the Multi-Raft one from the in-graph group digest."""
    bw = fleet.members[lo].reports[-1]
    og = fleet.members[lo + 1].reports[-1]
    mr = fleet.group_reports[group_id][-1]
    return bw, og, mr


def run_systems(cfg, *, write_rate, read_rate, epochs, seed=0, phi=0.0,
                shards=2, market="process", trace=None,
                warning_ticks=0, bid_policy=None, bid_on_trace=False,
                n_observers=0, staleness_bound=16, ae_interval=4):
    """(bwraft, raft, multiraft) steady-state reports.

    Fleet path: all three systems (2 + `shards` members) advance in one
    batched program, the Multi-Raft shards as one device-coupled group
    (DESIGN.md §9).  Sequential path: the pre-fleet per-system loop with
    the frozen sequential Multi-Raft reference.  `market="trace"` runs
    the BW-Raft member on a replayed `market.MarketTrace` instead of the
    synthetic walk (DESIGN.md §10) — the headline comparison on a real
    market (`examples/spot_market_scaleout.py --trace`)."""
    if not USE_FLEET:
        bw = BWRaftSim(cfg, mode="bwraft", write_rate=write_rate,
                       read_rate=read_rate, phi=phi, seed=seed,
                       market=market, trace=trace,
                       warning_ticks=warning_ticks, bid_policy=bid_policy,
                       bid_on_trace=bid_on_trace,
                       n_observers=n_observers,
                       staleness_bound=staleness_bound,
                       ae_interval=ae_interval)
        og = BWRaftSim(cfg, mode="raft", write_rate=write_rate,
                       read_rate=read_rate, phi=phi, seed=seed)
        mr = multiraft.MultiRaftSim(cfg, shards=shards,
                                    write_rate=write_rate,
                                    read_rate=read_rate, seed=seed,
                                    engine="sequential")
        return bw.run(epochs)[-1], og.run(epochs)[-1], mr.run(epochs)[-1]

    specs = system_specs(cfg, write_rate=write_rate, read_rate=read_rate,
                         seed=seed, phi=phi, shards=shards, group_id=0,
                         market=market, trace=trace,
                         warning_ticks=warning_ticks, bid_policy=bid_policy,
                         bid_on_trace=bid_on_trace,
                         n_observers=n_observers,
                         staleness_bound=staleness_bound,
                         ae_interval=ae_interval)
    fleet = FleetSim(specs)
    fleet.run(epochs)
    return collect_systems(fleet, 0, group_id=0)
