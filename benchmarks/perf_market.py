#!/usr/bin/env python
"""Spot-market subsystem benchmark: trace replay vs the synthetic walk.

Measures and GATES the §10 market contract (DESIGN.md §10):

  replay      the synthetic walk exported as a trace
              (`market/synthetic.export_walk_trace`) and replayed through
              the trace path must reproduce the process path
              **bit-identically** — states and reports — with the
              control plane managing.  Divergence exits 1 (the market
              analogue of `perf_tick.py`'s equivalence gate).
  sweep       a B-member fleet with a DIFFERENT (S, T) trace per member
              must compile ONE program and run `run(E)` as ONE dispatch
              (CountingJit-asserted via `fleet.total_compile_count`),
              with per-member-epoch device→host bytes under the same
              digest ceiling `perf_fleet.py` enforces; trace-replay tick
              overhead vs the synthetic walk is recorded (and gated at
              OVERHEAD_CEILING on the full run).
  comparison  the paper's Fig. 8 story on a real market: BW-Raft vs
              original Raft vs Multi-Raft cost/goodput under a committed
              sample trace, next to the synthetic-walk numbers.
  calibration `market.calibrate` fit quality: RevocationPredictor
              alpha/MAE against the Google-eviction sample,
              moment-matched walk parameters against the AWS sample.

Emits ``BENCH_market.json``; CI runs ``--smoke`` and uploads it
(`.github/workflows/ci.yml`).

  PYTHONPATH=src python benchmarks/perf_market.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.bwraft_kv import CONFIG
from repro.core import fleet as fleet_mod
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim
from repro.market import (calibrate_predictor, export_walk_trace, fit_walk,
                          load)
from benchmarks.common import run_systems

# trace replay swaps one (S,) RNG-normal draw for one (S,) dynamic-slice
# gather per tick — it must stay within this factor of the walk
OVERHEAD_CEILING = 2.0
# same digest ceiling perf_fleet.py enforces (DESIGN.md §7.1)
D2H_CEILING_BYTES_PER_MEMBER_EPOCH = 4096

_REPORT_FIELDS = ("reads_arrived", "writes_arrived", "reads_served",
                  "writes_committed", "killed", "n_secretaries",
                  "n_observers", "leader_changes", "no_leader_ticks",
                  "cost")


def replay_gate(epochs: int) -> dict:
    """§10 replay invariant on the paper cluster, manager ON: process
    run vs exported-walk replay must match bit for bit."""
    kw = dict(write_rate=8.0, read_rate=32.0, phi=0.02, seed=0)
    process = BWRaftSim(CONFIG, **kw)
    process_reports = process.run(epochs)
    trace = export_walk_trace(CONFIG, seed=0, epochs=epochs)
    replay = BWRaftSim(CONFIG, **kw, market="trace", trace=trace)
    replay_reports = replay.run(epochs)

    state_ok = all(np.array_equal(np.asarray(process.state[k]),
                                  np.asarray(replay.state[k]))
                   for k in process.state)
    reports_ok = all(
        getattr(a, f) == getattr(b, f)
        for a, b in zip(process_reports, replay_reports)
        for f in _REPORT_FIELDS)
    return {"epochs": epochs, "cluster": CONFIG.name,
            "managed": True, "phi": 0.02,
            "bit_identical": bool(state_ok and reports_ok),
            "state_identical": bool(state_ok),
            "reports_identical": bool(reports_ok)}


def _sweep_fleet(b: int, epochs: int, market: str) -> FleetSim:
    specs = []
    for i in range(b):
        trace = (export_walk_trace(CONFIG, seed=i, epochs=epochs)
                 if market == "trace" else None)
        specs.append(MemberSpec(
            cfg=CONFIG, write_rate=4.0 + 2.0 * (i % 4), read_rate=32.0,
            seed=i, manage_resources=False, prelease=(2, 6),
            market=market, trace=trace))
    return FleetSim(specs)


def measure_sweep(b: int, epochs: int, market: str) -> dict:
    """Warm-compile then time a B-member single-dispatch run; report
    wall time, ticks/sec, D2H bytes, and the compile delta this market
    mode cost (must be exactly 1 program for the whole run)."""
    before = fleet_mod.total_compile_count()
    _sweep_fleet(b, epochs, market).run(epochs)              # warm compile
    compiles = fleet_mod.total_compile_count() - before
    fleet = _sweep_fleet(b, epochs, market)
    assert fleet.single_dispatch_eligible
    t0 = time.perf_counter()
    fleet.run(epochs)
    wall_s = time.perf_counter() - t0
    return {
        "market": market, "B": b, "epochs": epochs,
        "wall_s": wall_s,
        "epoch_wall_s": wall_s / epochs,
        "ticks_per_sec": b * epochs * fleet.shapes.T / wall_s,
        "d2h_bytes_per_member_epoch": fleet.d2h_bytes / epochs / b,
        "dispatches_per_run": 1,
        "compile_count": compiles,
    }


def _report_row(rep) -> dict:
    return {"goodput": rep.goodput, "cost": rep.cost,
            "cost_per_kop": 1000 * rep.cost / max(rep.goodput, 1),
            "write_lat_p95": rep.write_lat_p95}


def market_comparison(epochs: int, trace_name: str) -> dict:
    """Fig. 8 on a real market: the three systems under the committed
    sample trace vs under the synthetic walk (same seeds/loads)."""
    kw = dict(write_rate=16.0, read_rate=48.0, epochs=epochs, shards=2)
    trace = load(trace_name, ticks=epochs * CONFIG.period_ticks)
    out = {}
    for label, mkw in (("synthetic", dict(market="process")),
                       (trace_name, dict(market="trace", trace=trace))):
        bw, og, mr = run_systems(CONFIG, **kw, **mkw)
        out[label] = {"bwraft": _report_row(bw), "original": _report_row(og),
                      "multiraft": _report_row(mr),
                      "bwraft_cost_saving_vs_multiraft":
                          1.0 - bw.cost / max(mr.cost, 1e-9)}
    return out


def calibration_block() -> dict:
    predictor, rep = calibrate_predictor(
        load("google-evict", ticks=1200), CONFIG.period_ticks)
    walk = fit_walk(load("aws-us-east", ticks=1200))
    return {
        "predictor": {"trace": rep.trace, "alpha": rep.alpha,
                      "mae": rep.mae, "one_step_mse": rep.one_step_mse,
                      "empirical": rep.empirical.tolist(),
                      "fitted": rep.fitted.tolist()},
        "walk": {"trace": walk.trace, "vol": walk.vol,
                 "vol_per_site": walk.vol_per_site.tolist(),
                 "mean": walk.mean.tolist(),
                 "reversion_r2": walk.reversion_r2},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (no overhead-ceiling gate)")
    ap.add_argument("--out", default="BENCH_market.json")
    args = ap.parse_args(argv)

    b, epochs = (4, 2) if args.smoke else (16, 5)
    print(f"=== spot-market subsystem: B={b}, {epochs} epochs ===")

    replay = replay_gate(epochs)
    print(f"replay invariant (managed, phi=0.02): "
          f"bit_identical={replay['bit_identical']}")

    process = measure_sweep(b, epochs, "process")
    trace = measure_sweep(b, epochs, "trace")
    overhead = trace["epoch_wall_s"] / process["epoch_wall_s"]
    for r in (process, trace):
        print(f"{r['market']:>9}: {r['epoch_wall_s']*1e3:8.1f} ms/epoch"
              f"  {r['ticks_per_sec']:>10.0f} ticks/s"
              f"  {r['compile_count']} compile(s), "
              f"{r['dispatches_per_run']} dispatch/run")
    print(f"trace-replay tick overhead vs synthetic walk: {overhead:.2f}X")

    comparison = market_comparison(epochs, "aws-us-east")
    for label, row in comparison.items():
        print(f"{label:>12}: bwraft ${row['bwraft']['cost']:.4f} vs "
              f"multiraft ${row['multiraft']['cost']:.4f} "
              f"({100*row['bwraft_cost_saving_vs_multiraft']:.1f}% saving)")

    calibration = calibration_block()
    print(f"calibration: predictor alpha="
          f"{calibration['predictor']['alpha']} "
          f"mae={calibration['predictor']['mae']:.4f}; "
          f"walk vol fit {calibration['walk']['vol']:.3f}")

    result = {
        "config": {"B": b, "epochs": epochs, "T": CONFIG.period_ticks,
                   "cluster": CONFIG.name, "smoke": args.smoke},
        "replay": replay,
        "sweep": {"process": process, "trace": trace,
                  "trace_overhead_vs_process": overhead},
        "comparison": comparison,
        "calibration": calibration,
        "ceilings": {
            "trace_overhead_vs_process": OVERHEAD_CEILING,
            "d2h_bytes_per_member_epoch":
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH,
            "compile_count_per_sweep": 1,
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    failures = []
    if not replay["bit_identical"]:
        failures.append("trace replay diverged from the synthetic walk "
                        "(§10 replay invariant)")
    for r in (process, trace):
        if r["compile_count"] != 1:
            failures.append(
                f"{r['market']} sweep compiled {r['compile_count']} "
                f"programs (must be exactly 1)")
        if (r["d2h_bytes_per_member_epoch"] >
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH):
            failures.append(
                f"{r['market']}: {r['d2h_bytes_per_member_epoch']:.0f} "
                f"D2H bytes/member/epoch exceeds ceiling "
                f"{D2H_CEILING_BYTES_PER_MEMBER_EPOCH}")
    if not args.smoke and overhead > OVERHEAD_CEILING:
        failures.append(f"trace-replay overhead {overhead:.2f}X exceeds "
                        f"ceiling {OVERHEAD_CEILING}X")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
