#!/usr/bin/env python
"""Flight-recorder benchmark: tracing overhead, drain traffic, and the
chaos-drill Perfetto artifact (DESIGN.md §14).

Measures and GATES the §14 observability contract:

  overhead    traced vs untraced epochs interleaved on ONE compiled
              program (trace_on is cfg_c data — flipping it mid-run is
              CountingJit-asserted to never recompile): the median
              traced epoch must cost <= 5% more wall time at the
              default all-classes mask.
  drain       the per-epoch ring drain is one D2H fetch of
              CAP*LANES*4 + (NCLASS+1)*4 bytes; at the default capacity
              it must stay under the same 4096 B/member/epoch digest
              ceiling perf_fleet.py enforces (§7.1) — tracing must not
              break the O(digest) transfer story.
  drill       a deterministic leader-kill chaos drill replayed with the
              recorder armed: the trace-replayed leader timeline must
              match the harness's per-tick alive-leader probe bit for
              bit (the leader track's GAPS are the measured leaderless
              spans), zero events dropped at the drill capacity, and
              the Perfetto artifact must be well-formed trace-event
              JSON.  The artifact is written next to the BENCH file
              and uploaded by CI.

Emits ``BENCH_trace.json`` (schema-checked by
`common.validate_bench_schema`); CI runs ``--smoke`` and uploads it
plus the drill artifact (`.github/workflows/ci.yml`).

  PYTHONPATH=src python benchmarks/perf_trace.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import statistics
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import validate_bench_schema
from repro.configs.bwraft_kv import CONFIG
from repro.core.runtime import BWRaftSim
from repro.market import kill_nodes, run_chaos
from repro.trace import ring as trace_ring

# same digest ceiling perf_fleet.py / perf_market.py enforce (§7.1)
D2H_CEILING_BYTES_PER_MEMBER_EPOCH = 4096
# the §14 overhead gate: tracing at the default mask must stay within
# 5% of the untraced tick cost (the gated-scatter emit is O(N) work
# next to the tick's O(N·L) replication ops)
OVERHEAD_CEILING_FRAC = 0.05
DRILL_TICKS = 160
DRILL_CAPACITY = 4096


def overhead_block(epochs: int, reps: int) -> dict:
    """Interleaved traced/untraced reps on one compiled epoch program.

    One sim, one compile; `set_trace` flips cfg_c between reps (the
    zero-recompile contract, asserted via the CountingJit counter), and
    the off/on reps alternate so drift (clock scaling, allocator state)
    hits both arms equally.  The gate compares medians."""
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=32.0, phi=0.02,
                    seed=0, manage_resources=False, prelease=(2, 6))
    # warm both arms on the same program
    sim.set_trace(on=False)
    sim.run(1)
    sim.set_trace(on=True)
    sim.run(1)
    compiles0 = sim._epoch_fn.cache_size()

    off_s, on_s = [], []
    for _ in range(reps):
        for traced, bucket in ((False, off_s), (True, on_s)):
            sim.set_trace(on=traced)
            t0 = time.perf_counter()
            sim.run(epochs)
            np.asarray(sim.state["tick"])        # sync
            bucket.append(time.perf_counter() - t0)
    recompiles = sim._epoch_fn.cache_size() - compiles0

    off_med, on_med = statistics.median(off_s), statistics.median(on_s)
    ticks = epochs * CONFIG.period_ticks
    return {
        "epochs_per_rep": epochs, "reps": reps,
        "off_median_s": off_med, "on_median_s": on_med,
        "off_tick_us": off_med / ticks * 1e6,
        "on_tick_us": on_med / ticks * 1e6,
        "overhead_frac": on_med / off_med - 1.0,
        "recompiles_on_toggle": recompiles,
        "events_decoded": len(sim.trace_events),
        "events_dropped": sim.events_dropped,
    }


def drain_block() -> dict:
    """Exact per-drain D2H bytes at the default ring capacity: the
    three trace leaves (`trace_ev`, `trace_pos`, `trace_emit`) by
    shape/dtype — the same accounting `state.pytree_nbytes` uses for
    the digest ceiling."""
    cap = trace_ring.DEFAULT_CAPACITY
    leaves = trace_ring.trace_leaves(cap)
    drain = sum(int(np.prod(leaves[k].shape)) * 4
                for k in ("trace_ev", "trace_pos", "trace_emit"))
    return {
        "capacity": cap, "lanes": trace_ring.LANES,
        "drain_bytes_per_member_epoch": drain,
        "metrics_registry_bytes": int(leaves["metrics_ctr"].size) * 4,
    }


def drill_block(artifact: str) -> dict:
    """Leader-kill drill with the recorder armed: safety audit + the
    trace/probe leader-timeline equivalence + the Perfetto artifact."""
    N = CONFIG.max_nodes
    faults = kill_nodes([0], 20, n_nodes=N, ticks=DRILL_TICKS,
                        name="leader-kill-traced")
    rep = run_chaos(CONFIG, faults, ticks=DRILL_TICKS, seed=0,
                    spot_bid=10.0, check=False, trace_on=True,
                    trace_capacity=DRILL_CAPACITY, trace_out=artifact)
    with open(artifact) as f:
        doc = json.load(f)
    events_ok = (isinstance(doc.get("traceEvents"), list)
                 and len(doc["traceEvents"]) > 0
                 and all({"ph", "pid", "name"} <= set(e)
                         for e in doc["traceEvents"]))
    leader_spans = [e for e in doc["traceEvents"]
                    if e.get("ph") == "X" and e.get("tid") == 9_999]
    return {
        "ticks": DRILL_TICKS, "capacity": DRILL_CAPACITY,
        "first_kill_tick": rep.first_kill_tick,
        "killed": rep.killed_total,
        "max_leaderless_span": rep.max_leaderless_span,
        "leader_uptime": rep.leader_uptime,
        "safety_ok": rep.safety_error is None,
        "events_decoded": len(rep.events),
        "events_dropped": rep.events_dropped,
        "trace_leader_match": rep.trace_leader_match,
        "perfetto_valid": bool(events_ok),
        "perfetto_events": len(doc.get("traceEvents", ())),
        "perfetto_leader_spans": len(leader_spans),
        "artifact": str(artifact),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer overhead reps for CI (gates still apply)")
    ap.add_argument("--out", default="BENCH_trace.json")
    args = ap.parse_args(argv)

    epochs = 2 if args.smoke else 4
    reps = 3 if args.smoke else 7
    artifact = str(pathlib.Path(args.out).with_name("trace_failover.json"))
    print("=== flight recorder (DESIGN.md §14) ===")

    overhead = overhead_block(epochs, reps)
    print(f"overhead: off={overhead['off_tick_us']:.1f}us/tick "
          f"on={overhead['on_tick_us']:.1f}us/tick "
          f"(+{overhead['overhead_frac'] * 100:.2f}%), "
          f"{overhead['recompiles_on_toggle']} recompile(s) on toggle, "
          f"{overhead['events_decoded']} events decoded")

    drain = drain_block()
    print(f"drain: CAP={drain['capacity']} -> "
          f"{drain['drain_bytes_per_member_epoch']} B/member/epoch "
          f"(ceiling {D2H_CEILING_BYTES_PER_MEMBER_EPOCH})")

    drill = drill_block(artifact)
    print(f"drill: killed={drill['killed']} "
          f"max_leaderless={drill['max_leaderless_span']} "
          f"leader_match={drill['trace_leader_match']} "
          f"events={drill['events_decoded']} "
          f"perfetto_valid={drill['perfetto_valid']} -> {artifact}")

    result = {
        "config": {"cluster": CONFIG.name, "epochs_per_rep": epochs,
                   "reps": reps, "drill_ticks": DRILL_TICKS,
                   "drill_capacity": DRILL_CAPACITY,
                   "smoke": args.smoke},
        "overhead": overhead,
        "drain": drain,
        "drill": drill,
        "ceilings": {
            "tick_overhead_frac": OVERHEAD_CEILING_FRAC,
            "drain_d2h_bytes_per_member_epoch":
                D2H_CEILING_BYTES_PER_MEMBER_EPOCH,
            "recompiles_on_toggle": 0,
            "events_dropped_total": 0,
        },
    }
    schema_problems = validate_bench_schema(result, name=args.out)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}")

    failures = list(schema_problems)
    if overhead["overhead_frac"] > OVERHEAD_CEILING_FRAC:
        failures.append(
            f"tracing overhead {overhead['overhead_frac'] * 100:.2f}% "
            f"exceeds the {OVERHEAD_CEILING_FRAC * 100:.0f}% ceiling")
    if overhead["recompiles_on_toggle"] != 0:
        failures.append(
            f"trace toggles recompiled {overhead['recompiles_on_toggle']} "
            f"program(s) (trace_on/trace_mask must be cfg_c data)")
    if (drain["drain_bytes_per_member_epoch"] >
            D2H_CEILING_BYTES_PER_MEMBER_EPOCH):
        failures.append(
            f"ring drain {drain['drain_bytes_per_member_epoch']} B exceeds "
            f"the {D2H_CEILING_BYTES_PER_MEMBER_EPOCH} B digest ceiling")
    if not drill["safety_ok"]:
        failures.append("traced chaos drill violated a safety property")
    if drill["trace_leader_match"] is not True:
        failures.append("trace-replayed leader timeline diverged from the "
                        "chaos harness's per-tick leader probe")
    if not drill["perfetto_valid"]:
        failures.append("Perfetto artifact is not well-formed trace-event "
                        "JSON")
    dropped = dict(overhead["events_dropped"])
    for k, v in drill["events_dropped"].items():
        dropped[k] = dropped.get(k, 0) + v
    if any(dropped.values()):
        failures.append(f"events dropped at benchmark capacities: "
                        f"{dropped}")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
