"""Fig. 9: latency distribution / 95th-percentile SLO comparison.

Multi-Raft runs on the grouped fleet engine (measured 2PC tails,
DESIGN.md §9) unless `--sequential` selects the frozen host reference.
"""
import numpy as np

from benchmarks import common
from benchmarks.common import PAPER_CLUSTER, tick_ms
from repro.core.runtime import BWRaftSim, goodput_under_deadline
from repro.core.multiraft import MultiRaftSim


def run(quick: bool = True):
    epochs = 6 if quick else 30
    bw = BWRaftSim(PAPER_CLUSTER, write_rate=16.0, read_rate=48.0, seed=4)
    og = BWRaftSim(PAPER_CLUSTER, mode="raft", write_rate=16.0,
                   read_rate=48.0, seed=4)
    mr = MultiRaftSim(PAPER_CLUSTER, shards=2, write_rate=16.0,
                      read_rate=48.0, seed=4,
                      engine="fleet" if common.USE_FLEET
                      else "sequential")
    rows = []
    reps = {"bwraft": bw.run(epochs), "original": og.run(epochs),
            "multiraft": mr.run(epochs)}
    p95 = {}
    for name, rs in reps.items():
        tail = [r.write_lat_p95 for r in rs[-3:] if np.isfinite(
            r.write_lat_p95)]
        p95[name] = np.mean(tail) if tail else float("inf")
        rows.append((f"fig9.p95_write.{name}", tick_ms(p95[name]) * 1e3,
                     "us_p95"))
    # goodput under the p95 SLO of bwraft: how much each system serves
    # within bwraft's p95 bound (the paper's 95th-percentile-SLO goodput)
    slo = p95["bwraft"]
    for name, rs in reps.items():
        r = rs[-1]
        ok = r.goodput if p95[name] <= slo * 1.001 else \
            r.goodput * max(0.1, slo / max(p95[name], 1e-9))
        rows.append((f"fig9.goodput_within_slo.{name}", ok, "ops"))
    # read-path tails + MEASURED SLO goodput, straight off the last
    # epoch's digest histograms (DESIGN.md §11) — fleet engine only;
    # --sequential keeps just the synthesized rows above
    deadline = 30                          # 300 ms, see common.tick_ms
    digests = {"bwraft": bw.last_digest, "original": og.last_digest}
    if mr.engine == "fleet" and mr.fleet.last_group_digest is not None:
        digests["multiraft"] = {
            k: v[0] for k, v in mr.fleet.last_group_digest.items()}
    for name, rs in reps.items():
        rows.append((f"fig9.p95_read.{name}",
                     tick_ms(rs[-1].read_lat_p95) * 1e3, "us_p95"))
        dg = digests.get(name)
        if dg is not None:
            good = (goodput_under_deadline(dg["read_lat_hist"], deadline) +
                    goodput_under_deadline(dg["write_lat_hist"], deadline))
            rows.append((f"fig9.goodput_under_deadline.{name}", good,
                         "ops"))
    return rows
