"""Logical-axis sharding rules (MaxText-style) with divisibility pruning.

Every parameter / activation carries a tuple of *logical* axis names.
A profile maps logical names to mesh axis names; `logical_to_spec`
resolves them against a concrete mesh, dropping any mesh axis that does
not evenly divide the corresponding dimension (JAX rejects uneven input
shardings).  The pruning decisions are recorded so the dry-run report can
show which dims fell back to replication (e.g. smollm's 15 heads on a
16-way "model" axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Sharding profiles.  Values are mesh-axis names or tuples of them; names not
# present in the mesh are silently skipped (so the same profile serves the
# single-pod ("data","model") and the multi-pod ("pod","data","model") mesh).
# ---------------------------------------------------------------------------

#: Default training profile: DP over (pod, data), ZeRO-3 style weight
#: sharding over "data" on the embed dim, tensor parallelism over "model".
TRAIN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,                 # set to "model" by the sequence-parallel profile
    "embed": "data",             # FSDP shard of weight d_model dims
    "embed_tp": None,            # second d_model dim on square weights
    "heads": "model",
    "kv_heads": "model",         # pruned to None when kv < |model|
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",          # expert parallelism
    "expert_mlp": None,
    "shared_mlp": "model",
    "layers": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "img_seq": None,
    "frames": None,
    "kv_seq": None,
    "unsharded": None,
}

#: Serving (decode) profile: batch over data, KV caches sharded over the
#: sequence axis on "model" (flash-decode style), weights as in training but
#: without the FSDP embed shard (decode is latency-bound; keep weights TP).
DECODE_RULES: dict[str, Any] = dict(
    TRAIN_RULES,
    batch=("pod", "data"),
    kv_seq="model",
    embed="data",
)

#: Long-context (batch=1) profile: nothing can shard on batch; KV/sequence
#: state shards over both axes.
LONG_RULES: dict[str, Any] = dict(
    TRAIN_RULES,
    batch=None,
    seq=("data", "model"),
    kv_seq=("data", "model"),
)

#: Sequence-parallel training profile (hillclimb lever): residual-stream
#: activations shard the sequence dim on "model" between blocks, turning the
#: two per-block all-reduces into reduce-scatter + all-gather pairs.
TRAIN_SP_RULES: dict[str, Any] = dict(TRAIN_RULES, seq="model")

PROFILES: dict[str, dict[str, Any]] = {
    "train": TRAIN_RULES,
    "train_sp": TRAIN_SP_RULES,
    "decode": DECODE_RULES,
    "long": LONG_RULES,
}


@dataclasses.dataclass
class PruneLog:
    """Records (path, dim, logical, mesh_axes, size) replication fallbacks."""
    entries: list = dataclasses.field(default_factory=list)

    def add(self, name: str, dim: int, logical: str, axes, size: int) -> None:
        self.entries.append((name, dim, logical, axes, size))

    def render(self) -> str:
        if not self.entries:
            return "(no sharding fallbacks)"
        lines = ["sharding fallbacks (dim -> replicated):"]
        for name, dim, logical, axes, size in self.entries:
            lines.append(f"  {name} dim{dim} [{logical}]={size} !% mesh{axes}")
        return "\n".join(lines)


def _mesh_extent(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: Mapping[str, Any],
    mesh: Mesh,
    *,
    name: str = "?",
    prune_log: Optional[PruneLog] = None,
) -> P:
    """Resolve logical axes -> PartitionSpec on `mesh`, pruning uneven dims.

    Mesh axes already used by an earlier dim of the same tensor are dropped
    (a mesh axis may appear at most once in a PartitionSpec).
    """
    assert len(logical_axes) == len(shape), (name, logical_axes, shape)
    used: set = set()
    out = []
    for dim, (logical, size) in enumerate(zip(logical_axes, shape)):
        if logical is None:
            out.append(None)
            continue
        mapped = rules.get(logical)
        if mapped is None:
            out.append(None)
            continue
        axes = mapped if isinstance(mapped, tuple) else (mapped,)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes:
            out.append(None)
            continue
        extent = _mesh_extent(mesh, axes)
        if size % extent != 0:
            # try progressively shorter prefixes before giving up
            while axes and size % _mesh_extent(mesh, axes) != 0:
                axes = axes[:-1]
            if not axes:
                if prune_log is not None:
                    prune_log.add(name, dim, logical, mapped, size)
                out.append(None)
                continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def tree_shardings(
    param_tree,
    rules: Mapping[str, Any],
    mesh: Mesh,
    *,
    prune_log: Optional[PruneLog] = None,
):
    """Map a tree of ParamSpec -> tree of NamedSharding."""
    from repro.models.common import ParamSpec  # circular-free local import

    def one(path, p: ParamSpec):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        spec = logical_to_spec(p.axes, p.shape, rules, mesh,
                               name=name, prune_log=prune_log)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        one, param_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def constrain(x, logical_axes, rules, mesh):
    """with_sharding_constraint via logical names (no-op outside mesh dims)."""
    spec = logical_to_spec(logical_axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_constrainer(rules, mesh):
    def f(x, *logical_axes):
        return constrain(x, logical_axes, rules, mesh)
    return f
