"""Device-resident flight recorder (DESIGN.md §14): in-graph
control-plane event capture into fixed-capacity ring buffers, a named
metrics registry reduced through the epoch digest, host-side decode
with exact per-class `events_dropped`, and Chrome/Perfetto + ASCII
timeline export."""
from repro.trace.ring import (CLASS_NAMES, CLS_AE, CLS_COMMIT,
                              CLS_ELECTION, CLS_HANDOFF, CLS_SPOT,
                              CLS_TWOPC, DEFAULT_CAPACITY, EVENT_CLASS,
                              EVENT_NAMES, EV_2PC_COMMIT, EV_2PC_PREPARE,
                              EV_AE_FALLBACK, EV_AE_SYNC, EV_CANDIDACY,
                              EV_COMMIT, EV_ELECT, EV_GRANT, EV_KILL,
                              EV_OBS_DRAIN, EV_REPRIEVE, EV_SEC_HANDOFF,
                              EV_SEC_STOP, EV_STEPDOWN, EV_WARN, LANES,
                              NCLASS, NEVENT, default_mask, emit, record,
                              trace_leaves)
from repro.trace.metrics import COUNTERS, NCOUNTER, as_dict, bump
from repro.trace.export import (DrainCursor, TraceEvent, leader_spans,
                                leader_timeline, to_perfetto,
                                write_perfetto)
from repro.trace.timeline import render

__all__ = [
    "CLASS_NAMES", "CLS_AE", "CLS_COMMIT", "CLS_ELECTION",
    "CLS_HANDOFF", "CLS_SPOT", "CLS_TWOPC", "DEFAULT_CAPACITY",
    "EVENT_CLASS", "EVENT_NAMES", "EV_2PC_COMMIT", "EV_2PC_PREPARE",
    "EV_AE_FALLBACK", "EV_AE_SYNC", "EV_CANDIDACY", "EV_COMMIT",
    "EV_ELECT", "EV_GRANT", "EV_KILL", "EV_OBS_DRAIN", "EV_REPRIEVE",
    "EV_SEC_HANDOFF", "EV_SEC_STOP", "EV_STEPDOWN", "EV_WARN",
    "LANES", "NCLASS", "NEVENT",
    "COUNTERS", "NCOUNTER", "DrainCursor", "TraceEvent", "as_dict",
    "bump", "default_mask", "emit", "leader_spans", "leader_timeline",
    "record", "render", "to_perfetto", "trace_leaves", "write_perfetto",
]
