"""Unified control-plane metrics registry (DESIGN.md §14).

Named counters accumulated in-graph in the `(NCOUNTER,)` int32
`metrics_ctr` state leaf, reduced through the epoch digest
(`trace_metrics`, group-summed by `fleet._group_digest`) and surfaced
as `EpochReport.metrics` — the structured replacement for growing the
report one ad-hoc scalar field at a time.  Counters are ALWAYS on
(unlike ring capture they are not gated by `trace_on`): they are a few
integer adds per tick, and the per-epoch reduction is what the digest
already pays for.  The leaf resets at compaction with the other
per-epoch counters.

This module must not import `repro.core` (it is imported by
`core/state.py` via `trace.ring`).
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax.numpy as jnp

COUNTERS = (
    # election seam (step.election_step)
    "elections_started", "votes_granted", "leader_elected",
    "leader_stepdowns", "sec_stops",
    # commit seam (step.commit_step)
    "commit_advances", "entries_committed",
    # revocation seam (step.spot_step, §12)
    "warns_armed", "reprieves", "kills",
    # handoff seam (§6/§13)
    "sec_handoffs", "obs_drains",
    # anti-entropy seam (step.anti_entropy_step, §13)
    "ae_rounds", "ae_fallbacks",
    # Multi-Raft 2PC seam (§9)
    "twopc_prepared", "twopc_committed",
)
NCOUNTER = len(COUNTERS)
INDEX = {name: i for i, name in enumerate(COUNTERS)}


def bump(state: Dict, name: str, amount) -> Dict:
    """Add `amount` to one named counter; a no-op passthrough on
    minimal states without the registry leaf."""
    if "metrics_ctr" not in state:
        return state
    return dict(state, metrics_ctr=state["metrics_ctr"].at[
        INDEX[name]].add(jnp.asarray(amount, jnp.int32)))


def as_dict(vec) -> Dict[str, int]:
    """Decode a digest's `(NCOUNTER,)` counter vector into
    `{name: int}` — the `EpochReport.metrics` payload."""
    arr = np.asarray(vec).reshape(-1)
    assert arr.shape[0] == NCOUNTER, arr.shape
    return {name: int(arr[i]) for i, name in enumerate(COUNTERS)}
