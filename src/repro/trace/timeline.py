"""ASCII timeline rendering for flight-recorder events (DESIGN.md §14)
— the terminal twin of `trace.export`'s Perfetto writer, for chaos
drills and notebook-free debugging."""
from __future__ import annotations

from typing import Optional, Sequence

from repro.trace.export import TraceEvent, leader_timeline
from repro.trace.ring import EVENT_NAMES

_MARKS = "123456789"


def render(events: Sequence[TraceEvent], *, ticks: Optional[int] = None,
           width: int = 72) -> str:
    """One row per event code that fired plus a leader-presence row;
    columns are tick buckets, the glyph is the event count in the
    bucket (capped at 9, '#' beyond)."""
    if not events:
        return "(no events)"
    horizon = ticks or (max(e.tick for e in events) + 1)
    width = max(1, min(width, horizon))
    per = max(1, -(-horizon // width))      # ticks per column
    cols = -(-horizon // per)
    rows = {}
    for e in events:
        rows.setdefault(e.code, [0] * cols)[min(e.tick // per,
                                                cols - 1)] += 1
    label_w = max(len(EVENT_NAMES[c]) for c in rows) + 2
    lines = [f"{'tick':>{label_w}} 0{'.' * (cols - 2)}{horizon - 1}"]
    up = leader_timeline(events, horizon)
    lead = "".join(
        "#" if up[c * per:(c + 1) * per].all()
        else ("." if not up[c * per:(c + 1) * per].any() else "/")
        for c in range(cols))
    lines.append(f"{'leader':>{label_w}} {lead}")
    for code in sorted(rows):
        cells = "".join(
            "." if n == 0 else (_MARKS[n - 1] if n <= 9 else "#")
            for n in rows[code])
        lines.append(f"{EVENT_NAMES[code]:>{label_w}} {cells}")
    return "\n".join(lines)
