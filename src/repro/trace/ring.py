"""Device-resident flight-recorder ring: in-graph event capture
(DESIGN.md §14).

Control-plane events are appended *inside* the compiled tick into one
fixed-capacity ring per cluster: a `(CAP, LANES)` int32 leaf whose five
lanes are `(code, tick, node, term, aux)`, plus a monotone int32 write
cursor and a per-class gated-emit counter.  Capture is gated by the
`trace_on` flag and the per-class `trace_mask` riding in `cfg_c` — both
are jit *arguments*, so toggling tracing or remasking event classes
never recompiles; only the ring capacity (a static shape,
`state.build_static(trace_capacity=...)`) is compile-key material.

The gate contract (audited by `tests/test_trace.py` against the
pre-change fixture `tests/data/trace_golden.json`): `emit` reads
dynamics and writes ONLY the three trace leaves, consumes no RNG, and
scatters nothing when the gate is down — so `trace_on=0` trajectories
and digests are bit-identical to the untraced program.

Overflow semantics: the cursor always advances by the number of gated
events, but a slot is written only for the newest `CAP`.  When a single
batch emits more than `CAP` events, only its last `CAP` land (`rank +
CAP > total`), which both keeps the scatter indices collision-free and
matches what a wrapping ring would retain.  The host drain
(`trace.export.DrainCursor`) recovers exact per-class `events_dropped`
from `cursor delta - decoded events` — no silent truncation.

This module is imported by `core/state.py` and `core/step.py`; it must
not import `repro.core` back.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------- #
# event classes (mask lanes) — `cfg_c["trace_mask"]` is (NCLASS,) bool
CLS_ELECTION, CLS_COMMIT, CLS_SPOT, CLS_HANDOFF, CLS_AE, CLS_TWOPC = \
    range(6)
NCLASS = 6
CLASS_NAMES = ("election", "commit", "spot", "handoff", "ae", "twopc")

# event codes (the ring's `code` lane)
EV_CANDIDACY = 0      # follower/candidate timed out, new candidacy
EV_GRANT = 1          # voter granted a vote (aux = candidate id)
EV_ELECT = 2          # candidate won: majority tallied this tick
EV_STEPDOWN = 3       # leader demoted (higher term seen)
EV_SEC_STOP = 4       # secretary stopped on a new-leader edge (§6)
EV_COMMIT = 5         # commit index advanced (aux = new commit length)
EV_WARN = 6           # advance warning armed, W > 0 (aux = W)
EV_KILL = 7           # revocation landed / iid failure (aux = old role)
EV_REPRIEVE = 8       # warning cleared before the timer expired (§12)
EV_SEC_HANDOFF = 9    # warned secretary: fan-out hand-back begins
EV_OBS_DRAIN = 10     # warned observer: read drain begins
EV_AE_SYNC = 11       # anti-entropy round landed (node = observer slot,
                      # aux = source applied length, §13)
EV_AE_FALLBACK = 12   # round used the any-voter fallback source
EV_2PC_PREPARE = 13   # cross-shard entries prepared (aux = count, §9)
EV_2PC_COMMIT = 14    # cross-shard entries committed (aux = count)
NEVENT = 15

EVENT_NAMES = (
    "candidacy", "grant", "elect", "stepdown", "sec_stop", "commit",
    "warn", "kill", "reprieve", "sec_handoff", "obs_drain", "ae_sync",
    "ae_fallback", "2pc_prepare", "2pc_commit")

# class of each event code — host-side table; `emit` call sites pass a
# python-int code, so the class lookup is static per site
EVENT_CLASS = np.array([
    CLS_ELECTION, CLS_ELECTION, CLS_ELECTION, CLS_ELECTION, CLS_ELECTION,
    CLS_COMMIT,
    CLS_SPOT, CLS_SPOT, CLS_SPOT,
    CLS_HANDOFF, CLS_HANDOFF,
    CLS_AE, CLS_AE,
    CLS_TWOPC, CLS_TWOPC], np.int32)
assert EVENT_CLASS.shape[0] == NEVENT == len(EVENT_NAMES)

LANES = 5                     # (code, tick, node, term, aux)
DEFAULT_CAPACITY = 128        # 128 * 5 * 4 B = 2560 B/drain, under §7.1


def trace_leaves(capacity: int) -> Dict:
    """Fresh flight-recorder leaves for `state.init_state`: the ring,
    its monotone cursor, and the per-class gated-emit counters.  NOT
    reset by `compact_state` — the cursor is monotone across epochs so
    the host drain windows stay exact."""
    from repro.trace.metrics import NCOUNTER
    return {
        "trace_ev": jnp.zeros((int(capacity), LANES), jnp.int32),
        "trace_pos": jnp.zeros((), jnp.int32),
        "trace_emit": jnp.zeros((NCLASS,), jnp.int32),
        "metrics_ctr": jnp.zeros((NCOUNTER,), jnp.int32),
    }


def emit(state: Dict, cfg_c: Dict, code: int, *, valid, node,
         term=0, aux=0) -> Dict:
    """Append up to `valid.sum()` events of one code into the ring.

    `valid` is a bool scalar or (n,) lane mask; `node`/`term`/`aux`
    broadcast against it.  The write is gated by
    `trace_on & trace_mask[class]` (cfg_c data — never recompiles);
    with the gate down the scatter writes nothing and the cursor adds
    zero, so the leaves are value-identical to the untraced program.
    States without trace leaves (minimal unit-test pytrees) pass
    through untouched."""
    if "trace_ev" not in state:
        return state
    cls = int(EVENT_CLASS[code])
    gate = cfg_c["trace_on"] & cfg_c["trace_mask"][cls]
    valid = jnp.atleast_1d(jnp.asarray(valid))
    n = valid.shape[0]
    v = valid & gate
    cap = state["trace_ev"].shape[0]
    vi = v.astype(jnp.int32)
    total = jnp.sum(vi)
    rank = jnp.cumsum(vi)               # 1-based rank among gated events
    # one batch larger than the ring: keep only the newest CAP, which
    # keeps the scatter indices unique AND matches ring retention
    keep = v & (rank + cap > total)
    slot = jnp.where(keep, (state["trace_pos"] + rank - 1) % cap, cap)
    row = jnp.stack([
        jnp.full((n,), code, jnp.int32),
        jnp.broadcast_to(state["tick"].astype(jnp.int32), (n,)),
        jnp.broadcast_to(jnp.asarray(node, jnp.int32), (n,)),
        jnp.broadcast_to(jnp.asarray(term, jnp.int32), (n,)),
        jnp.broadcast_to(jnp.asarray(aux, jnp.int32), (n,)),
    ], axis=1)
    return dict(
        state,
        trace_ev=state["trace_ev"].at[slot].set(row, mode="drop"),
        trace_pos=state["trace_pos"] + total,
        trace_emit=state["trace_emit"].at[cls].add(total))


def record(state: Dict, cfg_c: Dict, code: int, *, valid, node,
           term=0, aux=0, counter: Optional[str] = None,
           count=None) -> Dict:
    """`emit` + metrics bump in one call: the counter (always-on, NOT
    gated by `trace_on` — it replaces ad-hoc EpochReport fields) adds
    `count` when given, else the number of valid lanes."""
    from repro.trace import metrics as _metrics
    state = emit(state, cfg_c, code, valid=valid, node=node, term=term,
                 aux=aux)
    if counter is not None and "metrics_ctr" in state:
        amt = (jnp.sum(jnp.atleast_1d(jnp.asarray(valid))
                       .astype(jnp.int32)) if count is None
               else jnp.asarray(count, jnp.int32))
        state = _metrics.bump(state, counter, amt)
    return state


def default_mask(**overrides: bool) -> Tuple[bool, ...]:
    """The (NCLASS,) capture mask as a hashable tuple: all classes on,
    with keyword overrides by class name (`ae=False`, ...)."""
    mask = [True] * NCLASS
    for name, on in overrides.items():
        mask[CLASS_NAMES.index(name)] = bool(on)
    return tuple(mask)
