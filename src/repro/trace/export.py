"""Host-side flight-recorder drain + Chrome/Perfetto export
(DESIGN.md §14).

`DrainCursor` turns the three device trace leaves into typed
`TraceEvent` records with EXACT per-class `events_dropped`: the ring
cursor is monotone, so the decodable window is
`[max(seen, pos - CAP), pos)` and anything the per-class gated-emit
counters advanced beyond the decoded events was overwritten before this
drain — reported, never silently truncated.  One `drain()` is one D2H
fetch of `CAP·LANES·4 + (NCLASS+1)·4` bytes (2.6 KB at the default
capacity, under the §7.1 digest ceiling).

`write_perfetto` emits Chrome trace-event JSON (`chrome://tracing`,
https://ui.perfetto.dev): fleet member = process, node = thread, one
extra thread per site for anti-entropy rounds, and a synthetic
"leader" thread of complete (`"X"`) tenure spans — leaderless windows
are the GAPS on that track, which `market/chaos.py` pins against
`ChaosReport.max_leaderless_span`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.trace.ring import (CLASS_NAMES, EVENT_CLASS, EVENT_NAMES,
                              EV_AE_FALLBACK, EV_AE_SYNC, EV_ELECT,
                              EV_KILL, EV_STEPDOWN, NCLASS)

TICK_US = 10_000.0            # 1 tick = 10 ms, the repo-wide clock


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One decoded ring slot (see `trace.ring` lane layout)."""
    code: int
    tick: int
    node: int
    term: int
    aux: int
    member: int = 0

    @property
    def name(self) -> str:
        return EVENT_NAMES[self.code]

    @property
    def cls(self) -> int:
        return int(EVENT_CLASS[self.code])


class DrainCursor:
    """Incremental ring reader for one cluster/member.

    Call `drain(state)` once per epoch (or per tick in host-driven
    harnesses) on the CURRENT state pytree; returns the events appended
    since the previous drain, in emission order.  `dropped` accumulates
    the exact per-class overwrite counts: gated emits that fell out of
    the window before they could be decoded."""

    def __init__(self, member: int = 0):
        self.member = member
        self.pos = 0
        self.emit_seen = np.zeros(NCLASS, np.int64)
        self.dropped = np.zeros(NCLASS, np.int64)

    def drain(self, state: Dict) -> List[TraceEvent]:
        ev = np.asarray(state["trace_ev"])
        pos = int(np.asarray(state["trace_pos"]))
        emit = np.asarray(state["trace_emit"]).astype(np.int64)
        cap = ev.shape[0]
        start = max(self.pos, pos - cap)
        events = [TraceEvent(int(ev[i % cap, 0]), int(ev[i % cap, 1]),
                             int(ev[i % cap, 2]), int(ev[i % cap, 3]),
                             int(ev[i % cap, 4]), self.member)
                  for i in range(start, pos)]
        decoded = np.zeros(NCLASS, np.int64)
        for e in events:
            decoded[e.cls] += 1
        self.dropped += (emit - self.emit_seen) - decoded
        self.pos, self.emit_seen = pos, emit
        return events

    def dropped_by_class(self) -> Dict[str, int]:
        return {name: int(self.dropped[i])
                for i, name in enumerate(CLASS_NAMES)}


def leader_timeline(events: Sequence[TraceEvent],
                    ticks: int) -> np.ndarray:
    """Replay the event stream (in ring order — in-tick ordering is the
    emission order inside `step.tick`) into a per-tick `(ticks,)` bool
    leader-present vector, the trace-side twin of the chaos harness's
    per-tick `has_leader` probe."""
    up = np.zeros(ticks, bool)
    leader = -1
    # events are already tick-ordered by construction; walk tick by tick
    evs = list(events)
    j = 0
    for t in range(ticks):
        while j < len(evs) and evs[j].tick <= t:
            e = evs[j]
            if e.code == EV_ELECT:
                leader = e.node
            elif e.code in (EV_STEPDOWN, EV_KILL) and e.node == leader:
                leader = -1
            j += 1
        up[t] = leader >= 0
    return up


def leader_spans(events: Sequence[TraceEvent],
                 ticks: int) -> List[Dict]:
    """Leader tenure spans `{node, start, end}` (end exclusive) derived
    from elect/stepdown/kill events — the "leader" Perfetto track."""
    spans: List[Dict] = []
    leader, start = -1, 0
    for e in events:
        if e.code == EV_ELECT:
            if leader >= 0 and e.tick > start:
                spans.append({"node": leader, "start": start,
                              "end": e.tick})
            leader, start = e.node, e.tick
        elif e.code in (EV_STEPDOWN, EV_KILL) and e.node == leader:
            if e.tick + 1 > start:
                spans.append({"node": leader, "start": start,
                              "end": e.tick + 1})
            leader = -1
    if leader >= 0 and ticks > start:
        spans.append({"node": leader, "start": start, "end": ticks})
    return spans


_LEADER_TID = 9_999
_SITE_TID0 = 100_000


def to_perfetto(events: Sequence[TraceEvent], *, ticks: int = 0,
                sites: Optional[Dict[int, Sequence[int]]] = None,
                obs_site: Optional[Dict[int, Sequence[int]]] = None,
                annotations: Optional[Sequence[Dict]] = None) -> Dict:
    """Build the Chrome trace-event JSON dict (DESIGN.md §14 track
    mapping): pid = fleet member, tid = node (election/commit/spot/
    handoff/2PC instants), tid = site track for anti-entropy rounds
    (via `obs_site[member][slot]`, the static `dobs_site` wiring), and
    a per-member "leader" thread of `"X"` tenure spans whose gaps are
    the leaderless windows.  `annotations` (from
    `kvstore/service.py`) land on a "client" thread as spans."""
    tev: List[Dict] = []
    members = sorted({e.member for e in events}) or [0]
    horizon = max([ticks] + [e.tick + 1 for e in events])
    for m in members:
        tev.append({"ph": "M", "pid": m, "name": "process_name",
                    "args": {"name": f"member {m}"}})
        tev.append({"ph": "M", "pid": m, "tid": _LEADER_TID,
                    "name": "thread_name", "args": {"name": "leader"}})
        mev = [e for e in events if e.member == m]
        for span in leader_spans(mev, horizon):
            tev.append({
                "ph": "X", "pid": m, "tid": _LEADER_TID,
                "name": f"leader n{span['node']}",
                "ts": span["start"] * TICK_US,
                "dur": (span["end"] - span["start"]) * TICK_US})
        named_nodes, named_sites = set(), set()
        for e in mev:
            if e.code in (EV_AE_SYNC, EV_AE_FALLBACK):
                site = -1
                if obs_site and m in obs_site \
                        and e.node < len(obs_site[m]):
                    site = int(obs_site[m][e.node])
                tid = _SITE_TID0 + (site if site >= 0 else e.node)
                if tid not in named_sites:
                    named_sites.add(tid)
                    label = (f"site {site} ae" if site >= 0
                             else f"obs {e.node} ae")
                    tev.append({"ph": "M", "pid": m, "tid": tid,
                                "name": "thread_name",
                                "args": {"name": label}})
            else:
                tid = e.node
                if tid not in named_nodes:
                    named_nodes.add(tid)
                    label = f"node {e.node}"
                    if sites and m in sites and e.node < len(sites[m]):
                        label += f" @ site {int(sites[m][e.node])}"
                    tev.append({"ph": "M", "pid": m, "tid": tid,
                                "name": "thread_name",
                                "args": {"name": label}})
            tev.append({"ph": "i", "pid": m, "tid": tid, "s": "t",
                        "name": e.name, "ts": e.tick * TICK_US,
                        "args": {"term": e.term, "aux": e.aux}})
    for a in annotations or ():
        m = int(a.get("member", 0))
        tev.append({"ph": "X", "pid": m, "tid": _SITE_TID0 - 1,
                    "name": a.get("name", "read_index"),
                    "ts": float(a["start_tick"]) * TICK_US,
                    "dur": max(float(a.get("end_tick", a["start_tick"]))
                               - float(a["start_tick"]), 0.5) * TICK_US,
                    "args": {k: v for k, v in a.items()
                             if k not in ("name", "start_tick",
                                          "end_tick", "member")}})
        tev.append({"ph": "M", "pid": m, "tid": _SITE_TID0 - 1,
                    "name": "thread_name", "args": {"name": "client"}})
    return {"traceEvents": tev, "displayTimeUnit": "ms"}


def write_perfetto(events: Sequence[TraceEvent], path: str, **kw) -> Dict:
    """`to_perfetto` + JSON dump; returns the trace dict."""
    trace = to_perfetto(events, **kw)
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace
