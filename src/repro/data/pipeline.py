"""Deterministic synthetic data pipeline + workload generators.

Training: a seeded, restartable token stream — `batch_at(step)` is a pure
function of (seed, step, shard), so any pod can reproduce any batch after
failover, and elastic re-sharding (fewer pods -> wider per-pod slices) is
exact.  Serving: Google/Alibaba-trace-style request generators (Poisson
arrivals, Zipf keys, lognormal bursts) shared with the consensus
benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenPipeline:
    """Synthetic LM stream: Zipf-ish unigram mix with induced bigram
    structure so reduced models show decreasing loss."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, *, shard: int = 0, num_shards: int = 1,
                 extras: Optional[Dict] = None) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b_loc = cfg.global_batch // num_shards
        # generate the GLOBAL batch from (seed, step) only, then slice the
        # shard: re-sharding after failover is exact (no loss/duplication)
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        base = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
        toks = (base + rng.integers(0, 7, size=base.shape)) % cfg.vocab_size
        # bigram structure: even positions predict +1
        toks[:, 1::2] = (toks[:, 0:-1:2] + 1) % cfg.vocab_size
        toks = toks[shard * b_loc:(shard + 1) * b_loc]
        out = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if extras:
            out.update({k: jnp.asarray(v) for k, v in extras.items()})
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class RequestTrace:
    """Serving workload: arrival times + request sizes (trace-style)."""
    arrivals: np.ndarray          # arrival tick per request
    prompt_lens: np.ndarray
    keys: np.ndarray              # for KV-service benchmarks


def google_trace_like(n: int, *, rate: float = 16.0, burst: float = 2.0,
                      key_space: int = 1024, seed: int = 0) -> RequestTrace:
    """Poisson arrivals with lognormal burst modulation, Zipf keys — the
    shape of the Google cluster trace workloads used in the paper."""
    rng = np.random.default_rng(seed)
    mod = rng.lognormal(0.0, burst * 0.25, size=n)
    gaps = rng.exponential(1.0 / rate, size=n) / np.maximum(mod, 1e-2)
    arrivals = np.cumsum(gaps)
    prompt_lens = np.clip(rng.lognormal(4.5, 0.8, size=n), 8, 2048)
    keys = rng.zipf(1.2, size=n) % key_space
    return RequestTrace(arrivals=arrivals,
                        prompt_lens=prompt_lens.astype(np.int32),
                        keys=keys.astype(np.int32))


def rw_mix(trace: RequestTrace, alpha: float, seed: int = 0) -> np.ndarray:
    """alpha = read fraction; returns bool mask (True=read) per request."""
    rng = np.random.default_rng(seed)
    return rng.uniform(size=len(trace.arrivals)) < alpha
