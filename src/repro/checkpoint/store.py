"""Sharded checkpoint store with async save and atomic consensus commit.

Layout:  <dir>/step_<N>/shard_<i>.npz + manifest.json.  A checkpoint is
*valid* only once its `CKPT_COMMIT(step, digest)` record commits in the
BW-Raft control log (the coordinator does that) — a torn/partial save can
never be restored because the digest won't match.  Saves run on a worker
thread (training continues); `wait()` joins before the commit record is
proposed.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax


def tree_digest(tree) -> str:
    h = hashlib.sha256()
    for path, leaf in sorted(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            key=lambda kv: str(kv[0])):
        arr = np.asarray(leaf)
        h.update(str(path).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes()[:4096])     # prefix digest: fast + effective
        h.update(arr.tobytes()[-4096:])
    return h.hexdigest()[:16]


class CheckpointStore:
    def __init__(self, directory: str, *, shards: int = 1):
        self.dir = directory
        self.shards = shards
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def _flatten(self, tree) -> Dict[str, np.ndarray]:
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        return {jax.tree_util.keystr(path): np.asarray(leaf)
                for path, leaf in flat}

    def save(self, step: int, tree, *, blocking: bool = True) -> str:
        """Write shards + manifest; returns the digest."""
        digest = tree_digest(tree)
        flat = self._flatten(tree)

        def work():
            try:
                d = os.path.join(self.dir, f"step_{step}")
                os.makedirs(d, exist_ok=True)
                names = sorted(flat)
                per = -(-len(names) // self.shards)
                for i in range(self.shards):
                    chunk = {n: flat[n] for n in names[i * per:(i + 1) * per]}
                    np.savez(os.path.join(d, f"shard_{i}.npz"), **chunk)
                manifest = {"step": step, "digest": digest,
                            "shards": self.shards, "n_arrays": len(names)}
                tmp = os.path.join(d, "manifest.json.tmp")
                with open(tmp, "w") as f:
                    json.dump(manifest, f)
                os.replace(tmp, os.path.join(d, "manifest.json"))
            except BaseException as e:      # surfaced by wait()
                self._last_error = e

        if blocking:
            work()
        else:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        return digest

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # ------------------------------------------------------------------ #
    def restore(self, step: int, like_tree) -> Tuple[Any, str]:
        """Load a checkpoint into the structure of `like_tree`."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data: Dict[str, np.ndarray] = {}
        for i in range(manifest["shards"]):
            with np.load(os.path.join(d, f"shard_{i}.npz")) as z:
                data.update({k: z[k] for k in z.files})
        flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        leaves = [jax.numpy.asarray(data[jax.tree_util.keystr(p)])
                  for p, _ in flat]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), leaves)
        return tree, manifest["digest"]

    def available_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)
