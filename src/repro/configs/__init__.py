"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, RunConfig, ShapeConfig, SHAPES,
                                SHAPES_BY_NAME, shape_applicable)

_MODULES = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen2.5-3b": "qwen2_5_3b",
    "smollm-360m": "smollm_360m",
    "qwen3-8b": "qwen3_8b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-130m": "mamba2_130m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_run_config(cfg: ModelConfig, **overrides) -> RunConfig:
    kw = dict(cfg.run_overrides)
    kw.update(overrides)
    return RunConfig(**kw)
