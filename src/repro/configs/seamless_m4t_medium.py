"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206, enc-dec multimodal [arXiv:2308.11596; hf].

Encoder-decoder: 12 encoder + 12 decoder layers.  The audio frontend is a
STUB: input_specs() supplies precomputed frame embeddings (B, S, d_model).
vocab 256206 pads to 256256 for 16-way sharding (loss masks the pad).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio_encdec",
    num_layers=12, encoder_layers=12, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=256206,
    rope_theta=10_000.0,
    cross_attn_period=1, cross_attn_offset=0,   # every decoder layer
)
