"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152, llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

15 Q-heads / 5 KV-heads do not divide the 16-way "model" axis: attention
projections auto-replicate (see DESIGN.md §4); d_ff=2560 and d_model=960
still shard 16-way.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152, tie_embeddings=True, rope_theta=10_000.0,
    # pure data parallelism: 15 heads can't shard the 16-way "model" axis,
    # so spread the batch over BOTH axes instead — measured 18.9x step-bound
    # improvement on train_4k (EXPERIMENTS.md §Perf cell 4)
    sharding_overrides=(("batch", ("pod", "data", "model")),),
    run_overrides=(("num_microbatches", 1),),
)
