"""Architecture + runtime configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.common import pad_vocab


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | vlm | audio_encdec | moe | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_d_ff: int = 0          # total shared-expert ff width (0 = none)
    moe_layer_period: int = 1         # MoE MLP every `period` layers
    moe_layer_offset: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid (Jamba): attention layer every `attn_layer_period`, rest SSM
    attn_layer_period: int = 0
    attn_layer_offset: int = 0

    # VLM: cross-attention to image embeddings every `cross_attn_period`
    cross_attn_period: int = 0
    cross_attn_offset: int = 0
    num_image_tokens: int = 0

    # encoder-decoder (audio): encoder depth; frontend supplies embeddings
    encoder_layers: int = 0

    # sub-quadratic context support (long_500k eligibility)
    sub_quadratic: bool = False

    # per-arch sharding rule overrides, merged over the active profile
    sharding_overrides: Tuple[Tuple[str, object], ...] = ()
    # per-arch RunConfig overrides (e.g. bf16 optimizer state for >=90B)
    run_overrides: Tuple[Tuple[str, object], ...] = ()

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:        # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_layer_period:
            return i % self.attn_layer_period == self.attn_layer_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe_num_experts:
            return False
        return i % self.moe_layer_period == self.moe_layer_offset

    def is_cross_attn_layer(self, i: int) -> bool:
        if not self.cross_attn_period:
            return False
        return i % self.cross_attn_period == self.cross_attn_offset

    @property
    def layer_period(self) -> int:
        """Smallest repeating block period (for roofline extrapolation)."""
        p = 1
        if self.attn_layer_period:
            p = max(p, self.attn_layer_period)
        if self.moe_num_experts and self.moe_layer_period > 1:
            p = max(p, self.moe_layer_period)
        if self.cross_attn_period:
            p = max(p, self.cross_attn_period)
        return p

    def with_layers(self, n: int) -> "ModelConfig":
        kw = {"num_layers": n}
        if self.encoder_layers:
            kw["encoder_layers"] = n
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        P = self.layer_period
        kw = dict(
            num_layers=P, d_model=64, num_heads=4, num_kv_heads=2,
            head_dim=16, d_ff=128 if self.d_ff else 0, vocab_size=256,
        )
        if self.moe_num_experts:
            kw.update(moe_num_experts=8, moe_top_k=min(self.moe_top_k, 2),
                      moe_d_ff=32,
                      moe_shared_d_ff=64 if self.moe_shared_d_ff else 0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.cross_attn_period:
            kw.update(num_image_tokens=8)
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Runtime/distribution knobs — the hillclimbing levers."""
    sharding_profile: str = "train"     # train | train_sp | decode | long
    remat: bool = True
    remat_policy: str = "period"        # period | block
    scan_layers: bool = True            # False => unrolled (roofline path)
    unroll_attn: bool = False           # unroll chunked-attention loops
    num_microbatches: int = 1
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    attn_chunk_q: int = 2048
    attn_chunk_k: int = 2048
    attention_impl: str = "xla"         # xla (chunked jnp) | pallas
    attn_acc_dtype: str = "float32"     # bfloat16 halves score-intermediate
                                        # bytes (hillclimb lever)
    zero3_at_use: bool = False          # all-gather FSDP weights per layer
                                        # instead of activation all-reduce
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    donate_state: bool = True

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md §4)"
    return True, ""
