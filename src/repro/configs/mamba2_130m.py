"""mamba2-130m [ssm] — 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: every layer is an SSD mixer with no MLP (d_ff=0), matching
the Mamba2 architecture.  d_inner=1536, headdim=64 -> 24 SSD heads (not
16-divisible; SSD tensors replicate on "model" — the arch is DP-dominant,
see DESIGN.md §4).  Supports long_500k (O(1) decode state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=0, vocab_size=50280, ssm_state=128, ssm_head_dim=64,
    sub_quadratic=True,
    sharding_overrides=(("batch", ("pod", "data", "model")),),
)
