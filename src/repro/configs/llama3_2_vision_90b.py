"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256, cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B, 1600, d_model).  Cross-attention layers sit
at every 5th position (20 of 100).  Optimizer state is bf16 (90B params x
fp32 m/v would not fit 16 GB/chip at 256 chips — DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, rope_theta=500_000.0,
    cross_attn_period=5, cross_attn_offset=4, num_image_tokens=1600,
    run_overrides=(("opt_state_dtype", "bfloat16"),),
)
