"""The paper's own system config: the BW-Raft geo-distributed KV service.

Not a neural architecture — this is the cluster/workload configuration the
paper evaluates (4 sites, on-demand voters + spot secretaries/observers,
Google-trace-style workload).  Consumed by repro.core / benchmarks.
"""
from repro.core.cluster_config import ClusterConfig, SiteConfig

CONFIG = ClusterConfig(
    name="bwraft-kv-paper",
    sites=(
        SiteConfig("eu-frankfurt", followers=2, rtt_intra=1, rtt_inter=8,
                   on_demand_price=0.0416, spot_price_mean=0.0125),
        SiteConfig("asia-singapore", followers=2, rtt_intra=1, rtt_inter=10,
                   on_demand_price=0.0464, spot_price_mean=0.0139),
        SiteConfig("us-east", followers=2, rtt_intra=1, rtt_inter=6,
                   on_demand_price=0.0416, spot_price_mean=0.0104),
        SiteConfig("us-west", followers=1, rtt_intra=1, rtt_inter=7,
                   on_demand_price=0.0416, spot_price_mean=0.0110),
    ),
    secretary_fanout=4,          # f: followers one secretary can handle
    write_ratio_threshold=0.30,  # varpi
    read_growth_deadband=0.10,   # |A| <= 10% -> no change
    period_ticks=100,            # T ("peek" window)
    budget_per_period=2.0,       # vartheta ($/period for spot lease)
    max_log=4096,
    key_space=1024,
)
