"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=0, vocab_size=151936, qk_norm=True, rope_theta=1_000_000.0,
    moe_num_experts=128, moe_top_k=8, moe_d_ff=768,
)
