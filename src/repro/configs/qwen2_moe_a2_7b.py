"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

Every layer is MoE (d_ff=0 dense path unused); the 4 shared experts are a
dense SwiGLU of width 4x1408=5632.  60 experts pad to 64 for 16-way EP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=151936, rope_theta=1_000_000.0,
    moe_num_experts=60, moe_top_k=4, moe_d_ff=1408, moe_shared_d_ff=5632,
)
