"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 1:7 [arXiv:2403.19887; hf].

Period-8 block: position 4 is attention, the rest SSD; MoE MLP on odd
positions (every other layer), dense d_ff=24576 otherwise.  Jamba-1.5 uses
Mamba-1 internals; we adapt to SSD (TPU-native, DESIGN.md §3) with
d_inner=16384, ssd head_dim=128 -> 128 heads (16-divisible), state=64.
Optimizer state is bf16 (398B params, DESIGN.md §3).  Supports long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536, rope_theta=1_000_000.0,
    moe_num_experts=16, moe_top_k=2, moe_d_ff=24576,
    moe_layer_period=2, moe_layer_offset=1,
    attn_layer_period=8, attn_layer_offset=4,
    ssm_state=64, ssm_head_dim=128, ssm_chunk=128,
    sub_quadratic=True,
    run_overrides=(("opt_state_dtype", "bfloat16"),),
)
