"""Typed control-plane records packed into the consensus log's (key,val).

The jitted state machine stores int32 (key, value) pairs; control records
reserve the top of the key space:  key = RECORD_BASE + record_type, value
packs the payload.  The KV data plane hashes user keys below RECORD_BASE.
"""
from __future__ import annotations

import dataclasses
from enum import IntEnum


class RecordType(IntEnum):
    CKPT_COMMIT = 0        # value = step*2**12 | digest12
    MEMBERSHIP = 1         # value = alive-pods bitmap (<= 30 pods)
    SCALE = 2              # value = k_s*2**10 | k_o
    STRAGGLER = 3          # value = pod id reassigned
    EPOCH_MARK = 4


RECORD_BASE_FRACTION = 0.9375   # top 1/16 of key space is control records


def record_base(key_space: int) -> int:
    return int(key_space * RECORD_BASE_FRACTION)


def pack_ckpt(step: int, digest_hex: str) -> int:
    d12 = int(digest_hex[:3], 16)           # 12-bit digest tag
    return (step & 0x3FFFF) * 4096 + d12


def unpack_ckpt(value: int):
    return value // 4096, value % 4096


def pack_scale(k_s: int, k_o: int) -> int:
    return (k_s & 0x3FF) * 1024 + (k_o & 0x3FF)


def unpack_scale(value: int):
    return value // 1024, value % 1024


def pack_membership(alive_bitmap: int) -> int:
    return alive_bitmap & 0x3FFFFFFF


@dataclasses.dataclass(frozen=True)
class ControlRecord:
    rtype: RecordType
    value: int

    def key(self, key_space: int) -> int:
        return record_base(key_space) + int(self.rtype)
