"""Straggler detection + elastic data-parallel reassignment.

Pods report per-step heartbeats (step durations).  A pod is a straggler
when its EWMA duration exceeds `threshold` x the fleet median for
`patience` consecutive steps; its batch range is reassigned (committed
through the consensus log as a STRAGGLER record + new MEMBERSHIP view) and
the data pipeline's pure `batch_at(step, shard, num_shards)` makes the
re-sharding exact — no data loss or duplication across the transition.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PodStats:
    ewma: float = 0.0
    strikes: int = 0
    active: bool = True


class StragglerMitigator:
    def __init__(self, num_pods: int, *, threshold: float = 1.8,
                 patience: int = 3, alpha: float = 0.5):
        self.pods: List[PodStats] = [PodStats() for _ in range(num_pods)]
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.reassignments: List[int] = []

    def heartbeat(self, durations: Dict[int, float]) -> List[int]:
        """Feed per-pod step durations; returns pods newly marked out."""
        for pid, d in durations.items():
            p = self.pods[pid]
            p.ewma = d if p.ewma == 0 else \
                (1 - self.alpha) * p.ewma + self.alpha * d
        active = [p for p in self.pods if p.active and p.ewma > 0]
        if len(active) < 2:
            return []
        med = float(np.median([p.ewma for p in active]))
        newly = []
        for pid, p in enumerate(self.pods):
            if not p.active or p.ewma == 0:
                continue
            if p.ewma > self.threshold * med:
                p.strikes += 1
                if p.strikes >= self.patience:
                    p.active = False
                    newly.append(pid)
                    self.reassignments.append(pid)
            else:
                p.strikes = 0
        return newly

    def mark_failed(self, pid: int) -> None:
        self.pods[pid].active = False
        self.reassignments.append(pid)

    @property
    def active_pods(self) -> List[int]:
        return [i for i, p in enumerate(self.pods) if p.active]

    def shard_assignment(self) -> Dict[int, int]:
        """pod id -> shard index among active pods (contiguous)."""
        return {pid: i for i, pid in enumerate(self.active_pods)}

    def membership_bitmap(self) -> int:
        bm = 0
        for pid in self.active_pods:
            bm |= 1 << pid
        return bm
