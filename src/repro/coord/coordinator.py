"""ConsensusCoordinator: the BW-Raft control plane for multi-pod training.

Each training pod is a voter; checkpoint commits, membership views and
scale decisions flow through the replicated log, so every pod derives the
same view after any failure (restart = read the last committed
CKPT_COMMIT).  Observers double as inference replicas (`repro.coord.
elastic`); secretaries carry the checkpoint-manifest fan-out exactly as
they carry AppendEntries in the KV service.

In this container the cluster is the in-process simulator; on real
hardware each jax process would run one node with the same record schema
(launch/cluster.py documents the boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.cluster_config import ClusterConfig
from repro.core.runtime import BWRaftSim
from repro.coord import log_records as rec
from repro.kvstore.service import BWKVService, Timeout


@dataclasses.dataclass
class CommittedCheckpoint:
    step: int
    digest_tag: int
    revision: int


class ConsensusCoordinator:
    def __init__(self, cfg: ClusterConfig, *, seed: int = 0,
                 sim: Optional[BWRaftSim] = None):
        self.cfg = cfg
        self.sim = sim or BWRaftSim(cfg, mode="bwraft", write_rate=0.0,
                                    read_rate=0.0, seed=seed,
                                    manage_resources=False)
        self.kv = BWKVService(self.sim)
        self._last: Optional[CommittedCheckpoint] = None

    # -- checkpoint commit protocol ------------------------------------ #
    def commit_checkpoint(self, step: int, digest_hex: str
                          ) -> CommittedCheckpoint:
        """Propose CKPT_COMMIT(step, digest); returns once majority-
        replicated.  Raises Timeout if consensus can't be reached."""
        value = rec.pack_ckpt(step, digest_hex)
        key = rec.ControlRecord(rec.RecordType.CKPT_COMMIT, value).key(
            self.cfg.key_space)
        res = self.kv.put(f"__ckpt__", value)
        # __ckpt__ hashes arbitrarily; also store under the typed key for
        # crash recovery via state-machine read
        self._put_typed(rec.RecordType.CKPT_COMMIT, value)
        self._last = CommittedCheckpoint(step, value % 4096, res.revision)
        return self._last

    def _put_typed(self, rtype: rec.RecordType, value: int) -> None:
        kid = rec.record_base(self.cfg.key_space) + int(rtype)
        st = self.sim.state
        import repro.core.state as SM
        lid = int(SM.leader_id(st, self.sim.static))
        if lid < 0:
            self.kv._step(50)
            lid = int(SM.leader_id(self.sim.state, self.sim.static))
        st = self.sim.state
        pos = int(st["log_len"][lid])
        self.sim.state = dict(
            st,
            log_term=st["log_term"].at[lid, pos].set(st["term"][lid]),
            log_key=st["log_key"].at[lid, pos].set(kid),
            log_val=st["log_val"].at[lid, pos].set(value),
            log_len=st["log_len"].at[lid].set(pos + 1),
            entry_submit_t=st["entry_submit_t"].at[pos].set(st["tick"]),
        )
        # drive ticks until committed
        t = 0
        while int(self.sim.state["commit_len"].max()) <= pos and t < 400:
            self.kv._step(1)
            t += 1

    def last_committed_checkpoint(self) -> Optional[Tuple[int, int]]:
        """(step, digest_tag) from the replicated state machine — the
        restart path reads this, never local disk state."""
        import repro.core.state as SM
        st = self.sim.state
        kid = rec.record_base(self.cfg.key_space) + \
            int(rec.RecordType.CKPT_COMMIT)
        lid = int(SM.leader_id(st, self.sim.static))
        node = lid if lid >= 0 else 0
        value = int(st["kv"][node, kid])
        if value == 0:
            return None
        return rec.unpack_ckpt(value)

    # -- membership / elasticity ---------------------------------------- #
    def commit_membership(self, alive_bitmap: int) -> None:
        self._put_typed(rec.RecordType.MEMBERSHIP,
                        rec.pack_membership(alive_bitmap))

    def membership(self) -> int:
        import repro.core.state as SM
        st = self.sim.state
        kid = rec.record_base(self.cfg.key_space) + \
            int(rec.RecordType.MEMBERSHIP)
        lid = max(int(SM.leader_id(st, self.sim.static)), 0)
        return int(st["kv"][lid, kid])

    def commit_scale(self, k_s: int, k_o: int) -> None:
        self._put_typed(rec.RecordType.SCALE, rec.pack_scale(k_s, k_o))

    # -- pod failure ----------------------------------------------------- #
    def kill_pod(self, pod: int) -> None:
        """Simulate a voter-pod failure (e.g. the coordinator/leader)."""
        st = self.sim.state
        import jax.numpy as jnp
        alive = st["alive"].at[pod].set(False)
        self.sim.state = dict(st, alive=alive)

    def revive_pod(self, pod: int) -> None:
        st = self.sim.state
        import repro.core.state as SM
        self.sim.state = dict(
            st,
            alive=st["alive"].at[pod].set(True),
            role=st["role"].at[pod].set(SM.FOLLOWER))

    def wait_for_leader(self, max_ticks: int = 600) -> int:
        import repro.core.state as SM
        t = 0
        while t < max_ticks:
            lid = int(SM.leader_id(self.sim.state, self.sim.static))
            if lid >= 0:
                # classic Raft: a new leader commits a no-op of its own term
                # so prior-term entries (e.g. CKPT_COMMIT) become committed
                # and applied under the new leadership (§5.4.2)
                self._put_typed(rec.RecordType.EPOCH_MARK,
                                int(self.sim.state["tick"]))
                return lid
            self.kv._step(5)
            t += 5
        raise Timeout("no leader")
