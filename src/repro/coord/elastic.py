"""Elastic observer pool: serving replicas on preemptible capacity.

The serving analogue of the paper's observers: stateless replicas answer
read (inference) requests against the last *committed* checkpoint; any
number may be revoked at any time (Property 3.4 — state irrelevancy), so
requests re-route to surviving replicas/followers.  The pool scales with
Algorithm 1's observer decision.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import manager as mgr
from repro.core.cluster_config import ClusterConfig


@dataclasses.dataclass
class Replica:
    rid: int
    site: int
    ckpt_step: int                      # checkpoint it serves (readindex)
    alive: bool = True
    queue: int = 0


class ElasticObserverPool:
    """Routes batched requests across replicas; scales via Algorithm 1."""

    def __init__(self, cfg: ClusterConfig, *, capacity_per_replica: int = 8,
                 seed: int = 0):
        self.cfg = cfg
        self.capacity = capacity_per_replica
        self.replicas: List[Replica] = []
        self.rng = np.random.default_rng(seed)
        self._next_id = 0
        self.reads_prev = 0
        self.committed_step = -1
        self.dropped = 0
        self.served = 0
        self.rerouted = 0

    # ------------------------------------------------------------------ #
    def set_committed(self, step: int) -> None:
        self.committed_step = step

    def add_replicas(self, n: int) -> None:
        for _ in range(n):
            self.replicas.append(Replica(
                rid=self._next_id,
                site=int(self.rng.integers(0, self.cfg.num_sites)),
                ckpt_step=self.committed_step))
            self._next_id += 1

    def remove_replicas(self, n: int) -> None:
        for r in sorted((r for r in self.replicas if r.alive),
                        key=lambda r: r.queue)[:n]:
            r.alive = False

    def revoke_random(self, p: float) -> int:
        killed = 0
        for r in self.replicas:
            if r.alive and self.rng.uniform() < p:
                r.alive = False
                killed += 1
        return killed

    @property
    def alive(self) -> List[Replica]:
        # a replica can only serve if it has caught up to the committed
        # checkpoint (the readindex rule)
        return [r for r in self.replicas if r.alive]

    # ------------------------------------------------------------------ #
    def route(self, n_requests: int) -> Dict[int, int]:
        """Assign a batch of requests across fresh replicas; returns
        {rid: count}.  Requests overflowing total capacity stay queued at
        the followers (counted as rerouted)."""
        fresh = [r for r in self.alive if r.ckpt_step >= self.committed_step]
        for r in self.alive:
            if r.ckpt_step < self.committed_step:
                r.ckpt_step = self.committed_step   # catch-up next round
        if not fresh:
            self.rerouted += n_requests
            return {}
        out: Dict[int, int] = {}
        per = n_requests // len(fresh)
        rem = n_requests - per * len(fresh)
        for i, r in enumerate(fresh):
            take = per + (1 if i < rem else 0)
            cap = self.capacity * 4 - r.queue
            take2 = max(min(take, cap), 0)
            self.rerouted += take - take2
            r.queue += take2
            out[r.rid] = take2
        return out

    def serve_tick(self) -> int:
        done = 0
        for r in self.alive:
            s = min(r.queue, self.capacity)
            r.queue -= s
            done += s
        self.served += done
        return done

    # ------------------------------------------------------------------ #
    def autoscale(self, reads_now: int, writes_now: int,
                  budget: float, spot_price: float,
                  on_demand_price: float) -> mgr.PeekDecision:
        """Run the paper's Algorithm 1 on serving-load statistics."""
        stats = mgr.PeekStats(
            reads_prev=self.reads_prev, reads_now=reads_now,
            writes_now=writes_now,
            followers_per_site=[s.followers for s in self.cfg.sites],
            k_s=0, k_o=len(self.alive),
            budget=budget, spot_price=spot_price,
            on_demand_price=on_demand_price)
        dec = mgr.algorithm1(self.cfg, stats)
        if dec.dk_o > 0:
            self.add_replicas(dec.dk_o)
        elif dec.dk_o < 0:
            self.remove_replicas(-dec.dk_o)
        self.reads_prev = reads_now
        return dec
