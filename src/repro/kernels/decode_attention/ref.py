"""Oracle for flash-decode (mask + full softmax)."""
import jax
import jax.numpy as jnp


def decode_ref(q, k_cache, v_cache, cache_len):
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    k = jnp.repeat(k_cache, H // KV, axis=2) if KV != H else k_cache
    v = jnp.repeat(v_cache, H // KV, axis=2) if KV != H else v_cache
    s = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) / (hd ** 0.5)
    T = k.shape[1]
    valid = (jnp.arange(T)[None] < cache_len[:, None])[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthk->bshk", p,
                      v.astype(jnp.float32)).astype(q.dtype)
