"""jit'd public wrapper for the decode-attention kernel."""
import functools
import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512):
    interpret = jax.default_backend() != "tpu"
    return decode_attention_kernel(q, k_cache, v_cache, cache_len,
                                   block_k=block_k, interpret=interpret)
