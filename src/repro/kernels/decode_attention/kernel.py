"""Pallas TPU flash-decode: single query token vs a long KV cache.

Grid: (batch, heads, num_kv_blocks); the KV-block axis is sequential with
running (max, denom, acc) scratch — the kernel analogue of the
sequence-sharded decode path in repro.models.attention (there the
partial-softmax combine happens across devices; here across VMEM tiles).
A length mask handles caches filled to `cache_len < T`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, block_k: int, num_kv: int, sm_scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                    # (1, hd)
    k = k_ref[0, 0]                    # (block_k, hd)
    v = v_ref[0, 0]
    clen = len_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale    # (1, bk)
    pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(pos < clen, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, cache_len, *,
                            block_k: int = 512, interpret: bool = True):
    """q: (B,1,H,hd); caches: (B,T,KV,hd); cache_len: (B,) int32."""
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    group = H // KV
    bk = min(block_k, T)
    assert T % bk == 0
    nk = T // bk
    qt = q.transpose(0, 2, 1, 3)                   # (B,H,1,hd)
    kt = k_cache.transpose(0, 2, 1, 3)             # (B,KV,T,hd)
    vt = v_cache.transpose(0, 2, 1, 3)
    kernel = functools.partial(_decode_kernel, block_k=bk, num_kv=nk,
                               sm_scale=1.0 / (hd ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, cache_len.astype(jnp.int32))
    return out.transpose(0, 2, 1, 3)
