"""Reference twin of the anti-entropy sync kernel — the gather/argmax
formulation lifted verbatim from `core/step.py:anti_entropy_step`
(DESIGN.md §13), at the *unpadded* op signature (ops.py owns padding
and the uint32<->int32 digest bitcast).  Kernel == ref
**bit-identically** is the layer's test invariant (DESIGN.md §8,
`tests/test_wide_kernels.py`) — int32 in, int32 out, no tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp


def ae_sync_ref(dobs_alive, dobs_fol, dobs_applied, dobs_term,
                dobs_digest, dobs_synced_t, ae_phase, dobs_site,
                alive, is_voter, applied_len, term, applied_digest,
                site, site_rtt, tick, ae_interval):
    """Batched anti-entropy round (XLA gather form).

    Observer vectors (O,); node vectors (N,); site_rtt (S, S);
    scalars tick / ae_interval.  `dobs_digest`/`applied_digest` are
    int32 views of the uint32 digests (the bitcast happens in ops.py).
    Returns (dobs_applied, dobs_term, dobs_digest, dobs_synced_t)."""
    N = alive.shape[0]
    fol_c = jnp.clip(dobs_fol, 0, N - 1)
    fol_ok = (dobs_fol >= 0) & alive[fol_c] & is_voter[fol_c]
    alive_voter = is_voter & alive
    any_voter = jnp.any(alive_voter)
    fallback = jnp.argmax(alive_voter)
    eff = jnp.where(fol_ok, fol_c, fallback)
    interval = jnp.maximum(ae_interval, 1)
    due = (dobs_alive != 0) & (fol_ok | any_voter) & \
        (jnp.mod(tick + ae_phase, interval) == 0)
    src_applied = applied_len[eff]
    adopt = due & (src_applied >= dobs_applied)
    applied = jnp.where(adopt, src_applied, dobs_applied)
    out_term = jnp.where(adopt, term[eff], dobs_term)
    out_digest = jnp.where(adopt, applied_digest[eff], dobs_digest)
    hop = site_rtt[dobs_site, site[eff]]
    synced = jnp.where(due, tick - hop, dobs_synced_t)
    return applied, out_term, out_digest, synced
