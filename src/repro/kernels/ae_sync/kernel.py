"""Pallas kernel for the digest-tier anti-entropy round
(core/step.py `anti_entropy_step`, DESIGN.md §13; kernel layer §8).

One fused pass over the (1, Op) lane-tiled observer rows computes, all
in-register:

  * the due rule `(tick + ae_phase[o]) % max(ae_interval, 1) == 0`
    gated on slot liveness and source availability,
  * the any-live-voter fallback: the wired follower (`dobs_fol`) when
    it is an alive voter, else the FIRST alive voter (a min-index
    reduction over the node lanes — bit-identical to `jnp.argmax` on a
    boolean mask),
  * the monotone adoption of the source's (applied_len, term,
    applied_digest) triple — an observer never regresses,
  * the sync-hop RTT aging: `synced = tick - site_rtt[dobs_site,
    site[src]]`, the site-pair matrix gathered through its flattened
    (1, S*S) row by a fused one-hot over `dobs_site * S + site[src]`.

Gathers from node rows by per-observer indices are one-hot masked sums
over (Np, Op) — exactly one node row matches per observer lane, so the
sum reproduces the XLA gather bit-for-bit (including the uint32 digest,
which travels bitcast to int32).  Column vectors come from lane rows by
a diagonal pick (the TPU-safe vector transpose).  Padded observer lanes
arrive with `dobs_alive == 0` (never due — passthrough), padded node
lanes with `alive == 0` (never a voter, never a source: `dobs_fol`
clips to the REAL N, passed statically) — the masking contract; ops.py
pads, callers never see padded lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _iota2(shape, dim):
    # TPU needs >=2D iota (pallas guide: 1D iota fails to compile)
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _ae_sync_kernel(tick_ref, interval_ref,
                    dalive_ref, fol_ref, dapplied_ref, dterm_ref,
                    ddigest_ref, dsynced_ref, phase_ref, dsite_ref,
                    alive_ref, voter_ref, applied_ref, term_ref,
                    digest_ref, site_ref, srtt_ref,
                    out_applied_ref, out_term_ref, out_digest_ref,
                    out_synced_ref,
                    *, true_n: int, true_s: int):
    np_ = alive_ref.shape[1]
    fp = srtt_ref.shape[1]
    op = fol_ref.shape[1]
    tick = tick_ref[0, 0]
    interval = jnp.maximum(interval_ref[0, 0], 1)

    ids_n = _iota2((1, np_), 1)
    diag_n = _iota2((np_, np_), 0) == _iota2((np_, np_), 1)
    # node lane row (1, Np) -> column (Np, 1): diagonal pick
    col_n = lambda v: jnp.sum(jnp.where(diag_n, v, 0), axis=1,
                              keepdims=True)
    rows_n = _iota2((np_, op), 0)

    av = (alive_ref[...] != 0) & (voter_ref[...] != 0)      # (1, Np)
    any_voter = jnp.sum(av.astype(jnp.int32)) > 0
    # first alive voter == argmax over the boolean mask (0 when none —
    # masked out by `due` just like the XLA form)
    first = jnp.min(jnp.where(av, ids_n, np_))
    fallback = jnp.where(any_voter, first, 0)

    fol = fol_ref[...]                                      # (1, Op)
    fol_c = jnp.clip(fol, 0, true_n - 1)
    av_col = col_n(av.astype(jnp.int32))
    av_at_fol = jnp.sum(jnp.where(rows_n == fol_c, av_col, 0), axis=0,
                        keepdims=True)
    fol_ok = (fol >= 0) & (av_at_fol != 0)
    eff = jnp.where(fol_ok, fol_c, fallback)                # (1, Op)

    hit = rows_n == eff                                     # k == eff_o
    gather = lambda ref: jnp.sum(jnp.where(hit, col_n(ref[...]), 0),
                                 axis=0, keepdims=True)

    due = (dalive_ref[...] != 0) & (fol_ok | any_voter) & \
        (jnp.mod(tick + phase_ref[...], interval) == 0)
    src_applied = gather(applied_ref)
    dapplied = dapplied_ref[...]
    # monotone adoption: never regress the applied index (DESIGN.md §13)
    adopt = due & (src_applied >= dapplied)
    out_applied_ref[...] = jnp.where(adopt, src_applied, dapplied)
    out_term_ref[...] = jnp.where(adopt, gather(term_ref), dterm_ref[...])
    out_digest_ref[...] = jnp.where(adopt, gather(digest_ref),
                                    ddigest_ref[...])

    # sync-hop aging through the flattened site-pair matrix:
    # hop = site_rtt[dobs_site, site[eff]] == srtt_flat[dsite*S + seff]
    seff = gather(site_ref)
    idx = dsite_ref[...] * true_s + seff                    # (1, Op)
    diag_f = _iota2((fp, fp), 0) == _iota2((fp, fp), 1)
    srtt_col = jnp.sum(jnp.where(diag_f, srtt_ref[...], 0), axis=1,
                       keepdims=True)
    hop = jnp.sum(jnp.where(_iota2((fp, op), 0) == idx, srtt_col, 0),
                  axis=0, keepdims=True)
    out_synced_ref[...] = jnp.where(due, tick - hop, dsynced_ref[...])


def ae_sync_kernel(tick, interval, dobs_alive, dobs_fol, dobs_applied,
                   dobs_term, dobs_digest, dobs_synced, ae_phase,
                   dobs_site, alive, is_voter, applied_len, term,
                   applied_digest, site, srtt_flat, *,
                   true_n: int, true_s: int, interpret: bool = True):
    """Fused anti-entropy round over padded operands.

    Observer rows (1, Op) int32; node rows (1, Np) int32; srtt_flat
    (1, Fp) — the row-major flattened site-pair RTT matrix (stride =
    the REAL S, passed statically); scalars (1, 1).  Np / Op / Fp are
    lane multiples (ops.py pads; padded observer lanes have
    dobs_alive == 0, padded node lanes alive == 0).  Returns
    (dobs_applied, dobs_term, dobs_digest, dobs_synced_t) rows."""
    op = dobs_fol.shape[1]
    kernel = functools.partial(_ae_sync_kernel, true_n=true_n,
                               true_s=true_s)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)
    orow = pl.BlockSpec(dobs_fol.shape, lambda i: (0, 0))
    nrow = pl.BlockSpec(alive.shape, lambda i: (0, 0))
    frow = pl.BlockSpec(srtt_flat.shape, lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[scalar, scalar] + [orow] * 8 + [nrow] * 6 + [frow],
        out_specs=[orow] * 4,
        out_shape=[jax.ShapeDtypeStruct((1, op), jnp.int32)] * 4,
        interpret=interpret,
    )(tick, interval, dobs_alive, dobs_fol, dobs_applied, dobs_term,
      dobs_digest, dobs_synced, ae_phase, dobs_site,
      alive, is_voter, applied_len, term, applied_digest, site, srtt_flat)
