"""Public op for the anti-entropy sync kernel: padding, bitcast,
dispatch, fallback.

`core/step.py:anti_entropy_step` calls `ae_sync` when
`backend="pallas"` is resolved (DESIGN.md §8/§13).  The wrapper

  * normalizes observer operands to (1, Op) and node operands to
    (1, Np) lane-tiled int32 rows — padded observer lanes carry
    `dobs_alive == 0` (never due), padded node lanes `alive == 0`
    (never a voter or source); the REAL N and S ride as static bounds,
  * bitcasts the uint32 applied digests to int32 for the kernel and
    back on the way out (one-hot sums preserve the bit pattern),
  * flattens the (S, S) site-pair RTT matrix to a (1, S*S) row so the
    sync-hop gather is a single fused one-hot,
  * compiles the Pallas kernel on TPU and falls back to
    `interpret=True` everywhere else (the `raft_tick` fallback rule),
  * slices the four dobs_* rows back to (O,).

Bit-identical to `ref.py` and to the XLA formulation in
`core/step.py` (test invariant, `tests/test_wide_kernels.py`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ae_sync.kernel import ae_sync_kernel
from repro.kernels.raft_tick.ops import use_interpret

_BLOCK_LANE = 128   # lane multiple for observer/node/site-pair rows


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _row(v, n_pad: int):
    """(X,) vector -> zero-padded (1, n_pad) int32 lane row."""
    v = jnp.asarray(v, jnp.int32)
    return jnp.pad(v, (0, n_pad - v.shape[0]))[None, :]


@jax.jit
def ae_sync(dobs_alive, dobs_fol, dobs_applied, dobs_term, dobs_digest,
            dobs_synced_t, ae_phase, dobs_site, alive, is_voter,
            applied_len, term, applied_digest, site, site_rtt,
            tick, ae_interval):
    """Fused anti-entropy round (DESIGN.md §8/§13).

    Observer vectors (O,); node vectors (N,); site_rtt (S, S) int32;
    scalars tick / ae_interval (cfg_c data — a traced argument, so
    cadence sweeps never recompile).  The digests are uint32.  Returns
    (dobs_applied, dobs_term, dobs_digest, dobs_synced_t)."""
    O = dobs_fol.shape[0]
    N = alive.shape[0]
    S = site_rtt.shape[0]
    Op, Np = _pad_to(O, _BLOCK_LANE), _pad_to(N, _BLOCK_LANE)
    Fp = _pad_to(S * S, _BLOCK_LANE)
    as_i32 = lambda v: jax.lax.bitcast_convert_type(
        jnp.asarray(v, jnp.uint32), jnp.int32)
    srtt_flat = jnp.asarray(site_rtt, jnp.int32).reshape(-1)
    scalar = lambda s: jnp.asarray(s, jnp.int32).reshape(1, 1)
    out = ae_sync_kernel(
        scalar(tick), scalar(ae_interval),
        _row(dobs_alive, Op), _row(dobs_fol, Op), _row(dobs_applied, Op),
        _row(dobs_term, Op), _row(as_i32(dobs_digest), Op),
        _row(dobs_synced_t, Op), _row(ae_phase, Op), _row(dobs_site, Op),
        _row(alive, Np), _row(is_voter, Np), _row(applied_len, Np),
        _row(term, Np), _row(as_i32(applied_digest), Np), _row(site, Np),
        _row(srtt_flat, Fp),
        true_n=N, true_s=S, interpret=use_interpret())
    applied, oterm, odigest, synced = (v[0, :O] for v in out)
    return applied, oterm, jax.lax.bitcast_convert_type(
        odigest, jnp.uint32), synced
