"""Public op for the leader fan-out kernel: padding, dispatch, fallback.

`core/step.py:leader_step` calls `leader_fanout` when
`backend="pallas"` is resolved (DESIGN.md §8).  The wrapper

  * normalizes per-node operands to (1, Np) lane-tiled int32 rows and
    the RTT matrix to (Np, Np), Np a lane multiple — padded lanes carry
    `alive == 0`, which zeroes every ship/budget/rank contribution
    (masking contract; see kernel.py),
  * compiles the Pallas kernel on TPU and falls back to
    `interpret=True` everywhere else (the `raft_tick` fallback rule),
  * slices the app_* rows back to (N,) and the work delta to a scalar.

Bit-identical to `ref.py` and to the XLA formulation in
`core/step.py` (test invariant, `tests/test_wide_kernels.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import state as _state
from repro.kernels.leader_fanout import kernel as _k
from repro.kernels.leader_fanout.kernel import leader_fanout_kernel
from repro.kernels.raft_tick.ops import use_interpret

_BLOCK_LANE = 128   # node lane multiple: the (1, Np) row tile width

# the kernel mirrors the role constants to stay import-light; pin them
assert (_k.FOLLOWER, _k.CANDIDATE, _k.SECRETARY) == \
    (_state.FOLLOWER, _state.CANDIDATE, _state.SECRETARY)


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _row(v, n_pad: int):
    """(N,) vector -> zero-padded (1, n_pad) int32 lane row."""
    v = jnp.asarray(v, jnp.int32)
    return jnp.pad(v, (0, n_pad - v.shape[0]))[None, :]


@functools.partial(jax.jit, static_argnames=("msg_budget", "max_ship",
                                             "entries_per_msg"))
def leader_fanout(role, alive, warn_timer, sec_of, match_len,
                  app_arrive_t, app_from_len, app_upto, app_term,
                  app_commit, rtt, lid_c, has_leader, tick,
                  ldr_len, ldr_term, ldr_commit, *,
                  msg_budget: int, max_ship: int, entries_per_msg: int):
    """Fused budgeted fan-out (DESIGN.md §8).

    Per-node vectors (N,); rtt (N, N) int32; scalars lid_c /
    has_leader / tick and the leader's log length, term, and commit
    length; the three message-budget knobs are static python ints (the
    §7 static-shape rule).  Returns (app_arrive_t, app_from_len,
    app_upto, app_term, app_commit, work) with `work` the scalar
    leader-work delta."""
    N = role.shape[0]
    Np = _pad_to(N, _BLOCK_LANE)
    rtt = jnp.asarray(rtt, jnp.int32)
    rtt_p = jnp.pad(rtt, ((0, Np - N), (0, Np - N)))
    scalar = lambda s: jnp.asarray(s, jnp.int32).reshape(1, 1)
    out = leader_fanout_kernel(
        scalar(lid_c), scalar(has_leader), scalar(tick),
        scalar(ldr_len), scalar(ldr_term), scalar(ldr_commit),
        _row(role, Np), _row(alive, Np), _row(warn_timer, Np),
        _row(sec_of, Np), _row(match_len, Np),
        _row(app_arrive_t, Np), _row(app_from_len, Np),
        _row(app_upto, Np), _row(app_term, Np), _row(app_commit, Np),
        rtt_p,
        msg_budget=msg_budget, max_ship=max_ship,
        entries_per_msg=entries_per_msg, interpret=use_interpret())
    arrive, frm, upto, term, commit, work = out
    return (arrive[0, :N], frm[0, :N], upto[0, :N], term[0, :N],
            commit[0, :N], work[0, 0])
