"""Reference twin of the leader fan-out kernel — the gather/scatter HLO
formulation lifted verbatim from `core/step.py:leader_step`'s ship
section ("THE leader bottleneck").  Matches the kernel contract at the
*unpadded* op signature (ops.py owns padding).  Kernel == ref
**bit-identically** is the layer's test invariant (DESIGN.md §8,
`tests/test_wide_kernels.py`) — int32 in, int32 out, no tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.state import CANDIDATE, FOLLOWER, SECRETARY


def leader_fanout_ref(role, alive, warn_timer, sec_of, match_len,
                      app_arrive_t, app_from_len, app_upto, app_term,
                      app_commit, rtt, lid_c, has_leader, tick,
                      ldr_len, ldr_term, ldr_commit, *,
                      msg_budget: int, max_ship: int, entries_per_msg: int):
    """Budgeted AppendEntries fan-out (XLA cumsum/gather form).

    Per-node vectors (N,); rtt (N, N); scalars lid_c (clamped leader
    id), has_leader (bool), tick, and the leader's log length / term /
    commit length.  Returns (app_arrive_t, app_from_len, app_upto,
    app_term, app_commit, work) with `work` the scalar leader-work
    delta — the tuple `step.leader_step` consumes."""
    N = role.shape[0]
    sec = sec_of
    sec_alive = (sec >= 0) & alive[jnp.maximum(sec, 0)] & \
        (role[jnp.maximum(sec, 0)] == SECRETARY) & \
        (warn_timer[jnp.maximum(sec, 0)] < 0)
    relay = jnp.where(sec_alive, sec, lid_c)
    is_target = ((role == FOLLOWER) | (role == CANDIDATE)) & alive & \
        (jnp.arange(N) != lid_c)
    lat = rtt[lid_c, relay] * (relay != lid_c) + rtt[relay, jnp.arange(N)]
    arrive = tick + lat
    want = has_leader & is_target & (app_arrive_t < 0)
    direct = want & (relay == lid_c)
    relayed = want & (relay != lid_c)
    n_sec_msgs = jnp.sum(jnp.any(relayed) &
                         ((role == SECRETARY) & alive & (warn_timer < 0)))
    budget = jnp.maximum(jnp.int32(msg_budget) - n_sec_msgs, 0)
    pending = jnp.maximum(ldr_len - match_len, 0)
    batch_cost = 1 + jnp.minimum(pending, max_ship) // entries_per_msg
    rank = jnp.cumsum(jnp.where(direct, batch_cost, 0))
    ship = relayed | (direct & (rank <= budget))
    out_arrive = jnp.where(ship, arrive, app_arrive_t)
    out_from = jnp.where(ship, match_len, app_from_len)
    out_upto = jnp.where(ship, jnp.minimum(ldr_len, match_len + max_ship),
                         app_upto)
    out_term = jnp.where(ship, ldr_term, app_term)
    out_commit = jnp.where(ship, ldr_commit, app_commit)
    work = jnp.sum(ship & direct) + n_sec_msgs
    return (out_arrive, out_from, out_upto, out_term, out_commit,
            work.astype(jnp.int32))
