"""Pallas kernel for the budgeted AppendEntries fan-out — THE leader
bottleneck (core/step.py `leader_step`, paper §3/Fig 4; DESIGN.md §8).

One fused pass over the (1, Np) lane-tiled node rows computes, entirely
in-register:

  * the secretary/warned handoff mask (`sec_alive`: the batch of
    follower i relays via `sec_of[i]` iff that node is an alive,
    unwarned secretary — DESIGN.md §12),
  * the relay/direct split and the per-target delivery latency
    (leader->relay + relay->target, gathered from the resident (Np, Np)
    RTT matrix by one-hot reductions — no scatter/gather HLO),
  * the payload-scaled batch cost, the rank prefix-sum over direct
    targets (a triangular masked reduction — bit-identical to
    `jnp.cumsum`), and the budget cut `rank <= msg_budget - n_sec_msgs`,
  * the five app_* select-writes and the leader-work delta.

All gathers are one-hot masked sums over the node axis: exactly one row
matches per lane, so the sum reproduces the XLA gather bit-for-bit.
Column vectors come from lane rows by a diagonal pick over (Np, Np) —
the TPU-safe vector transpose.  Padded lanes arrive with `alive == 0`,
which zeroes `want`/`direct`/`relayed`/`dcost`, so they cannot ship,
count toward the budget, or perturb the rank prefix (masking contract;
ops.py pads, callers never see padded lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# role constants mirrored from core/state.py (kernels must not import
# core at trace time; ops.py asserts the pin against the real constants)
FOLLOWER, CANDIDATE, SECRETARY = 0, 1, 3


def _iota2(shape, dim):
    # TPU needs >=2D iota (pallas guide: 1D iota fails to compile)
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _leader_fanout_kernel(lid_ref, has_ref, tick_ref, llen_ref, lterm_ref,
                          lcommit_ref,
                          role_ref, alive_ref, warn_ref, sec_ref, match_ref,
                          arrive_ref, from_ref, upto_ref, term_ref,
                          commit_ref, rtt_ref,
                          out_arrive_ref, out_from_ref, out_upto_ref,
                          out_term_ref, out_commit_ref, work_ref,
                          *, msg_budget: int, max_ship: int,
                          entries_per_msg: int):
    np_ = role_ref.shape[1]
    lid = lid_ref[0, 0]
    has = has_ref[0, 0] != 0
    tick = tick_ref[0, 0]

    ids = _iota2((1, np_), 1)                              # lane = node id
    rows = _iota2((np_, np_), 0)
    diag = rows == _iota2((np_, np_), 1)
    # lane row (1, Np) -> column (Np, 1): diagonal pick (vector transpose)
    col = lambda v: jnp.sum(jnp.where(diag, v, 0), axis=1, keepdims=True)

    role = role_ref[...]
    alive = alive_ref[...] != 0
    warn = warn_ref[...]
    sec = sec_ref[...]
    match = match_ref[...]
    arrive0 = arrive_ref[...]

    # secretary/warned handoff mask in-register (DESIGN.md §12): node k
    # qualifies as a relay iff alive, SECRETARY-role, and unwarned
    q = alive & (role == SECRETARY) & (warn < 0)           # (1, Np)
    secc = jnp.maximum(sec, 0)
    hit_sec = rows == secc                                 # k == sec_of[i]
    q_at_sec = jnp.sum(jnp.where(hit_sec, col(q.astype(jnp.int32)), 0),
                       axis=0, keepdims=True)
    sec_alive = (sec >= 0) & (q_at_sec != 0)
    to_sec = sec_alive & (secc != lid)                     # relay != leader
    relay = jnp.where(sec_alive, secc, lid)

    is_target = ((role == FOLLOWER) | (role == CANDIDATE)) & alive & \
        (ids != lid)
    want = has & is_target & (arrive0 < 0)
    direct = want & ~to_sec
    relayed = want & to_sec

    any_rel = jnp.sum(relayed.astype(jnp.int32)) > 0
    n_sec = jnp.where(any_rel, jnp.sum(q.astype(jnp.int32)), 0)
    budget = jnp.maximum(jnp.int32(msg_budget) - n_sec, 0)

    # payload-scaled batch cost and the rank prefix over direct targets:
    # rank_i = sum_{k <= i} dcost_k, a triangular masked reduction —
    # the in-register form of the XLA cumsum (integer math, exact)
    pending = jnp.maximum(llen_ref[0, 0] - match, 0)
    cost = 1 + jnp.minimum(pending, max_ship) // entries_per_msg
    dcost = jnp.where(direct, cost, 0)
    tri = rows <= _iota2((np_, np_), 1)                    # k <= i
    rank = jnp.sum(jnp.where(tri, col(dcost), 0), axis=0, keepdims=True)
    ship = relayed | (direct & (rank <= budget))

    # delivery latency: rtt[lid, relay_i] * (relay_i != lid) +
    # rtt[relay_i, i], both gathered by one-hot row reductions
    rtt = rtt_ref[...]
    hit_rel = rows == relay                                # k == relay_i
    r1 = jnp.sum(jnp.where(hit_rel, rtt, 0), axis=0, keepdims=True)
    row_lid = jnp.sum(jnp.where(rows == lid, rtt, 0), axis=0, keepdims=True)
    r0 = jnp.sum(jnp.where(hit_rel, col(row_lid), 0), axis=0, keepdims=True)
    lat = r0 * to_sec.astype(jnp.int32) + r1

    ship_i = ship
    out_arrive_ref[...] = jnp.where(ship_i, tick + lat, arrive0)
    out_from_ref[...] = jnp.where(ship_i, match, from_ref[...])
    out_upto_ref[...] = jnp.where(
        ship_i, jnp.minimum(llen_ref[0, 0], match + max_ship), upto_ref[...])
    out_term_ref[...] = jnp.where(ship_i, lterm_ref[0, 0], term_ref[...])
    out_commit_ref[...] = jnp.where(ship_i, lcommit_ref[0, 0],
                                    commit_ref[...])
    # leader work: direct ships + one aggregated message per secretary
    work_ref[0, 0] = jnp.sum((ship & direct).astype(jnp.int32)) + n_sec


def leader_fanout_kernel(lid, has_leader, tick, ldr_len, ldr_term,
                         ldr_commit, role, alive, warn_timer, sec_of,
                         match_len, app_arrive_t, app_from_len, app_upto,
                         app_term, app_commit, rtt, *,
                         msg_budget: int, max_ship: int,
                         entries_per_msg: int, interpret: bool = True):
    """Fused budgeted fan-out over padded operands.

    Per-node vectors (1, Np) int32 with Np a lane multiple (ops.py
    pads; padded lanes have alive == 0); rtt (Np, Np); scalars (1, 1).
    Returns (app_arrive_t, app_from_len, app_upto, app_term, app_commit,
    work) — the five shipped-batch rows plus the (1, 1) leader-work
    delta."""
    np_ = role.shape[1]
    kernel = functools.partial(_leader_fanout_kernel, msg_budget=msg_budget,
                               max_ship=max_ship,
                               entries_per_msg=entries_per_msg)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)
    row = pl.BlockSpec((1, np_), lambda i: (0, 0))
    mat = pl.BlockSpec((np_, np_), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[scalar] * 6 + [row] * 10 + [mat],
        out_specs=[row] * 5 + [
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int32)] * 5 +
                  [jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(lid, has_leader, tick, ldr_len, ldr_term, ldr_commit,
      role, alive, warn_timer, sec_of, match_len,
      app_arrive_t, app_from_len, app_upto, app_term, app_commit, rtt)
