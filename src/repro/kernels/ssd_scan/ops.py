"""jit'd public wrapper for the SSD scan kernel."""
import functools
import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


@jax.jit
def ssd_scan(x, Bm, Cm, dt, A):
    interpret = jax.default_backend() != "tpu"
    return ssd_scan_kernel(x, Bm, Cm, dt, A, interpret=interpret)
