"""Sequential per-token oracle for the SSD scan."""
import jax.numpy as jnp
import numpy as np


def ssd_ref(x, Bm, Cm, dt, A):
    """Same contract as ssd_scan_kernel, computed as the literal
    recurrence h_t = a_t h_{t-1} + dt_t (B_t x_t^T); y_t = C_t . h_t."""
    B, nc, Q, H, P = x.shape
    N = Bm.shape[-1]
    x = np.asarray(x, np.float32).reshape(B, nc * Q, H, P)
    Bf = np.asarray(Bm, np.float32).reshape(B, nc * Q, N)
    Cf = np.asarray(Cm, np.float32).reshape(B, nc * Q, N)
    dtf = np.asarray(dt, np.float32).reshape(B, nc * Q, H)
    Af = np.asarray(A, np.float32)
    h = np.zeros((B, H, P, N), np.float32)
    ys = np.zeros((B, nc * Q, H, P), np.float32)
    for t in range(nc * Q):
        a = np.exp(Af[None, :] * dtf[:, t])               # (B,H)
        upd = (dtf[:, t, :, None] * x[:, t])[..., None] * \
            Bf[:, t, None, None, :]                       # (B,H,P,N)
        h = h * a[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, Cf[:, t])
    return ys.reshape(B, nc, Q, H, P), h
