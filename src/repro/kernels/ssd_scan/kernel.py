"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, heads, num_chunks) — the chunk axis runs sequentially,
carrying the inter-chunk SSM state (head_dim x d_state) in VMEM scratch.
Each step does the intra-chunk quadratic piece (two MXU matmuls over the
(Q,Q) decay-masked score matrix) plus the state update — the TPU-native
SSD formulation (matmuls, not elementwise scans).

Inputs (pre-projected, pre-conv, pre-activation — the block does that):
  x:  (B, nc, Q, H, P)   dt-scaled inputs
  Bm: (B, nc, Q, N)
  Cm: (B, nc, Q, N)
  dt: (B, nc, Q, H)      softplus'd
  A:  (H,)               -exp(A_log), i.e. negative decay rate
Outputs: y: (B, nc, Q, H, P), final state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, state_out_ref,
                h_ref, *, Q: int, num_chunks: int):
    ci = pl.program_id(2)
    h_id = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, :, 0, :]           # (Q, P)
    Bm = b_ref[0, 0]                   # (Q, N)
    Cm = c_ref[0, 0]                   # (Q, N)
    dt = dt_ref[0, 0, :, 0]            # (Q,)
    a = a_ref[0]                       # scalar: -exp(A_log) for this head

    loga = a * dt                                    # (Q,) negative
    cs = jnp.cumsum(loga)                            # (Q,)
    # intra-chunk: w[i,j] = exp(cs_i - cs_j) * dt_j  for i >= j
    diff = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)           # (Q, Q)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, Q)
    w = scores * L * dt[None, :]
    y_diag = jax.lax.dot_general(
        w.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, P)

    # off-diagonal: y_off_i = exp(cs_i) * C_i . h_prev
    h_prev = h_ref[...]                              # (P, N)
    y_off = jax.lax.dot_general(
        Cm.astype(jnp.float32), h_prev,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, P)
    y_off = y_off * jnp.exp(cs)[:, None]
    y_ref[0, 0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h = decay * h_prev + sum_j exp(cs_Q - cs_j) dt_j x_j B_j^T
    decay_chunk = jnp.exp(cs[-1])
    wB = Bm * (jnp.exp(cs[-1] - cs) * dt)[:, None]   # (Q, N)
    s_chunk = jax.lax.dot_general(
        x, wB.astype(x.dtype), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (P, N)
    h_ref[...] = h_prev * decay_chunk + s_chunk

    @pl.when(ci == num_chunks - 1)
    def _out():
        state_out_ref[0, 0] = h_ref[...]


def ssd_scan_kernel(x, Bm, Cm, dt, A, *, interpret: bool = True):
    """x:(B,nc,Q,H,P), Bm/Cm:(B,nc,Q,N), dt:(B,nc,Q,H), A:(H,) ->
    (y:(B,nc,Q,H,P), state:(B,H,P,N))."""
    B, nc, Q, H, P = x.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, Q=Q, num_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, Bm, Cm, dt.astype(jnp.float32), A.astype(jnp.float32))
    return y, state
