"""Public op for the grouped digest reduction: packing, padding,
dispatch, fallback.

`core/fleet.py:_group_digest` calls `group_reduce` when
`backend="pallas"` is resolved (DESIGN.md §8/§9).  The wrapper

  * packs the int digest leaves (counters + unit-bin histograms) into
    one (B, Fi) int32 matrix and the float leaves into a (B, Ff)
    float32 matrix — sums and maxes share the float matrix, the kernel
    reduces both ways and callers slice what they packed,
  * pads B to a sublane multiple with dropped rows (segment id == G,
    the masking rule that also drops ungrouped members), F to lane
    multiples, and G to a sublane multiple,
  * compiles the Pallas kernel on TPU and falls back to
    `interpret=True` everywhere else (the `raft_tick` fallback rule),
  * slices back to (G, ...) leaves.

Bit-identical to `ref.py` (the segment-op formulation kept in
`core/fleet.py` as the XLA path) — test invariant,
`tests/test_wide_kernels.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.group_digest.kernel import group_reduce_kernel
from repro.kernels.raft_tick.ops import use_interpret

_BLOCK_B = 8        # member-row sublane multiple (the grid axis)
_BLOCK_LANE = 128   # packed-leaf lane multiple


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("n_groups",))
def group_reduce(gids, int_mat, flt_mat, *, n_groups: int):
    """Blockwise masked group reduction (DESIGN.md §8/§9).

    gids (B,) int32 — ungrouped members carry `n_groups` and drop;
    int_mat (B, Fi) int32; flt_mat (B, Ff) float32.  Returns
    (g_int (G, Fi) sums, g_sum (G, Ff) sums, g_max (G, Ff) maxes),
    bit-identical to the segment-op twins including float order."""
    B, Fi = int_mat.shape
    Ff = flt_mat.shape[1]
    Bp = _pad_to(B, _BLOCK_B)
    Fip, Ffp = _pad_to(Fi, _BLOCK_LANE), _pad_to(Ff, _BLOCK_LANE)
    Gp = _pad_to(max(n_groups, 1), _BLOCK_B)
    # padded member rows drop like ungrouped ones: segment id == G
    gids_p = jnp.pad(jnp.asarray(gids, jnp.int32), (0, Bp - B),
                     constant_values=n_groups)[:, None]
    int_p = jnp.pad(jnp.asarray(int_mat, jnp.int32),
                    ((0, Bp - B), (0, Fip - Fi)))
    flt_p = jnp.pad(jnp.asarray(flt_mat, jnp.float32),
                    ((0, Bp - B), (0, Ffp - Ff)))
    g_int, g_sum, g_max = group_reduce_kernel(
        gids_p, int_p, flt_p, Gp, block_b=_BLOCK_B,
        interpret=use_interpret())
    return g_int[:n_groups, :Fi], g_sum[:n_groups, :Ff], \
        g_max[:n_groups, :Ff]
