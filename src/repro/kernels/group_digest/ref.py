"""Reference twin of the grouped digest-reduction kernel — the
`segment_sum`/`segment_max` formulation lifted verbatim from
`core/fleet.py:_group_digest` (DESIGN.md §9), at the packed-matrix op
signature (ops.py owns packing and padding).  Kernel == ref
**bit-identically** is the layer's test invariant (DESIGN.md §8,
`tests/test_wide_kernels.py`) — including the float leaves: the kernel
accumulates in ascending member order, which is scatter-add order, so
even non-associative float32 sums match exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def group_reduce_ref(gids, int_mat, flt_mat, *, n_groups: int):
    """Segment-op group reduction at the unpadded signature.

    gids (B,) int32 — ungrouped members carry `n_groups` and are
    dropped; int_mat (B, Fi) int32; flt_mat (B, Ff) float32.  Returns
    (g_int (G, Fi) sums, g_sum (G, Ff) sums, g_max (G, Ff) maxes)."""
    g_int = jax.ops.segment_sum(int_mat, gids, num_segments=n_groups)
    g_sum = jax.ops.segment_sum(flt_mat, gids, num_segments=n_groups)
    g_max = jax.ops.segment_max(flt_mat, gids, num_segments=n_groups)
    return g_int.astype(jnp.int32), g_sum, g_max
