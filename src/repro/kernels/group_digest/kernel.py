"""Pallas kernel for the Multi-Raft grouped digest reduction
(core/fleet.py `_group_digest`, DESIGN.md §9; kernel layer §8).

One blockwise masked reduction over the (B, F) packed digest matrices
replaces the per-leaf `segment_sum`/`segment_max` pair: the grid runs
sequentially over (block_b, F) member blocks, and each block's rows
accumulate into the resident (Gp, F) output by a one-hot group-row
select — ascending member order, so the float sums apply in exactly the
order XLA's scatter-add does (bit-identity invariant, no tolerance).

Masking contract: ragged groups need no shape work (any mix of group
sizes is just the one-hot pattern); dropped members — the ungrouped,
and the rows ops.py pads B up with — carry segment id `n_groups`, which
matches no output row in [0, G) and so contributes nothing (the
segment-ops drop rule).  Empty groups come back as 0 for sums and
`-inf` for the float max — exactly `jax.ops.segment_max`'s identity.

Int leaves (counters + unit-bin histograms) and float leaves
(read_lat_sum / cost_delta sums, read_lat_max max) travel as separate
matrices so integer exactness never rides through float lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _iota2(shape, dim):
    # TPU needs >=2D iota (pallas guide: 1D iota fails to compile)
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _group_reduce_kernel(gid_ref, int_ref, flt_ref,
                         out_int_ref, out_sum_ref, out_max_ref,
                         *, block_b: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        out_int_ref[...] = jnp.zeros_like(out_int_ref)
        out_sum_ref[...] = jnp.zeros_like(out_sum_ref)
        out_max_ref[...] = jnp.full_like(out_max_ref, -jnp.inf)

    gid = gid_ref[:, 0]                                    # (block_b,)
    rows_g = _iota2((out_int_ref.shape[0], 1), 0)          # (Gp, 1)
    # ascending member order: grid blocks ascend and the in-block loop
    # unrolls ascending, so float accumulation order == scatter-add order
    for r in range(block_b):
        hit = rows_g == gid[r]                             # (Gp, 1)
        out_int_ref[...] += jnp.where(hit, int_ref[r, :][None, :], 0)
        frow = flt_ref[r, :][None, :]
        out_sum_ref[...] += jnp.where(hit, frow, 0.0)
        out_max_ref[...] = jnp.where(
            hit, jnp.maximum(out_max_ref[...], frow), out_max_ref[...])


def group_reduce_kernel(gids, int_mat, flt_mat, n_groups_pad: int, *,
                        block_b: int = 8, interpret: bool = True):
    """Blockwise masked group reduction over padded operands.

    gids (Bp, 1) int32 (dropped rows carry an id >= the real G);
    int_mat (Bp, Fi) int32; flt_mat (Bp, Ff) float32; Bp % block_b == 0,
    lane dims are lane multiples, n_groups_pad a sublane multiple
    (ops.py pads).  Returns (g_int (Gp, Fi), g_sum (Gp, Ff),
    g_max (Gp, Ff)) — sums for every lane, max separately, callers
    slice the leaves they packed."""
    Bp, Fi = int_mat.shape
    Ff = flt_mat.shape[1]
    nB = Bp // block_b
    kernel = functools.partial(_group_reduce_kernel, block_b=block_b)
    blk = lambda w: pl.BlockSpec((block_b, w), lambda b: (b, 0))
    out = lambda w, d: pl.BlockSpec((n_groups_pad, w), lambda b: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nB,),
        in_specs=[pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
                  blk(Fi), blk(Ff)],
        out_specs=[out(Fi, jnp.int32), out(Ff, jnp.float32),
                   out(Ff, jnp.float32)],
        out_shape=[jax.ShapeDtypeStruct((n_groups_pad, Fi), jnp.int32),
                   jax.ShapeDtypeStruct((n_groups_pad, Ff), jnp.float32),
                   jax.ShapeDtypeStruct((n_groups_pad, Ff), jnp.float32)],
        interpret=interpret,
    )(gids, int_mat, flt_mat)
