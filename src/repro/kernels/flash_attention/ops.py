"""jit'd public wrapper: TPU -> Mosaic kernel, CPU -> interpret mode."""
import functools
import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True):
    interpret = jax.default_backend() != "tpu"
    return flash_attention_kernel(q, k, v, block_q=block_q, block_k=block_k,
                                  causal=causal, interpret=interpret)
