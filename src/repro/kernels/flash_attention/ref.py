"""Pure-jnp oracle for flash attention (naive full-materialization)."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd). fp32 softmax, GQA via repeat."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) / (hd ** 0.5)
    if causal:
        S_, T_ = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((S_, T_), bool), T_ - S_)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthk->bshk", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
