"""Pallas TPU flash attention (blocked online-softmax, causal, GQA).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the last axis runs
sequentially on TPU, carrying the running max / denominator / accumulator
in VMEM scratch.  BlockSpecs tile q into (block_q, head_dim) and k/v into
(block_k, head_dim) VMEM-resident tiles; head_dim and block sizes should
be multiples of 128 on real hardware (the MXU lane width) — tests sweep
smaller shapes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, num_kv: int, causal: bool,
                  sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                               # (block_q, hd)
    k = k_ref[0, 0]                               # (block_k, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, block_q: int = 128,
                           block_k: int = 128, causal: bool = True,
                           interpret: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) with H % KV == 0. Returns (B,S,H,hd).

    interpret=True runs the kernel body on CPU (validation); on TPU pass
    interpret=False to compile with Mosaic.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    sm_scale = 1.0 / (hd ** 0.5)

    # layout: (B, H, S, hd) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, block_q=bq, block_k=bk, num_kv=nk, causal=causal,
        sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
