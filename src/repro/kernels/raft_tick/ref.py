"""Reference twins of the raft_tick Pallas kernels — PR-1 formulations.

Each function matches the contract of its `kernel.py` twin at the
*unpadded* op signature (ops.py owns padding) and is lifted from the
`reference=True` branches of `core/step.py`: the (N, W) gather + masked
scatter window adopt, the O(L·N) commit-count matrix, and the sequential
apply scatters.  Kernel == ref **bit-identically** is the layer's test
invariant (DESIGN.md §8, `tests/test_raft_tick_kernels.py`) — int32
in, int32 out, no tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp


def log_match_append_ref(log_term, log_key, log_val,
                         ldr_term, ldr_key, ldr_val,
                         log_len, app_from_len, app_upto, due, *, w: int):
    """Follower log-matching + window adopt (PR-1 gather/scatter form).

    log_* (N, L); ldr_* (L,) — the leader's log row; per-node vectors
    (N,); `due` bool.  Returns (out_term, out_key, out_val, new_len,
    accept) with accept int32 — same tuple as the kernel."""
    N, L = log_term.shape
    prev = app_from_len - 1
    prev_c = jnp.clip(prev, 0, L - 1)
    my_prev_term = jnp.take_along_axis(log_term, prev_c[:, None],
                                       axis=1)[:, 0]
    ldr_prev_term = ldr_term[prev_c]
    match = (prev < 0) | (my_prev_term == ldr_prev_term)
    accept = due & match

    base = jnp.where(accept, app_from_len, 0)
    widx = base[:, None] + jnp.arange(w)[None, :]             # (N, W)
    valid = accept[:, None] & (widx < app_upto[:, None]) & (widx < L)
    widx_c = jnp.clip(widx, 0, L - 1)
    rows = jnp.broadcast_to(jnp.arange(N)[:, None], widx.shape)
    put = lambda dst, src: dst.at[
        jnp.where(valid, rows, N), jnp.where(valid, widx_c, L)].set(
        src, mode="drop")
    out_term = put(log_term, ldr_term[widx_c])
    out_key = put(log_key, ldr_key[widx_c])
    out_val = put(log_val, ldr_val[widx_c])

    new_len = jnp.where(accept, jnp.minimum(app_upto, app_from_len + w),
                        log_len)
    new_len = jnp.where(accept & (log_len > new_len) &
                        (my_prev_term == ldr_prev_term),
                        jnp.maximum(log_len, new_len), new_len)
    return out_term, out_key, out_val, new_len, accept.astype(jnp.int32)


def commit_majority_ref(match_len, voter_alive, ldr_term, ldr_cur_term,
                        majority):
    """Commit length by the O(L·N) threshold-count matrix (PR-1 form).

    match_len (N,) int32; voter_alive (N,) bool (voter & alive, the
    in-register mask of the kernel); ldr_term (L,); scalars
    ldr_cur_term / majority.  Returns the scalar commit length."""
    L = ldr_term.shape[0]
    lens = jnp.arange(L) + 1
    counts = jnp.sum((match_len[None, :] >= lens[:, None]) &
                     voter_alive[None, :], axis=1)
    can = counts >= majority
    term_ok = ldr_term == ldr_cur_term
    return jnp.max(jnp.where(can & term_ok, lens, 0))


def apply_last_wins_ref(kv, keys, vals, valid):
    """State-machine apply as A sequential scatters (PR-1 form):
    ascending apply order makes the last committed entry win per key.

    kv (N, K); keys/vals (N, A) int32; valid (N, A) bool.  Out-of-range
    keys drop (scatter mode="drop"), matching the kernel's no-column-
    matches behavior.  Returns the updated (N, K) kv."""
    N, K = kv.shape
    A = keys.shape[1]
    rows = jnp.arange(N)
    for a in range(A):
        kv = kv.at[jnp.where(valid[:, a], rows, N),
                   jnp.where(valid[:, a], keys[:, a], K)].set(
            vals[:, a], mode="drop")
    return kv
