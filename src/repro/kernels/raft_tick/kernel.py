"""Pallas kernels for the BW-Raft consensus-tick hot path.

Three hand-tiled kernels replace the generic gather/scatter HLO of the
per-tick inner loops (`core/step.py`, DESIGN.md §8) — the single-leader
fan-out bottleneck the paper scales around:

  log_match_append   fused follower log-matching: the prev_idx/prev_term
                     check, conflict truncation, and the window append in
                     ONE pass over the (N, L) log block in VMEM.  The L
                     axis runs sequentially so the prev-term gather (a
                     one-hot reduction in-register) completes before any
                     position at or past the append window is written —
                     appends land at positions >= app_from_len > prev.
  commit_majority    the leader commit rule: largest log length l such
                     that a majority of voters report match_len >= l,
                     with the voter/alive mask applied in-register.
                     `count(match >= l)` is non-increasing in l, so the
                     blockwise threshold count is exactly the kth-largest
                     (k = majority) voter match_len of the XLA sort
                     formulation — bit-identical, no sort needed.
  apply_last_wins    the state-machine apply: for each KV column the last
                     valid committed entry in the apply window wins —
                     replacing the dedupe + single-scatter HLO with an
                     in-register select over (N, K) blocks (A is small
                     and static, so the window unrolls in VMEM).

Contracts (DESIGN.md §8): all operands int32; DEAD/padded node slots are
masked by `due`/`valid`/`voter_alive` inputs computed upstream, never
inside the kernels; every kernel is bit-identical to its `ref.py` twin
(the PR-1 formulations lifted from `core/step.py`) — a test invariant
(`tests/test_raft_tick_kernels.py`).  Shape padding to block multiples
happens in `ops.py`; padded rows arrive fully masked and padded columns
can never be selected (append windows and commit lengths are bounded by
the REAL L, passed statically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _iota2(shape, dim):
    # TPU needs >=2D iota (pallas guide: 1D iota fails to compile)
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


# --------------------------------------------------------------------- #
# 1. fused log-match + append
# --------------------------------------------------------------------- #
def _log_match_append_kernel(due_ref, from_ref, upto_ref, len_ref,
                             term_ref, key_ref, val_ref,
                             lterm_ref, lkey_ref, lval_ref,
                             out_term_ref, out_key_ref, out_val_ref,
                             new_len_ref, accept_ref,
                             myprev_scr, ldrprev_scr,
                             *, w: int, true_l: int, n_lblocks: int,
                             block_l: int):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        myprev_scr[...] = jnp.zeros_like(myprev_scr)
        ldrprev_scr[...] = jnp.zeros_like(ldrprev_scr)

    frm = from_ref[:, 0]                                   # (bN,)
    term = term_ref[...]                                   # (bN, bL)
    lterm = lterm_ref[...]                                 # (1, bL)
    cols = l * block_l + _iota2(term.shape, 1)             # (bN, bL)

    # one-hot gather of the log-matching terms at prev = from-1: the hit
    # column is unique, so the masked sum accumulates the exact value
    prev_c = jnp.clip(frm - 1, 0, true_l - 1)
    hit = cols == prev_c[:, None]
    myprev_scr[...] += jnp.sum(jnp.where(hit, term, 0), axis=1,
                               keepdims=True)
    ldrprev_scr[...] += jnp.sum(jnp.where(hit, lterm, 0), axis=1,
                                keepdims=True)

    # the prev-term accumulators are complete for every row whose append
    # window reaches this block: writes happen at cols >= frm > prev, and
    # the L grid axis runs ascending
    due = due_ref[:, 0] != 0
    match = (frm - 1 < 0) | (myprev_scr[:, 0] == ldrprev_scr[:, 0])
    accept = due & match
    hi = jnp.minimum(upto_ref[:, 0], frm + w)
    sel = accept[:, None] & (cols >= frm[:, None]) & (cols < hi[:, None])
    out_term_ref[...] = jnp.where(sel, lterm, term)
    out_key_ref[...] = jnp.where(sel, lkey_ref[...], key_ref[...])
    out_val_ref[...] = jnp.where(sel, lval_ref[...], val_ref[...])

    @pl.when(l == n_lblocks - 1)
    def _finish():
        ln = len_ref[:, 0]
        nl = jnp.where(accept, hi, ln)
        # a matching follower whose log already extends past the shipped
        # window keeps its longer log (same rule as core/step.py)
        nl = jnp.where(accept & (ln > nl) &
                       (myprev_scr[:, 0] == ldrprev_scr[:, 0]),
                       jnp.maximum(ln, nl), nl)
        new_len_ref[...] = nl[:, None]
        accept_ref[...] = accept.astype(jnp.int32)[:, None]


def log_match_append_kernel(log_term, log_key, log_val,
                            ldr_term, ldr_key, ldr_val,
                            log_len, app_from_len, app_upto, due,
                            *, w: int, true_l: int,
                            block_n: int = 8, block_l: int = 128,
                            interpret: bool = True):
    """Fused log-match + append over padded operands.

    log/out arrays (N, L); leader rows (1, L); per-node vectors (N, 1)
    int32 (`due` nonzero = deliverable batch this tick).  N % block_n ==
    0 and L % block_l == 0 (ops.py pads); `true_l` is the unpadded log
    window — clip bound of the prev index, identical to the XLA paths.
    Returns (out_term, out_key, out_val, new_len, accept)."""
    N, L = log_term.shape
    nN, nL = N // block_n, L // block_l
    kernel = functools.partial(_log_match_append_kernel, w=w, true_l=true_l,
                               n_lblocks=nL, block_l=block_l)
    vec = pl.BlockSpec((block_n, 1), lambda n, l: (n, 0))
    mat = pl.BlockSpec((block_n, block_l), lambda n, l: (n, l))
    row = pl.BlockSpec((1, block_l), lambda n, l: (0, l))
    return pl.pallas_call(
        kernel,
        grid=(nN, nL),
        in_specs=[vec, vec, vec, vec, mat, mat, mat, row, row, row],
        out_specs=[mat, mat, mat, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((N, L), jnp.int32)] * 3 +
                  [jax.ShapeDtypeStruct((N, 1), jnp.int32)] * 2,
        scratch_shapes=[pltpu.VMEM((block_n, 1), jnp.int32),
                        pltpu.VMEM((block_n, 1), jnp.int32)],
        interpret=interpret,
    )(due, app_from_len, app_upto, log_len,
      log_term, log_key, log_val, ldr_term, ldr_key, ldr_val)


# --------------------------------------------------------------------- #
# 2. commit majority (kth-largest voter match_len, mask in-register)
# --------------------------------------------------------------------- #
def _commit_majority_kernel(majority_ref, curterm_ref, match_ref, vmask_ref,
                            lterm_ref, commit_ref, best_scr,
                            *, true_l: int, n_lblocks: int, block_l: int):
    l = pl.program_id(0)

    @pl.when(l == 0)
    def _init():
        best_scr[0, 0] = 0

    # voter mask applied in-register: DEAD / non-voter rows count -1
    vmatch = jnp.where(vmask_ref[...] != 0, match_ref[...], -1)   # (N, 1)
    lens = l * block_l + _iota2(lterm_ref.shape, 1) + 1           # (1, bL)
    # counts(l) = #voters with match >= l is non-increasing in l, so
    # `counts >= majority` selects exactly the lens <= the majority-th
    # largest voter match_len — the sort-free order statistic
    counts = jnp.sum((vmatch >= lens).astype(jnp.int32), axis=0,
                     keepdims=True)                               # (1, bL)
    can = counts >= majority_ref[0, 0]
    term_ok = lterm_ref[...] == curterm_ref[0, 0]
    ok = can & term_ok & (lens <= true_l)
    best_scr[0, 0] = jnp.maximum(best_scr[0, 0],
                                 jnp.max(jnp.where(ok, lens, 0)))

    @pl.when(l == n_lblocks - 1)
    def _finish():
        commit_ref[0, 0] = best_scr[0, 0]


def commit_majority_kernel(match_len, voter_alive, ldr_term, ldr_cur_term,
                           majority, *, true_l: int, block_l: int = 128,
                           interpret: bool = True):
    """Largest commit length with majority voter replication.

    match_len/voter_alive (N, 1) int32; ldr_term (1, L) — the leader's
    per-entry terms (commit is restricted to current-term entries, Raft
    §5.4.2); majority/ldr_cur_term (1, 1).  L % block_l == 0; `true_l`
    bounds candidate lengths to the unpadded window.  Returns (1, 1)."""
    N = match_len.shape[0]
    L = ldr_term.shape[1]
    nL = L // block_l
    kernel = functools.partial(_commit_majority_kernel, true_l=true_l,
                               n_lblocks=nL, block_l=block_l)
    scalar = pl.BlockSpec((1, 1), lambda l: (0, 0),
                          memory_space=pltpu.SMEM)
    col = pl.BlockSpec((N, 1), lambda l: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nL,),
        in_specs=[scalar, scalar, col, col,
                  pl.BlockSpec((1, block_l), lambda l: (0, l))],
        out_specs=pl.BlockSpec((1, 1), lambda l: (0, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.int32)],
        interpret=interpret,
    )(majority, ldr_cur_term, match_len, voter_alive, ldr_term)


# --------------------------------------------------------------------- #
# 3. last-wins apply
# --------------------------------------------------------------------- #
def _apply_last_wins_kernel(keys_ref, vals_ref, valid_ref, kv_ref, out_ref,
                            *, n_apply: int, block_k: int):
    k = pl.program_id(1)
    cols = k * block_k + _iota2(kv_ref.shape, 1)          # (bN, bK)
    out = kv_ref[...]
    # ascending apply order: later entries overwrite earlier ones — the
    # in-register form of "dedupe then scatter once" (log order,
    # Property 3.2).  A is small and static, so this unrolls.
    for a in range(n_apply):
        m = (valid_ref[:, a] != 0)[:, None] & \
            (keys_ref[:, a][:, None] == cols)
        out = jnp.where(m, vals_ref[:, a][:, None], out)
    out_ref[...] = out


def apply_last_wins_kernel(kv, keys, vals, valid, *,
                           block_n: int = 8, block_k: int = 128,
                           interpret: bool = True):
    """Apply committed (key, val) windows to the KV rows, last write wins.

    kv (N, K); keys/vals/valid (N, A) int32 — entry a of row i writes
    kv[i, keys[i, a]] = vals[i, a] iff valid[i, a], later a wins.  Keys
    outside [0, K) never match a column — the in-register equivalent of
    scatter mode="drop".  N % block_n == 0, K % block_k == 0."""
    N, K = kv.shape
    A = keys.shape[1]
    nN, nK = N // block_n, K // block_k
    kernel = functools.partial(_apply_last_wins_kernel, n_apply=A,
                               block_k=block_k)
    win = pl.BlockSpec((block_n, A), lambda n, k: (n, 0))
    return pl.pallas_call(
        kernel,
        grid=(nN, nK),
        in_specs=[win, win, win,
                  pl.BlockSpec((block_n, block_k), lambda n, k: (n, k))],
        out_specs=pl.BlockSpec((block_n, block_k), lambda n, k: (n, k)),
        out_shape=jax.ShapeDtypeStruct((N, K), jnp.int32),
        interpret=interpret,
    )(keys, vals, valid, kv)
