"""Public ops for the raft_tick kernels: padding, dispatch, fallback.

The jitted wrappers below are what `core/step.py` calls when
`backend="pallas"` is selected (DESIGN.md §8).  They

  * normalize operands to the kernels' 2D int32 layout,
  * pad N to a sublane multiple and L/K to a lane multiple (padded rows
    arrive fully masked — `due`/`valid`/`voter_alive` pad with 0 — and
    padded columns are unreachable because window/commit bounds use the
    REAL sizes, passed statically),
  * compile the Pallas kernel on TPU and fall back to `interpret=True`
    everywhere else (the fallback rule), so the same tick runs — and
    the tier-1 suite passes — on CPU-only hosts,
  * slice the result back to the caller's shapes.

Each op is bit-identical to its `ref.py` twin and to the XLA
formulations in `core/step.py` (test invariant,
`tests/test_raft_tick_kernels.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.raft_tick.kernel import (apply_last_wins_kernel,
                                            commit_majority_kernel,
                                            log_match_append_kernel)

_BLOCK_N = 8        # int32 sublane multiple
_BLOCK_LANE = 128   # lane width: L and K blocks


def use_interpret() -> bool:
    """interpret=True fallback rule: compile Pallas only on TPU;
    everywhere else the kernels run through the Pallas interpreter —
    inside jit, so they still trace into one XLA program (DESIGN.md §8).
    GPU is deliberately interpret-only for now: the kernels lean on
    TPU-specific pieces (pltpu VMEM/SMEM scratch, sequential grid
    iteration carrying accumulators across L blocks) that the Triton
    lowering does not honor — a Mosaic-GPU port is a ROADMAP item."""
    return jax.default_backend() != "tpu"


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad2(x, rows: int, cols: int):
    """Zero-pad a 2D int32 array up to (rows, cols)."""
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def _col(v, n_pad: int):
    """(N,) vector -> zero-padded (n_pad, 1) int32 column."""
    v = jnp.asarray(v, jnp.int32)
    return jnp.pad(v, (0, n_pad - v.shape[0]))[:, None]


@functools.partial(jax.jit, static_argnames=("w",))
def log_match_append(log_term, log_key, log_val, ldr_term, ldr_key, ldr_val,
                     log_len, app_from_len, app_upto, due, *, w: int):
    """Fused follower log-match + window append (kernel 1, DESIGN.md §8).

    log_* (N, L) int32; ldr_* (L,) — the leader's log row; log_len /
    app_from_len / app_upto (N,) int32; due (N,) bool; w = max_ship.
    Returns (log_term, log_key, log_val, new_len, accept) with accept
    bool — the tuple `step.follower_step` consumes."""
    N, L = log_term.shape
    Np, Lp = _pad_to(N, _BLOCK_N), _pad_to(L, _BLOCK_LANE)
    row = lambda r: _pad2(jnp.asarray(r, jnp.int32)[None, :], 1, Lp)
    out = log_match_append_kernel(
        _pad2(log_term, Np, Lp), _pad2(log_key, Np, Lp),
        _pad2(log_val, Np, Lp),
        row(ldr_term), row(ldr_key), row(ldr_val),
        _col(log_len, Np), _col(app_from_len, Np), _col(app_upto, Np),
        _col(due, Np),
        w=w, true_l=L, block_n=_BLOCK_N, block_l=_BLOCK_LANE,
        interpret=use_interpret())
    out_term, out_key, out_val, new_len, accept = out
    return (out_term[:N, :L], out_key[:N, :L], out_val[:N, :L],
            new_len[:N, 0], accept[:N, 0] != 0)


@jax.jit
def commit_majority(match_len, voter_alive, ldr_term, ldr_cur_term,
                    majority):
    """Majority-replicated commit length (kernel 2, DESIGN.md §8).

    match_len (N,) int32; voter_alive (N,) bool (is_voter & alive — the
    in-register mask; secretaries/observers never count, Property 3.4);
    ldr_term (L,) the leader's per-entry terms; scalars ldr_cur_term and
    majority.  Returns the scalar int32 commit length."""
    N, L = match_len.shape[0], ldr_term.shape[0]
    Np, Lp = _pad_to(N, _BLOCK_N), _pad_to(L, _BLOCK_LANE)
    scalar = lambda s: jnp.asarray(s, jnp.int32).reshape(1, 1)
    commit = commit_majority_kernel(
        _col(match_len, Np), _col(voter_alive, Np),
        _pad2(jnp.asarray(ldr_term, jnp.int32)[None, :], 1, Lp),
        scalar(ldr_cur_term), scalar(majority),
        true_l=L, block_l=_BLOCK_LANE, interpret=use_interpret())
    return commit[0, 0]


@jax.jit
def apply_last_wins(kv, keys, vals, valid):
    """Last-wins state-machine apply (kernel 3, DESIGN.md §8).

    kv (N, K) int32; keys/vals (N, A) int32; valid (N, A) bool.  Entry a
    of row i writes kv[i, keys[i, a]] = vals[i, a] iff valid — ascending
    a, so the LAST committed entry per key wins (log order, Property
    3.2); keys outside [0, K) drop.  Returns the updated (N, K) kv."""
    N, K = kv.shape
    A = keys.shape[1]
    Np, Kp = _pad_to(N, _BLOCK_N), _pad_to(K, _BLOCK_LANE)
    pad_win = lambda x: _pad2(jnp.asarray(x, jnp.int32), Np, A)
    # XLA scatter wraps negative indices once (numpy semantics); the
    # kernel's column match would silently drop them — normalize here so
    # the op stays bit-identical to the scatter formulations
    keys = jnp.asarray(keys, jnp.int32)
    keys = jnp.where(keys < 0, keys + K, keys)
    out = apply_last_wins_kernel(
        _pad2(kv, Np, Kp), pad_win(keys), pad_win(vals), pad_win(valid),
        block_n=_BLOCK_N, block_k=_BLOCK_LANE, interpret=use_interpret())
    return out[:N, :K]
