"""Kernel families for the BW-Raft hot paths (DESIGN.md §8).

Each family is a kernel.py + ref.py + ops.py package: `raft_tick`
(follower log-match + append, commit majority, last-wins apply),
`leader_fanout` (the budgeted AppendEntries ship — THE leader
bottleneck), `group_digest` (the Multi-Raft grouped digest reduction),
and `ae_sync` (digest-tier anti-entropy rounds).  Kernels compile on
TPU and run through the Pallas interpreter elsewhere; every op is
bit-identical to its frozen ref twin and to the XLA formulations in
`core/` (test invariant).
"""
from __future__ import annotations

import jax

BACKENDS = ("auto", "xla", "pallas")


def resolve_backend(backend: str) -> str:
    """The per-platform backend-auto rule (DESIGN.md §8): `"auto"`
    resolves to `"pallas"` on TPU — where the kernels compile and the
    flip is earned — and `"xla"` everywhere else (off-TPU the kernels
    run through the Pallas interpreter, a correctness path, not a fast
    path; BENCH_tick.json marks such timings `interpreted`).
    `"xla"`/`"pallas"` pass through, so the knob stays overridable, and
    callers key their epoch caches on the RESOLVED backend so `"auto"`
    and its resolution share one compiled program."""
    assert backend in BACKENDS, backend
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend
