"""Calibrating the control plane against a market trace (DESIGN.md §10).

Two fits, both against the (S, T) arrays of a `traces.MarketTrace`:

  `calibrate_predictor`  fit `manager.RevocationPredictor` (the SpotTune
                         stand-in Algorithm 1 scores offers with): pick
                         the EWMA alpha minimizing one-step-ahead error
                         on the trace's per-epoch per-site revocation
                         rates, seed the rate vector from the data, and
                         report the residual calibration error.
  `fit_walk`             moment-match the synthetic walk (mean via the
                         sample mean, vol by inverting the walk's
                         residual ``p[t+1] - p[t] - 0.2*(mean - p[t]) =
                         0.15*vol*mean*noise``) so process-mode sweeps
                         can run at trace-calibrated parameters.

Pure NumPy — this is host-side control-plane tooling, like `manager`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.manager import RevocationPredictor
from repro.market.traces import MarketTrace

DEFAULT_ALPHAS = (0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9)


@dataclasses.dataclass
class CalibrationReport:
    """What a fit achieved, for `BENCH_market.json` and the tests."""
    trace: str
    alpha: float                 # chosen EWMA smoothing
    empirical: np.ndarray        # (S,) per-tick revocation hazard
    fitted: np.ndarray           # (S,) predictor rates after the fit
    mae: float                   # mean |fitted - empirical|
    one_step_mse: float          # best one-step-ahead MSE over epochs


def epoch_revocation_rates(trace: MarketTrace, period_ticks: int
                           ) -> np.ndarray:
    """(E, S) per-epoch per-site revocation rates — the fraction of each
    epoch's ticks a site spends revoked, i.e. exactly what the manager's
    per-epoch "peek" observes.  Uses the whole epochs only (the ragged
    tail is dropped); needs at least one full epoch."""
    E = trace.ticks // period_ticks
    assert E >= 1, (trace.ticks, period_ticks)
    r = trace.revoked[:, :E * period_ticks]
    return r.reshape(trace.sites, E, period_ticks).mean(axis=2).T


def calibrate_predictor(trace: MarketTrace, period_ticks: int, *,
                        alphas: Sequence[float] = DEFAULT_ALPHAS,
                        prior: float = 0.02
                        ) -> Tuple[RevocationPredictor, CalibrationReport]:
    """Fit `RevocationPredictor` to a trace: replay the trace's per-epoch
    revocation rates through the EWMA for every candidate alpha, score
    each by one-step-ahead MSE (predict *before* updating — exactly the
    order Algorithm 1 consumes the predictor in), keep the best, and
    report the calibration error of the final rates against the trace's
    overall empirical hazard."""
    obs = epoch_revocation_rates(trace, period_ticks)       # (E, S)
    S = trace.sites
    leased = np.ones(S)

    def replay(alpha: float) -> Tuple[RevocationPredictor, float]:
        p = RevocationPredictor(S, alpha=alpha, prior=prior)
        err = 0.0
        for e in range(obs.shape[0]):
            err += float(np.mean((p.predict() - obs[e]) ** 2))
            p.update(obs[e], leased)
        return p, err / obs.shape[0]

    scored = [(replay(a), a) for a in alphas]
    (predictor, mse), alpha = min(scored, key=lambda t: t[0][1])
    empirical = trace.empirical_revocation_rates()
    report = CalibrationReport(
        trace=trace.name, alpha=float(alpha), empirical=empirical,
        fitted=predictor.predict(),
        mae=float(np.mean(np.abs(predictor.predict() - empirical))),
        one_step_mse=float(mse))
    return predictor, report


def sliding_window_rates(trace: MarketTrace, end_tick: int,
                         window_ticks: int) -> np.ndarray:
    """(S,) empirical revocation rates over the trailing `window_ticks`
    ticks ending at `end_tick` (exclusive), read through the §10 time
    wrap (``t % T``) so a recalibration window keeps sliding on runs
    longer than the trace.  ``end_tick <= 0`` or a window at least the
    trace length degrades to the full-trace rates — the same target
    `calibrate_predictor` fits against."""
    T = trace.ticks
    if end_tick <= 0 or window_ticks >= T:
        return trace.empirical_revocation_rates()
    idx = np.arange(end_tick - window_ticks, end_tick) % T
    return trace.revoked[:, idx].mean(axis=1)


@dataclasses.dataclass(eq=False)
class HazardAwareBid:
    """Per-epoch hazard-aware bidding policy (DESIGN.md §12).

    Maps a per-site revocation hazard to a per-site bid as a multiple
    of the site's mean price: a calm site (hazard 0) bids
    ``high_mult * mean`` (bid up: out-wait transient spikes), a hot
    site (hazard >= `hazard_ref`) bids ``low_mult * mean`` (shed:
    surrender early rather than ride the spike into an unwarned kill),
    with linear interpolation between.  The hazard source is the
    trailing-window trace rates (`sliding_window_rates`) when
    `window_ticks` > 0 and a trace is at hand, else the manager's
    `RevocationPredictor` — the same signal Algorithm 1 peeks.

    Bids are *data*: `runtime.BWRaftSim`/`fleet.FleetSim` call
    `update` once per epoch and write the result into
    ``cfg_c["spot_bid"]``, so sweeping policies never recompiles.
    `eq=False` keeps identity hashing for `fleet.MemberSpec`.
    """
    mean_price: np.ndarray            # (S,) per-site mean prices
    low_mult: float = 1.1             # shed bid at/above hazard_ref
    high_mult: float = 2.5            # bid-up bid at hazard 0
    hazard_ref: float = 0.05          # hazard that pins the shed bid
    window_ticks: int = 0             # 0: predictor; >0: trailing window

    def __post_init__(self):
        self.mean_price = np.atleast_1d(
            np.asarray(self.mean_price, np.float64))

    def bids(self, hazard: np.ndarray) -> np.ndarray:
        """(S,) bids for (S,) hazards by the interpolation rule."""
        frac = np.clip(np.asarray(hazard, np.float64)
                       / max(self.hazard_ref, 1e-9), 0.0, 1.0)
        mult = self.high_mult - frac * (self.high_mult - self.low_mult)
        mean = self.mean_price
        if mean.shape[0] < frac.shape[0]:       # repeat-last, like pads
            mean = np.concatenate(
                [mean, np.full(frac.shape[0] - mean.shape[0], mean[-1])])
        return (mult * mean[:frac.shape[0]]).astype(np.float32)

    def update(self, *, predictor=None, trace: MarketTrace = None,
               end_tick: int = 0, sites: int = 0) -> np.ndarray:
        """Recalibrate and return the (sites,) bid vector for the next
        epoch.  Hazard rows tile onto sites by ``s % len`` (the site
        round-robin rule)."""
        if self.window_ticks > 0 and trace is not None:
            hazard = sliding_window_rates(trace, end_tick,
                                          self.window_ticks)
        elif predictor is not None:
            hazard = np.asarray(predictor.predict())
        else:
            hazard = np.zeros(max(sites, 1))
        S = sites if sites > 0 else hazard.shape[0]
        return self.bids(hazard[np.arange(S) % hazard.shape[0]])


@dataclasses.dataclass
class WalkFit:
    """Moment-matched walk parameters recovered from a price trace."""
    trace: str
    mean: np.ndarray             # (S,) fitted reversion targets
    vol: float                   # fitted relative volatility (pooled)
    vol_per_site: np.ndarray     # (S,)
    # one-step fit quality: 1 - SSE(fitted reversion)/SSE(hold-last-price)
    # — the share of one-step price variance the fitted mean reversion
    # explains beyond predicting "price stays put".  > 0 means the walk
    # structure is present in the trace; ~0 means a driftless random
    # walk fits as well and the recovered mean/vol should be distrusted.
    reversion_r2: float


def fit_walk(trace: MarketTrace) -> WalkFit:
    """Invert the walk recurrence on a price trace: the reversion target
    is the per-site sample mean, and since the one-step residual of the
    true walk is ``0.15 * vol * mean * N(0,1)`` (away from the price
    floor), ``vol ≈ std(residual) / (0.15 * mean)`` per site.  Floor-
    clamped ticks are excluded from the residual (the clamp truncates
    the noise and would bias vol low).  `reversion_r2` scores the fit
    against the hold-last-price null model."""
    p = np.asarray(trace.price, np.float64)
    mean = p.mean(axis=1)
    resid = p[:, 1:] - (p[:, :-1] + 0.2 * (mean[:, None] - p[:, :-1]))
    off_floor = p[:, 1:] > 0.1 * mean[:, None] * (1 + 1e-6)
    vol_site = np.array([
        resid[s][off_floor[s]].std() / (0.15 * max(mean[s], 1e-9))
        if off_floor[s].any() else 0.0
        for s in range(trace.sites)])
    hold_err = p[:, 1:] - p[:, :-1]
    r2 = 1.0 - float(np.sum(resid ** 2)) / \
        max(float(np.sum(hold_err ** 2)), 1e-12)
    return WalkFit(trace=trace.name, mean=mean.astype(np.float32),
                   vol=float(vol_site.mean()),
                   vol_per_site=vol_site.astype(np.float32),
                   reversion_r2=r2)
