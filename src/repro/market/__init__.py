"""Trace-driven spot-market subsystem (DESIGN.md §10).

Every market model — the in-sim mean-reverting walk, regime-switching
and correlated-shock processes, AWS spot-price histories, Google
cluster-trace preemption logs — compiles down to one replayable artifact:
a `MarketTrace` of (S, T) per-site price and revocation arrays on the
tick grid.  Traces enter the device program through `cfg_c` as jit
*arguments* (`runtime.make_cfg_arrays(market="trace", trace=...)`), so a
B-member trace sweep is still one compiled dispatch per epoch, and a
synthetic walk exported with `export_walk_trace` replays bit-identically
through the trace path (the §10 replay invariant).

`market.calibrate` fits `manager.RevocationPredictor` and the walk's
mean/vol against a trace's empirical revocation rates.
"""
from repro.market.traces import (MarketTrace, available_traces,
                                 bucket_events, load, load_aws_spot_history,
                                 load_google_cluster_events, resample_price)
from repro.market.synthetic import (CorrelatedSiteShocks, MeanRevertingWalk,
                                    RegimeSwitchingWalk, export_walk_trace,
                                    walk_params_from_cluster,
                                    walk_price_update)
from repro.market.calibrate import (CalibrationReport, HazardAwareBid,
                                    WalkFit, calibrate_predictor,
                                    epoch_revocation_rates, fit_walk,
                                    sliding_window_rates)
# chaos last: its runner lazily imports repro.core, which imports market
from repro.market.chaos import (ChaosReport, FaultSchedule, kill_mask,
                                kill_nodes, mass_kill, run_chaos,
                                warning_then_reprieve)

__all__ = [
    "MarketTrace", "available_traces", "bucket_events", "load",
    "load_aws_spot_history", "load_google_cluster_events", "resample_price",
    "CorrelatedSiteShocks", "MeanRevertingWalk", "RegimeSwitchingWalk",
    "export_walk_trace", "walk_params_from_cluster", "walk_price_update",
    "CalibrationReport", "HazardAwareBid", "WalkFit", "calibrate_predictor",
    "epoch_revocation_rates", "fit_walk", "sliding_window_rates",
    "ChaosReport", "FaultSchedule", "kill_mask", "kill_nodes", "mass_kill",
    "run_chaos", "warning_then_reprieve",
]
