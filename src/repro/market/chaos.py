"""Deterministic chaos harness: scripted fault schedules + safety replay.

A `FaultSchedule` is the fault-injection twin of `traces.MarketTrace`
(DESIGN.md §12): an (M, Tf) bool array `kill[m, t]` raising the
revocation *signal* for node m on tick t.  It rides into the device
program through `cfg_c["fault_trace"]` as a jit argument — swapping
schedules never recompiles — and is subject to the same advance-warning
contract as market revocations: the signal must stay up for
`warning_ticks + 1` consecutive ticks before the kill lands, and a
signal that drops early is a reprieve.  Unlike market columns, fault
columns hit *any* node, including on-demand voters — that is what makes
leader-kill drills expressible.

Builders (`kill_nodes`, `kill_mask`, `mass_kill`, `warning_then_reprieve`)
construct the canonical drill shapes; `run_chaos` replays a schedule
through a host tick loop, snapshotting every tick and checking the
paper's safety properties (`core.invariants.check_all`) plus measuring
recovery: how many ticks the cluster runs leaderless after the first
kill lands.

Module-level code is pure NumPy; `run_chaos` imports `repro.core`
lazily so `repro.market` stays importable from the core layer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(eq=False)
class FaultSchedule:
    """One scripted fault drill on the tick grid (DESIGN.md §12).

    `kill` is (M, Tf) bool: True raises node m's revocation signal on
    tick t.  The in-step lookup wraps at `cfg_c["fault_len"]` — the
    *fitted* width — so a schedule padded to the full run length is
    one-shot, while a deliberately short schedule repeats.  `eq=False`
    keeps identity hashing so a schedule can ride on a frozen
    `fleet.MemberSpec` field.
    """
    name: str
    kill: np.ndarray

    def __post_init__(self):
        self.kill = np.asarray(self.kill, bool)
        assert self.kill.ndim == 2, self.kill.shape

    @property
    def nodes(self) -> int:
        return self.kill.shape[0]

    @property
    def ticks(self) -> int:
        return self.kill.shape[1]

    def fit_to(self, nodes: int, ticks: int) -> np.ndarray:
        """(nodes, ticks) bool for `cfg_c["fault_trace"]`.  Extra rows
        and columns pad False (inert) — widening a drill to a longer
        run or a padded fleet never invents faults; truncation drops
        the overhang.  Contrast `MarketTrace.fit_to`, which tiles: a
        drill is a one-shot script, not a stationary process."""
        out = np.zeros((nodes, ticks), bool)
        m = min(nodes, self.kill.shape[0])
        t = min(ticks, self.kill.shape[1])
        out[:m, :t] = self.kill[:m, :t]
        return out


# --------------------------------------------------------------------- #
# canonical drill builders
# --------------------------------------------------------------------- #
def kill_nodes(nodes: Sequence[int], at: int, *, n_nodes: int, ticks: int,
               hold: Optional[int] = None, warning_ticks: int = 0,
               name: str = "kill-nodes") -> FaultSchedule:
    """Raise the revocation signal on `nodes` at tick `at`, sustained for
    `hold` ticks.  The kill lands only when ``hold > warning_ticks``
    (the §12 warning contract); the default hold is exactly
    ``warning_ticks + 1``, the minimum that lands."""
    h = int(hold if hold is not None else warning_ticks + 1)
    assert h >= 1 and 0 <= at and at + h <= ticks, (at, h, ticks)
    kill = np.zeros((n_nodes, ticks), bool)
    for n in nodes:
        kill[int(n), at:at + h] = True
    return FaultSchedule(name, kill)


def kill_mask(mask: np.ndarray, at: int, *, ticks: int,
              hold: Optional[int] = None, warning_ticks: int = 0,
              name: str = "kill-mask") -> FaultSchedule:
    """`kill_nodes` with a (n_nodes,) bool mask instead of an index list."""
    mask = np.asarray(mask, bool)
    return kill_nodes(np.where(mask)[0], at, n_nodes=mask.shape[0],
                      ticks=ticks, hold=hold, warning_ticks=warning_ticks,
                      name=name)


def mass_kill(at: int, *, n_nodes: int, ticks: int,
              spare: Sequence[int] = (), hold: Optional[int] = None,
              warning_ticks: int = 0) -> FaultSchedule:
    """Correlated mass revocation: every node except `spare` gets the
    signal at tick `at` — the phi=1-style drill, but scripted and
    warned.  Spare at least a quorum of voters to keep the run
    recoverable."""
    mask = np.ones(n_nodes, bool)
    mask[list(spare)] = False
    return kill_mask(mask, at, ticks=ticks, hold=hold,
                     warning_ticks=warning_ticks, name="mass-kill")


def warning_then_reprieve(nodes: Sequence[int], at: int, *, n_nodes: int,
                          ticks: int, warning_ticks: int,
                          hold: Optional[int] = None) -> FaultSchedule:
    """The price-dips-back drill: the signal rises at `at` but drops
    after `hold` ticks (default `warning_ticks`, one short of landing),
    so the warned node degrades, is re-leased around, and then resumes
    — no kill ever lands.  Requires ``warning_ticks >= 1``."""
    assert warning_ticks >= 1, "W=0 has no window to reprieve inside"
    h = int(hold if hold is not None else warning_ticks)
    assert 1 <= h <= warning_ticks, (h, warning_ticks)
    return kill_nodes(nodes, at, n_nodes=n_nodes, ticks=ticks, hold=h,
                      warning_ticks=0, name="warning-then-reprieve")


# --------------------------------------------------------------------- #
# the replay harness
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class ChaosReport:
    """What one chaos replay observed (for tests and BENCH_faults.json)."""
    name: str
    ticks: int
    warning_ticks: int
    first_kill_tick: int          # -1: nothing ever died
    killed_total: int
    recovery_ticks: int           # first leaderless span after first kill
    max_leaderless_span: int
    leader_uptime: float          # fraction of ticks with an alive leader
    alive_end: int
    safety_error: Optional[str]   # None = all §3 properties held
    trace: List[Dict[str, np.ndarray]] = dataclasses.field(
        default_factory=list, repr=False)
    # flight-recorder capture (DESIGN.md §14, `trace_on=True` only):
    # decoded events, exact per-class ring-overwrite counts, whether the
    # trace-replayed leader timeline matches the harness's per-tick
    # alive-leader probe bit for bit, and the Perfetto artifact path
    events: List = dataclasses.field(default_factory=list, repr=False)
    events_dropped: Optional[Dict[str, int]] = None
    trace_leader_match: Optional[bool] = None
    perfetto_path: Optional[str] = None


def run_chaos(cfg, faults: FaultSchedule, *, warning_ticks: int = 0,
              ticks: Optional[int] = None, seed: int = 0, phi: float = 0.0,
              write_rate: float = 8.0, read_rate: float = 16.0,
              lease: Optional[Sequence[int]] = (4, 6), every: int = 1,
              spot_bid=None, check: bool = True, trace_on: bool = False,
              trace_capacity: int = 1024,
              trace_out: Optional[str] = None) -> ChaosReport:
    """Replay a `FaultSchedule` through a host tick loop and audit it.

    Builds a `runtime.BWRaftSim` carrying the schedule (so the exact
    same `cfg_c` plumbing the benchmarks use is what the harness
    exercises), leases `lease` secretaries/observers, then drives
    `step.tick` directly for `ticks` ticks (default: the schedule's
    width), snapshotting every `every` ticks.  Checks every paper
    safety property over the snapshot trace (`invariants.check_all` —
    raises when `check`, else records the violation) and measures
    recovery: how many ticks elapse from the first landed kill until an
    alive leader exists again (0 when the kill never takes the leader).

    Pass a large `spot_bid` (say 10x the mean price) to silence
    market-driven revocations so the scripted schedule is the only
    fault source — the deterministic-drill configuration the fault
    tests replay.

    `trace_on=True` arms the flight recorder (DESIGN.md §14) and drains
    the ring every tick: the report gains the decoded events, the exact
    per-class overwrite counts, and `trace_leader_match` — whether the
    trace-replayed leader timeline (`trace.export.leader_timeline`)
    reproduces the harness's per-tick alive-leader probe bit for bit.
    `trace_out` additionally writes the Perfetto artifact, whose leader
    track's GAPS are the leaderless spans this report measures."""
    import jax

    from repro.core import invariants
    from repro.core import runtime as RT
    from repro.core import state as SM
    from repro.core import step as step_mod
    from repro.trace import export as trace_export

    T = int(ticks if ticks is not None else faults.ticks)
    sim = RT.BWRaftSim(cfg, write_rate=write_rate, read_rate=read_rate,
                       phi=phi, seed=seed, warning_ticks=warning_ticks,
                       faults=faults, fault_ticks=T, spot_bid=spot_bid,
                       trace_on=trace_on, trace_capacity=trace_capacity)
    if lease is not None:
        sim._lease(*lease)
    static, cfg_c = sim.static, sim.cfg_c
    tickfn = jax.jit(lambda s, r, c: step_mod.tick(s, static, c, r))

    state = sim.state
    rng = jax.random.PRNGKey(seed)
    prev_alive = np.asarray(state["alive"]).copy()
    trace: List[Dict[str, np.ndarray]] = []
    leader_up: List[bool] = []
    first_kill, killed_total = -1, 0
    cursor = trace_export.DrainCursor()
    events: List[trace_export.TraceEvent] = []
    for t in range(T):
        rng, sub = jax.random.split(rng)
        state, _ = tickfn(state, sub, cfg_c)
        alive = np.asarray(state["alive"])
        role = np.asarray(state["role"])
        newly_dead = int((prev_alive & ~alive).sum())
        killed_total += newly_dead
        if newly_dead and first_kill < 0:
            first_kill = t
        prev_alive = alive.copy()
        leader_up.append(bool(((role == SM.LEADER) & alive).any()))
        if trace_on:
            events.extend(cursor.drain(state))
        if t % every == 0:
            trace.append(invariants.snapshot(state))

    # recovery: ticks from the first landed kill until a leader exists
    recovery, span, max_span = 0, 0, 0
    for t in range(T):
        span = span + 1 if not leader_up[t] else 0
        max_span = max(max_span, span)
    if first_kill >= 0:
        t = first_kill
        while t < T and not leader_up[t]:
            t += 1
        recovery = t - first_kill

    error: Optional[str] = None
    try:
        invariants.check_all(trace)
    except AssertionError as exc:      # pragma: no cover - violation path
        if check:
            raise
        error = str(exc)

    leader_match: Optional[bool] = None
    perfetto_path: Optional[str] = None
    if trace_on:
        up = trace_export.leader_timeline(events, T)
        leader_match = bool((up == np.asarray(leader_up, bool)).all())
        if trace_out is not None:
            trace_export.write_perfetto(
                events, trace_out, ticks=T,
                sites={0: np.asarray(static["site"])},
                obs_site={0: np.asarray(static["dobs_site"])})
            perfetto_path = str(trace_out)

    return ChaosReport(
        name=faults.name, ticks=T, warning_ticks=int(warning_ticks),
        first_kill_tick=first_kill, killed_total=killed_total,
        recovery_ticks=recovery, max_leaderless_span=max_span,
        leader_uptime=float(np.mean(leader_up)) if leader_up else 1.0,
        alive_end=int(np.asarray(state["alive"]).sum()),
        safety_error=error, trace=trace, events=events,
        events_dropped=cursor.dropped_by_class() if trace_on else None,
        trace_leader_match=leader_match, perfetto_path=perfetto_path)
