"""Replayable market traces: the (S, T) arrays every provider compiles to.

The market provider contract (DESIGN.md §10): whatever the source — the
synthetic processes in `market/synthetic.py`, an AWS spot-price history,
a Google cluster-trace preemption log — a market is materialized as a
`MarketTrace`: a per-site price series `price[s, t]` (float32, (S, T))
and a per-site revocation schedule `revoked[s, t]` (bool, (S, T)) on the
simulator's tick grid.  The tick replays it verbatim (`step.spot_step`
indexes column `tick % T`), so a trace is ground truth: no clamping, no
re-noising, no RNG at replay time.

External-format loaders live here too:

  `load_aws_spot_history`      AWS ``describe-spot-price-history`` JSON
  `load_google_cluster_events` Google cluster-trace task-event slices

both resampled onto the tick grid by the §10 rule — zero-order hold for
prices, event→tick bucketing for revocations — plus a registry of small
sample traces committed under ``market/traces/`` (`load`,
`available_traces`) so examples/benchmarks run offline.
"""
from __future__ import annotations

import csv
import dataclasses
import datetime
import json
from collections import defaultdict
from pathlib import Path
from typing import Callable, Dict, Tuple

import numpy as np

TRACES_DIR = Path(__file__).resolve().parent / "traces"

# Google cluster-trace task event types (subset we care about)
GOOGLE_EVICT = 2


@dataclasses.dataclass(eq=False)
class MarketTrace:
    """One replayable market on the tick grid (DESIGN.md §10).

    `price` is (S, T) float32 — the spot price at site s during tick t —
    and `revoked` is (S, T) bool — True revokes (kills) every spot node
    at site s on tick t.  `eq=False` keeps identity hashing so a trace
    can ride on a frozen `fleet.MemberSpec` field.

    `revoked_node` (optional, (M, T) bool) carries *per-node* revocation
    columns (DESIGN.md §12): row m revokes only the single node it maps
    to, not the whole site — the event-bucket resampling at machine
    granularity instead of the site broadcast.  When present,
    `runtime.make_cfg_arrays` fits it to the simulator's node axis
    (`node_columns`) and `step.spot_step` reads it in place of the site
    signal; None keeps the frozen site-level semantics.
    """
    name: str
    price: np.ndarray
    revoked: np.ndarray
    revoked_node: np.ndarray = None         # optional (M, T) bool

    def __post_init__(self):
        self.price = np.asarray(self.price, np.float32)
        self.revoked = np.asarray(self.revoked, bool)
        assert self.price.ndim == 2, self.price.shape
        assert self.price.shape == self.revoked.shape, \
            (self.price.shape, self.revoked.shape)
        if self.revoked_node is not None:
            self.revoked_node = np.asarray(self.revoked_node, bool)
            assert self.revoked_node.ndim == 2, self.revoked_node.shape
            assert self.revoked_node.shape[1] == self.ticks, \
                (self.revoked_node.shape, self.ticks)

    @property
    def sites(self) -> int:
        return self.price.shape[0]

    @property
    def ticks(self) -> int:
        return self.price.shape[1]

    def fit_to(self, sites: int, ticks: int) -> "MarketTrace":
        """Re-shape onto a target (sites, ticks) grid: site s reads source
        row ``s % S0`` (round-robin tiling, the same rule `state.
        build_static` uses to map spot slots onto sites) and tick t reads
        source column ``t % T0`` (wrap).  Widening is replay-neutral:
        the in-step lookup wraps at the member's own source length
        (`cfg_c["trace_len"]`, kept by `make_cfg_arrays`), not at the
        widened array width, so the tiled tail is never read out of
        phase (DESIGN.md §10)."""
        s_idx = np.arange(sites) % self.sites
        t_idx = np.arange(ticks) % self.ticks
        grid = np.ix_(s_idx, t_idx)
        node = None
        if self.revoked_node is not None:
            m_idx = np.arange(self.revoked_node.shape[0])
            node = self.revoked_node[np.ix_(m_idx, t_idx)]
        return MarketTrace(self.name, self.price[grid], self.revoked[grid],
                           node)

    def node_columns(self, nodes: int, ticks: int) -> np.ndarray:
        """Per-node revocation columns fitted to the simulator's
        (nodes, ticks) grid (DESIGN.md §12): node n reads source row
        ``n % M`` (round-robin, the site-tiling rule applied to
        machines) and tick t reads source column ``t % T`` (the §10
        time wrap — the in-step lookup shares `cfg_c["trace_len"]` with
        the site arrays)."""
        assert self.revoked_node is not None, \
            f"trace {self.name!r} carries no per-node columns"
        M = self.revoked_node.shape[0]
        n_idx = np.arange(nodes) % M
        t_idx = np.arange(ticks) % self.revoked_node.shape[1]
        return self.revoked_node[np.ix_(n_idx, t_idx)]

    def empirical_revocation_rates(self) -> np.ndarray:
        """Per-site per-tick revocation hazard — the calibration target
        for `market.calibrate` (DESIGN.md §10)."""
        return self.revoked.mean(axis=1)


# --------------------------------------------------------------------- #
# resampling (the §10 rule)
# --------------------------------------------------------------------- #
def resample_price(times: np.ndarray, values: np.ndarray,
                   ticks: int, span: Tuple[float, float]) -> np.ndarray:
    """Zero-order hold of an irregular price series onto `ticks` uniform
    tick instants spanning ``[span[0], span[1]]``: tick k takes the last
    observation at or before its wall-clock instant (the first
    observation when k precedes them all).  This is the §10 price
    resampling rule."""
    times = np.asarray(times, float)
    values = np.asarray(values, float)
    order = np.argsort(times, kind="stable")
    times, values = times[order], values[order]
    grid = np.linspace(span[0], span[1], ticks)
    idx = np.clip(np.searchsorted(times, grid, side="right") - 1,
                  0, len(times) - 1)
    return values[idx]


def bucket_events(times: np.ndarray, ticks: int,
                  span: Tuple[float, float]) -> np.ndarray:
    """Event→tick bucketing (the §10 revocation resampling rule): an
    event at wall time tau marks tick ``floor((tau - t0)/(t1 - t0) *
    ticks)`` (clipped to [0, ticks-1]) as revoked."""
    out = np.zeros(ticks, bool)
    t0, t1 = span
    width = max(t1 - t0, 1e-12)
    for tau in np.asarray(times, float):
        out[int(np.clip((tau - t0) / width * ticks, 0, ticks - 1))] = True
    return out


def _iso_ts(ts: str) -> float:
    return datetime.datetime.fromisoformat(
        ts.replace("Z", "+00:00")).timestamp()


# --------------------------------------------------------------------- #
# external trace formats
# --------------------------------------------------------------------- #
def load_aws_spot_history(path, *, ticks: int = 600,
                          bid_multiplier: float = 1.5) -> MarketTrace:
    """AWS ``aws ec2 describe-spot-price-history`` JSON → MarketTrace.

    Records are grouped by ``AvailabilityZone`` (one site per AZ, sorted
    by name), each AZ's step-function price is zero-order-held onto the
    shared tick grid spanning the trace's full wall-clock range, and
    revocations are derived by the in-sim bid rule: a site is revoked on
    any tick whose price exceeds ``bid_multiplier`` × that AZ's mean
    price (the same 1.5× rule `state.init_state` bids with —
    DESIGN.md §10)."""
    data = json.loads(Path(path).read_text())
    per_az: Dict[str, list] = defaultdict(list)
    for rec in data["SpotPriceHistory"]:
        per_az[rec["AvailabilityZone"]].append(
            (_iso_ts(rec["Timestamp"]), float(rec["SpotPrice"])))
    assert per_az, f"no SpotPriceHistory records in {path}"
    azs = sorted(per_az)
    all_times = [t for recs in per_az.values() for t, _ in recs]
    span = (min(all_times), max(all_times))
    price = np.stack([
        resample_price(np.array([t for t, _ in per_az[az]]),
                       np.array([p for _, p in per_az[az]]),
                       ticks, span)
        for az in azs]).astype(np.float32)
    bid = bid_multiplier * price.mean(axis=1, keepdims=True)
    return MarketTrace(Path(path).stem, price, price > bid)


def load_google_cluster_events(path, *, ticks: int = 600,
                               sites: int = 0,
                               price_mean: float = 0.0125,
                               node_rows: int = 0) -> MarketTrace:
    """Google cluster-trace task-event slice (CSV with a
    ``time_us,machine_id,event_type`` header) → MarketTrace.

    Machines hash onto ``sites`` rows round-robin by first-seen rank
    (0 → one site per distinct machine, capped at 4); every EVICT
    (event_type 2) marks its tick revoked at the machine's site by the
    §10 bucketing rule.  The trace records preemptions, not prices, so
    the price rows are flat at `price_mean` — pair with an AWS price
    trace or a synthetic walk when price dynamics matter.

    ``node_rows > 0`` additionally buckets each machine's evictions at
    machine granularity into `revoked_node` (DESIGN.md §12): machine
    rank m lands in row ``m % node_rows``, so a single eviction kills
    one simulated node instead of broadcasting over its whole site —
    the per-node fault model the warning window degrades through."""
    events = []
    machines: Dict[str, int] = {}
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            mid = row["machine_id"]
            if mid not in machines:
                machines[mid] = len(machines)
            if int(row["event_type"]) == GOOGLE_EVICT:
                events.append((float(row["time_us"]), machines[mid]))
    assert machines, f"no events in {path}"
    S = sites if sites > 0 else min(len(machines), 4)
    all_times = [t for t, _ in events]
    span = (min(all_times), max(all_times)) if events else (0.0, 1.0)
    revoked = np.zeros((S, ticks), bool)
    for s in range(S):
        site_times = [t for t, m in events if m % S == s]
        if site_times:
            revoked[s] = bucket_events(np.array(site_times), ticks, span)
    price = np.full((S, ticks), price_mean, np.float32)
    revoked_node = None
    if node_rows > 0:
        revoked_node = np.zeros((node_rows, ticks), bool)
        for n in range(node_rows):
            node_times = [t for t, m in events if m % node_rows == n]
            if node_times:
                revoked_node[n] = bucket_events(np.array(node_times),
                                                ticks, span)
    return MarketTrace(Path(path).stem, price, revoked, revoked_node)


# --------------------------------------------------------------------- #
# bundled sample traces (committed under market/traces/)
# --------------------------------------------------------------------- #
_BUNDLED: Dict[str, Tuple[str, Callable]] = {
    "aws-us-east": ("aws_spot_us_east.json", load_aws_spot_history),
    "google-evict": ("google_cluster_evictions.csv",
                     load_google_cluster_events),
}


def available_traces() -> Tuple[str, ...]:
    """Names accepted by `load` (and the example's ``--trace`` flag)."""
    return tuple(sorted(_BUNDLED))


def load(name: str, *, ticks: int = 600, **kwargs) -> MarketTrace:
    """Load a bundled sample trace by registry name, resampled onto
    `ticks` ticks.  Extra kwargs go to the format loader."""
    if name not in _BUNDLED:
        raise KeyError(
            f"unknown trace {name!r}; available: {available_traces()}")
    fname, loader = _BUNDLED[name]
    trace = loader(TRACES_DIR / fname, ticks=ticks, **kwargs)
    trace.name = name
    return trace
