"""Synthetic market providers: processes that materialize to (S, T) traces.

Three generators, all compiling down to `traces.MarketTrace` (the §10
provider contract):

  `MeanRevertingWalk`      THE in-sim process: `walk_price_update` below
                           is the exact expression `step.spot_step` runs,
                           and `export_walk_trace` replays the sim's key
                           schedule, so an exported walk fed back through
                           the trace path is **bit-identical** to the
                           process path (the §10 replay invariant,
                           `tests/test_market.py`).
  `RegimeSwitchingWalk`    calm/spike Markov-modulated vol+mean — the
                           bursty AZ-wide price spikes real AWS histories
                           show, which a single-vol walk cannot produce.
  `CorrelatedSiteShocks`   a common cross-site shock factor — correlated
                           capacity crunches, the failure mode that
                           revokes several sites in one tick and actually
                           threatens quorums.

Every provider exposes ``materialize(ticks, *, seed) -> MarketTrace``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import state as state_mod
from repro.core.cluster_config import ClusterConfig
from repro.market.traces import MarketTrace


def walk_price_update(price, mean, vol, r_price):
    """One tick of the mean-reverting site price walk — the process the
    paper's synthetic market runs.  Factored out of `step.spot_step` so
    the in-sim step and the trace exporter share ONE expression and the
    exported trace replays bit-identically (DESIGN.md §10).  Keep the
    operation order untouched: any reformulation breaks the replay
    invariant at the last float32 bit."""
    noise = jax.random.normal(r_price, price.shape) * vol * mean
    price = price + 0.2 * (mean - price) + 0.15 * noise
    return jnp.maximum(price, 0.1 * mean)


def walk_params_from_cluster(cfg: ClusterConfig, *, pad_sites: int = 0,
                             spot_price_vol: Optional[float] = None
                             ) -> Tuple[np.ndarray, float, np.ndarray,
                                        np.ndarray]:
    """(mean, vol, price0, bid) of the in-sim walk for this cluster —
    the same derivations `runtime.make_cfg_arrays` (mean/vol, padded
    sites repeat the last real site) and `state.init_state`
    (price0/bid via `state.site_price_init`) use."""
    sp = [s.spot_price_mean for s in cfg.sites]
    sp = sp + [sp[-1]] * pad_sites
    vol = (cfg.sites[0].spot_price_vol if spot_price_vol is None
           else spot_price_vol)
    price0, bid = state_mod.site_price_init(cfg, cfg.num_sites + pad_sites)
    return np.asarray(sp, np.float32), float(vol), price0, bid


@functools.partial(jax.jit, static_argnames=("T",))
def _epoch_walk_prices(price, sub, mean, vol, *, T: int):
    """One epoch of walk prices under the sim's exact key schedule: tick
    keys = split(epoch key, T); per tick the sim splits into
    (r_spot, r_work, r_lead, r_elec) and `spot_step` splits r_spot into
    (r_price, r_revoke, r_fail) — the price consumes r_price only."""
    keys = jax.random.split(sub, T)

    def body(p, k):
        r_spot = jax.random.split(k, 4)[0]
        r_price = jax.random.split(r_spot, 3)[0]
        p = walk_price_update(p, mean, vol, r_price)
        return p, p
    return jax.lax.scan(body, price, keys)


def export_walk_trace(cfg: ClusterConfig, *, seed: int, epochs: int,
                      pad_sites: int = 0,
                      spot_price_vol: Optional[float] = None,
                      name: Optional[str] = None) -> MarketTrace:
    """Materialize the in-sim mean-reverting walk as a `MarketTrace`
    covering `epochs` x `cfg.period_ticks` ticks, bit-identical to what a
    `BWRaftSim(cfg, seed=seed)` / same-seed fleet member would draw: the
    run key is PRNGKey(seed), each epoch consumes one
    ``rng, sub = split(rng)`` exactly as `BWRaftSim.run_epoch` /
    `FleetSim._split_epoch_rngs` do.  Revocations follow the in-sim bid
    rule (price > 1.5x site mean).  This is the §10 replay-invariant
    exporter (`tests/test_market.py`, `benchmarks/perf_market.py`)."""
    mean, vol, price0, bid = walk_params_from_cluster(
        cfg, pad_sites=pad_sites, spot_price_vol=spot_price_vol)
    rng = jax.random.PRNGKey(seed)
    price = jnp.asarray(price0)
    mean_j = jnp.asarray(mean, jnp.float32)
    vol_j = jnp.float32(vol)
    cols: List[np.ndarray] = []
    for _ in range(epochs):
        rng, sub = jax.random.split(rng)
        price, ps = _epoch_walk_prices(price, sub, mean_j, vol_j,
                                       T=cfg.period_ticks)
        cols.append(np.asarray(ps))                      # (T, S)
    prices = np.concatenate(cols, axis=0).T.astype(np.float32)  # (S, E*T)
    return MarketTrace(name or f"walk-{cfg.name}-seed{seed}",
                       prices, prices > bid[:, None])


@dataclasses.dataclass(eq=False)
class MeanRevertingWalk:
    """The in-sim walk as a provider object (`materialize(ticks, seed)`);
    `ticks` must be a whole number of `cfg.period_ticks` epochs because
    bit-identity is defined against the sim's per-epoch key schedule."""
    cfg: ClusterConfig
    pad_sites: int = 0
    spot_price_vol: Optional[float] = None

    def materialize(self, ticks: int, *, seed: int) -> MarketTrace:
        T = self.cfg.period_ticks
        assert ticks % T == 0, \
            f"ticks={ticks} must be a multiple of period_ticks={T}"
        return export_walk_trace(self.cfg, seed=seed, epochs=ticks // T,
                                 pad_sites=self.pad_sites,
                                 spot_price_vol=self.spot_price_vol)


def _floor_clamp(price: np.ndarray, mean: np.ndarray) -> np.ndarray:
    """The walk's price floor (0.1x mean), applied at generation time —
    traces replay verbatim, so the floor must be in the data
    (DESIGN.md §10)."""
    return np.maximum(price, 0.1 * mean)


@dataclasses.dataclass(eq=False)
class RegimeSwitchingWalk:
    """Calm/spike Markov-modulated walk: each site carries a two-state
    regime chain (calm -> spike w.p. `p_spike` per tick, spike -> calm
    w.p. `p_calm`); the spike regime multiplies the walk's volatility by
    `spike_vol_mult` and its reversion target by `spike_mean_mult`, which
    is what drives prices through the bid and produces the *clustered*
    revocation bursts AWS spot histories show."""
    mean: np.ndarray
    vol: float
    bid: np.ndarray
    p_spike: float = 0.02
    p_calm: float = 0.25
    spike_vol_mult: float = 4.0
    spike_mean_mult: float = 1.8

    @classmethod
    def from_cluster(cls, cfg: ClusterConfig, **kw) -> "RegimeSwitchingWalk":
        mean, vol, _, bid = walk_params_from_cluster(cfg)
        return cls(mean=mean, vol=vol, bid=bid, **kw)

    def materialize(self, ticks: int, *, seed: int) -> MarketTrace:
        rng = np.random.default_rng(seed)
        S = len(self.mean)
        mean = np.asarray(self.mean, np.float64)
        price = mean.copy()
        spike = np.zeros(S, bool)
        prices = np.empty((S, ticks), np.float32)
        for t in range(ticks):
            flip = rng.random(S)
            spike = np.where(spike, flip >= self.p_calm, flip < self.p_spike)
            target = mean * np.where(spike, self.spike_mean_mult, 1.0)
            vol_t = self.vol * np.where(spike, self.spike_vol_mult, 1.0)
            noise = rng.standard_normal(S) * vol_t * mean
            price = _floor_clamp(price + 0.2 * (target - price) +
                                 0.15 * noise, mean)
            prices[:, t] = price
        return MarketTrace(f"regime-seed{seed}", prices,
                           prices > np.asarray(self.bid)[:, None])


@dataclasses.dataclass(eq=False)
class CorrelatedSiteShocks:
    """Mean-reverting walk whose per-tick noise shares a common factor
    across sites: ``z_s = sqrt(c)*z_common + sqrt(1-c)*z_site`` with
    ``c = correlation`` — region-wide capacity crunches that push several
    sites over their bids in the SAME tick, the simultaneous-revocation
    pattern that actually threatens a quorum (and that i.i.d. per-site
    noise essentially never produces)."""
    mean: np.ndarray
    vol: float
    bid: np.ndarray
    correlation: float = 0.6

    @classmethod
    def from_cluster(cls, cfg: ClusterConfig, **kw) -> "CorrelatedSiteShocks":
        mean, vol, _, bid = walk_params_from_cluster(cfg)
        return cls(mean=mean, vol=vol, bid=bid, **kw)

    def materialize(self, ticks: int, *, seed: int) -> MarketTrace:
        assert 0.0 <= self.correlation <= 1.0, self.correlation
        rng = np.random.default_rng(seed)
        S = len(self.mean)
        mean = np.asarray(self.mean, np.float64)
        price = mean.copy()
        prices = np.empty((S, ticks), np.float32)
        w_common = np.sqrt(self.correlation)
        w_site = np.sqrt(1.0 - self.correlation)
        for t in range(ticks):
            z = w_common * rng.standard_normal() + \
                w_site * rng.standard_normal(S)
            price = _floor_clamp(price + 0.2 * (mean - price) +
                                 0.15 * z * self.vol * mean, mean)
            prices[:, t] = price
        return MarketTrace(f"corr-seed{seed}", prices,
                           prices > np.asarray(self.bid)[:, None])
