"""AdamW with global-norm clipping — framework-free, sharding-friendly.

Optimizer state mirrors the parameter tree (same logical axes, so the same
sharding rules apply); the dtype of m/v is configurable (`opt_state_dtype`)
— bf16 state halves optimizer HBM for the ≥90B archs (see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def init_opt_state(params, dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(param_specs, dtype=jnp.float32):
    """ShapeDtypeStruct/ParamSpec mirror for the dry-run path."""
    from repro.models.common import ParamSpec
    conv = lambda p: ParamSpec(p.shape, dtype, p.axes, "zeros")
    return {"m": jax.tree.map(conv, param_specs,
                              is_leaf=lambda x: isinstance(x, ParamSpec)),
            "v": jax.tree.map(conv, param_specs,
                              is_leaf=lambda x: isinstance(x, ParamSpec)),
            "step": ParamSpec((), jnp.int32, (), "zeros")}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0, grad_clip=0.0):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if grad_clip:
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), \
            v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
