"""One BW-Raft protocol tick — pure, branch-free, jit/vmap/scan-able.

Implements the paper's §3 mechanics with an explicit latency model
(per-link RTT classes) and per-node work-capacity accounting:

  1. spot-market dynamics: price step (synthetic walk or trace replay,
     DESIGN.md §10), revocations kill secretaries/observers
  2. client arrivals: Poisson reads (to observers/followers) + writes (to
     the leader's queue)
  3. leader: accept writes into the log (capacity-bounded), ship
     AppendEntries batches — to its secretaries (BW-Raft) or directly to
     every follower (plain Raft) — heartbeats included
  4. secretary relay: forward leader batches to assigned followers,
     aggregate acks, report counts to the leader
  5. followers: log-matching check on (prev_idx, prev_term), truncate
     conflicts, append, ack; forward uncommitted appends to observers
  6. leader commit: majority of *voters* (secretaries/observers never
     count — Property 3.4 state irrelevancy), entry commit times recorded
  7. all nodes: apply committed entries to the KV state machine
  8. reads: served by observers that applied >= readindex, else rerouted
     to their follower (queueing latency tracked)
  9. elections: randomized timeouts, RequestVote with log-up-to-date
     restriction, majority-of-voters win (Property 3.1)

Every rule is masked array math, so thousands of clusters step in parallel
under vmap and 1e5+ ticks run under lax.scan.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.state import (CANDIDATE, DEAD, FOLLOWER, LEADER, OBSERVER,
                              SECRETARY, entry_mix, leader_id)
from repro.kernels import resolve_backend
from repro.kernels.ae_sync import ops as ae_ops
from repro.kernels.leader_fanout import ops as lf_ops
from repro.kernels.raft_tick import ops as rt_ops
from repro.market import synthetic as market_synth
from repro.trace import metrics as trace_metrics
from repro.trace import ring as trace_ring


def _rand(rng, n):
    return jax.random.split(rng, n)


def cross_shard_mark(idx, frac):
    """Deterministic cross-shard marking (DESIGN.md §9): entry `idx` is a
    cross-shard 2PC coordinator iff `floor((idx+1)*frac) > floor(idx*frac)`
    — exactly `floor(n*frac)` of the first n entries are marked, and no RNG
    is consumed, so `frac == 0` leaves the trajectory bit-identical to an
    unsharded run.  Used both for the commit-time 2PC latency charge
    (`commit_step`) and the prepare/abort census (`runtime` digest)."""
    i = idx.astype(jnp.float32)
    return jnp.floor((i + 1) * frac) > jnp.floor(i * frac)


def spot_step(state, static, cfg_c, rng):
    """Site price dynamics + revocation of spot nodes (DESIGN.md §10).

    Two market sources, selected per member by the `cfg_c["market_trace"]`
    flag — a jit *argument*, so process and trace members mix freely in
    one compiled fleet program:

      process  the synthetic mean-reverting walk
               (`market/synthetic.walk_price_update` — the §10 provider
               refactor keeps the expression bit-identical); revocation
               is price-driven (price > the site's standing bid)
      trace    per-tick lookup into the (S, Tt) `cfg_c["price_trace"]` /
               `cfg_c["revoke_trace"]` arrays at column
               `tick % cfg_c["trace_len"]` — the member's OWN trace
               period, a jit argument, so short traces wrap correctly
               even when widened to a fleet-shared Tt (the §10
               time-wrap rule); both price and revocation replay the
               trace verbatim, no RNG drawn from the market

    The i.i.d. failure knob `phi` applies on top of either source (set
    phi=0 for pure trace replay).  The tick's RNG is split identically on
    both sources and the process branch is computed-then-discarded under
    a trace, so a synthetic walk exported as a trace
    (`market/synthetic.export_walk_trace`) replays **bit-identically**
    through this function — the §10 replay invariant
    (`tests/test_market.py`, gated by `benchmarks/perf_market.py`).

    Revocation robustness (DESIGN.md §12) rides on top, all cfg_c data
    and RNG-free so `warn_ticks == 0` with no faults is bit-identical to
    the frozen site-level rule (`spot_step_reference`):

      * the standing bid is `cfg_c["spot_bid"]` (per-epoch policy
        updates without recompiles); `bid_on_trace` re-derives trace
        revocations from replayed prices vs the CURRENT bid
      * per-node revocation columns (`node_trace` /
        `revoke_node_trace`) replace the site broadcast when the trace
        carries them
      * deterministic chaos schedules (`fault_on` / `fault_trace`,
        column `tick % fault_len`) raise the same signal on ANY node —
        voters included (leader-kill drills)
      * the advance-warning window: a raised revocation signal arms
        `warn_timer` at W = `warn_ticks` and counts down while the
        signal holds; the kill lands only when it hits 0, and a signal
        that drops early (price dips back under the bid) is a
        *reprieve* — the timer resets to -1 and the node resumes.  The
        `phi` i.i.d. knob stays an unwarned immediate kill.
    """
    S = state["spot_price"].shape[0]
    r_price, r_revoke, r_fail = _rand(rng, 3)
    synth_price = market_synth.walk_price_update(
        state["spot_price"], cfg_c["spot_price_mean"],
        cfg_c["spot_price_vol"], r_price)
    use_trace = cfg_c["market_trace"]
    t = jnp.mod(state["tick"], cfg_c["trace_len"])
    price = jnp.where(use_trace, cfg_c["price_trace"][:, t], synth_price)

    over_bid = price > cfg_c["spot_bid"]                      # (S,)
    revoked_site = jnp.where(use_trace & ~cfg_c["bid_on_trace"],
                             cfg_c["revoke_trace"][:, t],
                             over_bid)                        # (S,)
    site = jnp.asarray(static["site"])
    is_spot = ~jnp.asarray(static["is_voter"])
    # per-node revocation columns, else the site signal broadcast (N,)
    market_sig = jnp.where(cfg_c["node_trace"] & use_trace,
                           cfg_c["revoke_node_trace"][:, t],
                           revoked_site[site])
    # deterministic chaos schedule: hits any node, voters included
    tf = jnp.mod(state["tick"], cfg_c["fault_len"])
    fault_sig = cfg_c["fault_on"] & cfg_c["fault_trace"][:, tf]
    sig = state["alive"] & ((is_spot & market_sig) | fault_sig)

    # advance-warning countdown (RNG-free; W=0 kills the tick the
    # signal rises, exactly the pre-§12 rule)
    timer = state["warn_timer"]
    newly = sig & (timer < 0)
    timer = jnp.where(sig,
                      jnp.where(newly, cfg_c["warn_ticks"],
                                jnp.maximum(timer - 1, 0)),
                      -1)
    due = sig & (timer <= 0)

    # i.i.d. failure knob phi on top: immediate, no warning
    iid_fail = jax.random.uniform(r_fail, site.shape) < cfg_c["phi"]
    killed = state["alive"] & (due | (is_spot & iid_fail))
    timer = jnp.where(killed, -1, timer)

    # flight-recorder inputs (DESIGN.md §14), captured before the state
    # rewrite: a reprieve is a held warning whose signal dropped this
    # tick; the warned-secretary/observer handoff edges mirror the
    # `warn_timer >= 0` rules in `leader_step`/`commit_step`/`read_step`
    prev_role = state["role"]
    reprieve = (state["warn_timer"] >= 0) & ~sig & state["alive"]
    warn_live = cfg_c["warn_ticks"] > 0

    alive = state["alive"] & ~killed
    role = jnp.where(killed, DEAD, state["role"])
    state = dict(state, spot_price=price, alive=alive, role=role,
                 warn_timer=timer)

    # §12 revocation seam -> ring + registry (all RNG-free, gated
    # capture — trace_on=0 stays bit-identical, DESIGN.md §14)
    nid = jnp.arange(killed.shape[0])
    # minimal unit-test states omit consensus leaves (tests/test_market
    # drives spot_step alone); record no-ops without the ring leaves,
    # so the term lane just falls back to 0 there
    term = state["term"] if "term" in state else 0
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_WARN, valid=newly & warn_live,
        node=nid, term=term, aux=cfg_c["warn_ticks"],
        counter="warns_armed")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_KILL, valid=killed, node=nid,
        term=term, aux=prev_role, counter="kills")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_REPRIEVE, valid=reprieve, node=nid,
        term=term, counter="reprieves")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_SEC_HANDOFF,
        valid=newly & warn_live & (prev_role == SECRETARY), node=nid,
        term=term, counter="sec_handoffs")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_OBS_DRAIN,
        valid=newly & warn_live & (prev_role == OBSERVER), node=nid,
        term=term, counter="obs_drains")

    # digest-tier observers (DESIGN.md §13) are spot instances too: the
    # site revocation signal, the §12 warning window, and the phi knob
    # all apply, addressed by `static["dobs_site"]`.  Per-node trace
    # columns and chaos fault schedules stay dense-only (they are
    # node-indexed).  The phi draw uses a FRESH fold of r_fail so the
    # dense streams above are untouched; the whole block vanishes at
    # O == 0 (python guard — epoch programs compile per static shape),
    # which is what keeps digest-off trajectories bit-identical to the
    # pre-§13 golden fixtures.  Minimal unit-test states omit the
    # digest leaves entirely — treat absence as O == 0.
    O = state["dobs_alive"].shape[0] if "dobs_alive" in state else 0
    if O:
        dsite = jnp.asarray(static["dobs_site"])
        sig_d = state["dobs_alive"] & revoked_site[dsite]
        timer_d = state["dobs_warn"]
        newly_d = sig_d & (timer_d < 0)
        timer_d = jnp.where(sig_d,
                            jnp.where(newly_d, cfg_c["warn_ticks"],
                                      jnp.maximum(timer_d - 1, 0)),
                            -1)
        due_d = sig_d & (timer_d <= 0)
        iid_d = jax.random.uniform(jax.random.fold_in(r_fail, 1),
                                   (O,)) < cfg_c["phi"]
        killed_d = state["dobs_alive"] & (due_d | iid_d)
        timer_d = jnp.where(killed_d, -1, timer_d)
        state = dict(state, dobs_alive=state["dobs_alive"] & ~killed_d,
                     dobs_warn=timer_d)
    return state, killed


def spot_step_reference(state, static, cfg_c, rng):
    """The frozen pre-§12 site-level market step: immediate kills, no
    warning window, no per-node columns, no chaos schedules.  Kept
    verbatim as the reference twin — `tests/test_faults.py` pins
    `spot_step` at `warn_ticks=0` (and no faults) bit-identical to this
    on both market paths (DESIGN.md §12); the only delta from the
    historical body is that the standing bid now reads from
    `cfg_c["spot_bid"]` (same values at init, see `state.init_state`)."""
    r_price, r_revoke, r_fail = _rand(rng, 3)
    synth_price = market_synth.walk_price_update(
        state["spot_price"], cfg_c["spot_price_mean"],
        cfg_c["spot_price_vol"], r_price)
    use_trace = cfg_c["market_trace"]
    t = jnp.mod(state["tick"], cfg_c["trace_len"])
    price = jnp.where(use_trace, cfg_c["price_trace"][:, t], synth_price)

    revoked_site = jnp.where(use_trace, cfg_c["revoke_trace"][:, t],
                             price > cfg_c["spot_bid"])       # (S,)
    site = jnp.asarray(static["site"])
    is_spot = ~jnp.asarray(static["is_voter"])
    iid_fail = jax.random.uniform(r_fail, site.shape) < cfg_c["phi"]
    killed = is_spot & state["alive"] & (revoked_site[site] | iid_fail)

    alive = state["alive"] & ~killed
    role = jnp.where(killed, DEAD, state["role"])
    return dict(state, spot_price=price, alive=alive, role=role), killed


def workload_step(state, static, cfg_c, rng):
    """Client arrivals this tick: writes -> leader queue, reads -> per-node
    read queues (observers first, at their site, else followers).

    Cross-shard split (DESIGN.md §9): when this member is one shard of a
    Multi-Raft group, a `cross_frac` fraction of the arriving writes are
    cross-shard 2PC coordinators.  The split is deterministic — cumulative
    cross arrivals = floor(cumulative writes * cross_frac) — so it costs
    no RNG draw and is inert at `cross_frac == 0`."""
    r_w, r_r, r_key = _rand(rng, 3)
    # open-loop arrival schedule (DESIGN.md §11): per-tick rate curves
    # ride in cfg_c as jit-argument arrays the way market traces do
    # (DESIGN.md §10) — the lookup wraps at the plan's OWN length, so
    # fleet-widened curves replay identically and swapping schedules at
    # one shape never recompiles.  Closed loop (`open_loop` off) keeps
    # the scalar-rate knob: the `where` selects the identical rate
    # value, so pre-§11 trajectories are bit-identical
    # (`tests/test_serving.py` golden regression).
    ta = jnp.mod(state["tick"], cfg_c["arrival_len"])
    lam_w = jnp.where(cfg_c["open_loop"], cfg_c["write_curve"][ta],
                      cfg_c["write_rate"])
    lam_r = jnp.where(cfg_c["open_loop"], cfg_c["read_curve"][ta],
                      cfg_c["read_rate"])
    n_writes = jax.random.poisson(r_w, lam_w).astype(jnp.int32)
    n_reads = jax.random.poisson(r_r, lam_r).astype(jnp.int32)

    chi = cfg_c["cross_frac"]
    w_before = state["writes_arrived"].astype(jnp.float32)
    w_after = (state["writes_arrived"] + n_writes).astype(jnp.float32)
    n_cross = (jnp.floor(w_after * chi) -
               jnp.floor(w_before * chi)).astype(jnp.int32)

    N = state["role"].shape[0]
    # read routing: spread over alive observers; overflow to followers.
    # Warned observers drain: they take no NEW reads (routing skips
    # them, DESIGN.md §12) but `read_step` still serves their queue
    # until the kill lands
    is_obs = (state["role"] == OBSERVER) & state["alive"] & \
        (state["warn_timer"] < 0)
    is_fol = ((state["role"] == FOLLOWER) | (state["role"] == LEADER)) & \
        state["alive"]
    n_obs = jnp.maximum(jnp.sum(is_obs), 0)
    n_fol = jnp.maximum(jnp.sum(is_fol), 1)
    cap = jnp.int32(static["work_capacity"])
    # digest-tier observers (DESIGN.md §13) join the observer pool:
    # routing treats a digest slot exactly like a dense observer slot
    # (same 90% offload ceiling, same per-slot split), and the same §12
    # drain rule skips warned slots.  At O == 0 `pool` is literally
    # `n_obs` (python guard), so pre-§13 routing is bit-identical.
    O = state["dobs_alive"].shape[0] if "dobs_alive" in state else 0
    if O:
        is_dobs = state["dobs_alive"] & (state["dobs_warn"] < 0)
        pool = n_obs + jnp.sum(is_dobs)
    else:
        pool = n_obs
    # offload up to 90% of reads, but never beyond observer service capacity
    # (headroom x2 absorbs bursts; the rest goes to followers)
    obs_share = jnp.where(pool > 0,
                          jnp.minimum((n_reads * 9) // 10, pool * cap),
                          0)
    fol_share = n_reads - obs_share
    extra = {}
    per_obs = jnp.where(is_obs, obs_share // jnp.maximum(pool, 1), 0)
    if O:
        # dense observers keep the exact O == 0 floor rule above (so a
        # member padded with never-enabled digest slots routes
        # bit-identically to its unpadded twin — the fleet/sequential
        # A/B invariant); the floored remainder, which the O == 0 rule
        # drops, is spread by rank over the digest slots instead — the
        # tier absorbs it
        base = obs_share // jnp.maximum(pool, 1)
        rem = obs_share - base * jnp.maximum(pool, 1)
        r_dobs = jnp.cumsum(is_dobs.astype(jnp.int32)) - 1
        extra["dobs_read_queue"] = state["dobs_read_queue"] + \
            jnp.where(is_dobs, base + (r_dobs < rem), 0)
    per_fol = jnp.where(is_fol, fol_share // n_fol, 0)
    read_queue = state["read_queue"] + per_obs + per_fol

    return dict(state, **extra,
                read_queue=read_queue,
                write_pending=state["write_pending"] + n_writes,
                reads_arrived=state["reads_arrived"] + n_reads,
                writes_arrived=state["writes_arrived"] + n_writes,
                cross_arrived=state["cross_arrived"] + n_cross), \
        (n_writes, n_reads, r_key)


def leader_step(state, static, cfg_c, rng_key, *, backend="xla"):
    """Leader accepts queued writes into its log and ships append batches.

    `backend="pallas"` fuses the budgeted ship — the relay/direct
    split, the secretary/warned handoff mask, the rank-based message
    budget, and the five app_* writes — into one in-register pass
    (`kernels/leader_fanout`, DESIGN.md §8); bit-identical to the XLA
    cumsum/gather formulation below (test invariant)."""
    N = state["role"].shape[0]
    L = state["log_term"].shape[1]
    lid = leader_id(state, static)
    has_leader = lid >= 0
    lid_c = jnp.maximum(lid, 0)
    tick = state["tick"]

    # --- accept writes into the leader log (bounded by capacity & space) --
    cap = jnp.int32(static["work_capacity"])
    space = L - state["log_len"][lid_c]
    n_accept = jnp.where(has_leader,
                         jnp.minimum(jnp.minimum(state["write_pending"],
                                                 cap), space), 0)
    start = state["log_len"][lid_c]
    idxs = start + jnp.arange(64)                             # static window
    take = jnp.arange(64) < n_accept
    # key popularity (DESIGN.md §11): uniform draw (the pre-§11 stream,
    # untouched) or inverse-transform sampling of the (K,) cfg_c CDF —
    # Zipfian hot keys under `workload.ZipfianKeys`.  The Zipfian draw
    # uses a FRESH fold of the tick key, so closed-loop runs
    # (`key_zipf` off) consume exactly the pre-§11 RNG stream.
    keys_uniform = jax.random.randint(rng_key, (64,), 0,
                                      state["kv"].shape[1])
    u = jax.random.uniform(jax.random.fold_in(rng_key, 2), (64,))
    keys_zipf = jnp.clip(
        jnp.searchsorted(cfg_c["key_cdf"], u, side="left"),
        0, state["kv"].shape[1] - 1).astype(jnp.int32)
    keys = jnp.where(cfg_c["key_zipf"], keys_zipf, keys_uniform)
    vals = jax.random.randint(jax.random.fold_in(rng_key, 1), (64,),
                              0, 2**20)
    safe_idx = jnp.where(take, idxs, L - 1)
    log_term = state["log_term"].at[lid_c, safe_idx].set(
        jnp.where(take, state["term"][lid_c], state["log_term"][lid_c,
                                                                safe_idx]),
        mode="drop")
    log_key = state["log_key"].at[lid_c, safe_idx].set(
        jnp.where(take, keys, state["log_key"][lid_c, safe_idx]),
        mode="drop")
    log_val = state["log_val"].at[lid_c, safe_idx].set(
        jnp.where(take, vals, state["log_val"][lid_c, safe_idx]),
        mode="drop")
    entry_submit = state["entry_submit_t"].at[safe_idx].set(
        jnp.where(take & has_leader, tick, state["entry_submit_t"][safe_idx]),
        mode="drop")
    new_len = jnp.where(has_leader, start + n_accept, start)
    log_len = state["log_len"].at[lid_c].set(new_len)

    state = dict(state, log_term=log_term, log_key=log_key, log_val=log_val,
                 log_len=log_len,
                 write_pending=state["write_pending"] - n_accept,
                 entry_submit_t=entry_submit)

    # Multi-Raft 2PC prepare seam -> ring + registry (DESIGN.md §9/§14):
    # entries accepted this tick carrying the cross-shard coordinator
    # mark.  Shared by both backends (emitted before the pallas split);
    # `cross_frac == 0` keeps the count at zero — no event, no bump.
    n_prep = jnp.sum(take & cross_shard_mark(idxs, cfg_c["cross_frac"])
                     ).astype(jnp.int32)
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_2PC_PREPARE, valid=n_prep > 0,
        node=lid_c, term=state["term"][lid_c], aux=n_prep,
        counter="twopc_prepared", count=n_prep)

    # --- ship AppendEntries (budgeted fan-out: THE leader bottleneck) ----
    rtt = jnp.asarray(static["rtt"])

    if backend == "pallas":
        # fused kernel: handoff mask, relay/direct split, budget rank,
        # and the app_* writes in one pass (`kernels/leader_fanout`)
        (app_arrive_t, app_from_len, app_upto, app_term, app_commit,
         work) = lf_ops.leader_fanout(
            state["role"], state["alive"], state["warn_timer"],
            state["sec_of"], state["match_len"], state["app_arrive_t"],
            state["app_from_len"], state["app_upto"], state["app_term"],
            state["app_commit"], rtt, lid_c, has_leader, tick,
            state["log_len"][lid_c], state["term"][lid_c],
            state["commit_len"][lid_c],
            msg_budget=static["msg_budget"], max_ship=static["max_ship"],
            entries_per_msg=static["entries_per_msg"])
        leader_work = state["leader_work"].at[lid_c].add(work)
        return dict(state, app_arrive_t=app_arrive_t,
                    app_from_len=app_from_len, app_upto=app_upto,
                    app_term=app_term, app_commit=app_commit,
                    leader_work=leader_work)

    # secretary relay wiring: follower f's batch goes via sec_of[f] if that
    # secretary is alive, else directly from the leader.
    sec = state["sec_of"]                                     # (N,)
    # a warned secretary hands its fan-out back to the leader NOW, so
    # no in-flight batch is stranded when the kill lands (DESIGN.md §12;
    # `warn_timer < 0` is all-True whenever warnings are off)
    sec_alive = (sec >= 0) & state["alive"][jnp.maximum(sec, 0)] & \
        (state["role"][jnp.maximum(sec, 0)] == SECRETARY) & \
        (state["warn_timer"][jnp.maximum(sec, 0)] < 0)
    relay = jnp.where(sec_alive, sec, lid_c)                  # hop node
    is_target = ((state["role"] == FOLLOWER) | (state["role"] == CANDIDATE)) \
        & state["alive"] & (jnp.arange(N) != lid_c)
    # delivery latency: leader->relay + relay->target (direct: leader->target)
    lat = rtt[lid_c, relay] * (relay != lid_c) + \
        rtt[relay, jnp.arange(N)]
    arrive = tick + lat
    # Shipping is continuous (slot-free gating paces it to one batch per
    # RTT), but the LEADER can emit at most `msg_budget` direct messages
    # per tick: plain Raft pays one per follower, BW-Raft pays one per
    # secretary (the offload, paper §3/Fig 4).  Relayed batches spend the
    # secretary's capacity instead, which is bounded by fanout f by
    # construction.
    want = has_leader & is_target & (state["app_arrive_t"] < 0)
    direct = want & (relay == lid_c)
    relayed = want & (relay != lid_c)
    n_sec_msgs = jnp.sum(jnp.any(relayed) &
                         ((state["role"] == SECRETARY) & state["alive"] &
                          (state["warn_timer"] < 0)))
    msg_budget = jnp.maximum(
        jnp.int32(static["msg_budget"]) - n_sec_msgs, 0)
    # cost of a batch scales with its payload (network/CPU bytes): this is
    # what makes the single leader the bottleneck at scale (paper §1)
    pending = jnp.maximum(state["log_len"][lid_c] - state["match_len"], 0)
    batch_cost = 1 + jnp.minimum(pending, static["max_ship"]) //         static["entries_per_msg"]
    rank = jnp.cumsum(jnp.where(direct, batch_cost, 0))
    ship = relayed | (direct & (rank <= msg_budget))
    app_arrive_t = jnp.where(ship, arrive, state["app_arrive_t"])
    app_from_len = jnp.where(ship, state["match_len"], state["app_from_len"])
    app_upto = jnp.where(
        ship, jnp.minimum(state["log_len"][lid_c],
                          state["match_len"] + static["max_ship"]),
        state["app_upto"])
    app_term = jnp.where(ship, state["term"][lid_c], state["app_term"])
    app_commit = jnp.where(ship, state["commit_len"][lid_c],
                           state["app_commit"])
    # leader work accounting: direct messages + one per active secretary
    leader_work = state["leader_work"].at[lid_c].add(
        jnp.sum(ship & direct) + n_sec_msgs)

    return dict(state, app_arrive_t=app_arrive_t, app_from_len=app_from_len,
                app_upto=app_upto, app_term=app_term, app_commit=app_commit,
                leader_work=leader_work)


def follower_step(state, static, cfg_c, *, reference=False, backend="xla"):
    """Deliver due append batches: log-matching check, truncate-adopt,
    schedule acks; followers forward to observers eagerly (Step 6, Fig. 5).

    The window adopt is position-aligned (a follower copies the LEADER'S
    row at the same log indices), so the fast path expresses it as one
    elementwise select over (N, L) with the broadcast leader row — XLA CPU
    vectorizes it, unlike the (N, W) gather + scatter of the PR-1
    formulation, which `reference=True` preserves bit-for-bit as the
    benchmark baseline (`benchmarks/perf_fleet.py`, DESIGN.md §7.1).
    `backend="pallas"` fuses the prev-term check, conflict truncation,
    and append into one VMEM pass (`kernels/raft_tick`, DESIGN.md §8) —
    bit-identical to both XLA formulations (test invariant)."""
    N = state["role"].shape[0]
    L = state["log_term"].shape[1]
    tick = state["tick"]
    lid = leader_id(state, static)
    lid_c = jnp.maximum(lid, 0)
    rtt = jnp.asarray(static["rtt"])

    delivered = (state["app_arrive_t"] >= 0) & \
        (state["app_arrive_t"] <= tick) & state["alive"]
    # term check: reject stale-term appends (Property 3.1/3.3); the slot
    # clears on ANY delivery, else stale batches deadlock the link
    ok_term = state["app_term"] >= state["term"]
    due = delivered & ok_term & (lid >= 0)

    W = static["max_ship"]
    if backend == "pallas" and not reference:
        # fused kernel: log-matching check + truncate + append in one
        # pass through VMEM; accept comes back out for the ack schedule
        log_term, log_key, log_val, new_len, accept = \
            rt_ops.log_match_append(
                state["log_term"], state["log_key"], state["log_val"],
                state["log_term"][lid_c], state["log_key"][lid_c],
                state["log_val"][lid_c],
                state["log_len"], state["app_from_len"],
                state["app_upto"], due, w=W)
        nack = due & ~accept
    else:
        # log-matching at prev = app_from_len-1: follower's term at that
        # index must equal the leader's (content is the leader's log row).
        prev = state["app_from_len"] - 1
        prev_c = jnp.clip(prev, 0, L - 1)
        my_prev_term = jnp.take_along_axis(
            state["log_term"], prev_c[:, None], axis=1)[:, 0]
        ldr_prev_term = state["log_term"][lid_c, prev_c]
        match = (prev < 0) | (my_prev_term == ldr_prev_term)
        accept = due & match
        # mismatch: nack -> leader will retry from an earlier match
        # point; we model the optimized backtrack by halving match_len
        nack = due & ~match

        # adopt leader entries [from_len, upto) — window-bounded copy
        if reference:
            # PR-1 formulation: (N, W) gather of the leader window, then
            # a masked scatter back — kept only as the perf baseline
            base = jnp.where(accept, state["app_from_len"], 0)
            widx = base[:, None] + jnp.arange(W)[None, :]     # (N,W)
            valid = accept[:, None] & \
                (widx < state["app_upto"][:, None]) & (widx < L)
            widx_c = jnp.clip(widx, 0, L - 1)
            ldr_terms = state["log_term"][lid_c][widx_c]
            ldr_keys = state["log_key"][lid_c][widx_c]
            ldr_vals = state["log_val"][lid_c][widx_c]
            rows = jnp.broadcast_to(jnp.arange(N)[:, None], widx.shape)
            put = lambda dst, src: dst.at[
                jnp.where(valid, rows, N),
                jnp.where(valid, widx_c, L)].set(src, mode="drop")
            log_term = put(state["log_term"], ldr_terms)
            log_key = put(state["log_key"], ldr_keys)
            log_val = put(state["log_val"], ldr_vals)
        else:
            # fast path: position p adopts leader_row[p] iff p lies in
            # the accepted window [from_len, min(upto, from_len + W))
            pos = jnp.arange(L)[None, :]                      # (1,L)
            lo = state["app_from_len"][:, None]
            hi = jnp.minimum(state["app_upto"],
                             state["app_from_len"] + W)[:, None]
            sel = accept[:, None] & (pos >= lo) & (pos < hi)
            adopt = lambda dst, ldr_row: jnp.where(sel, ldr_row[None, :],
                                                   dst)
            log_term = adopt(state["log_term"], state["log_term"][lid_c])
            log_key = adopt(state["log_key"], state["log_key"][lid_c])
            log_val = adopt(state["log_val"], state["log_val"][lid_c])
        new_len = jnp.where(accept,
                            jnp.minimum(state["app_upto"],
                                        state["app_from_len"] + W),
                            state["log_len"])
        new_len = jnp.where(accept & (state["log_len"] > new_len) &
                            (my_prev_term == ldr_prev_term),
                            jnp.maximum(state["log_len"], new_len), new_len)
    # followers adopt term & learn commit (piggybacked)
    term = jnp.where(due, jnp.maximum(state["term"], state["app_term"]),
                     state["term"])
    role = jnp.where(due & (state["role"] == CANDIDATE), FOLLOWER,
                     state["role"])
    commit_len = jnp.where(accept,
                           jnp.maximum(state["commit_len"],
                                       jnp.minimum(state["app_commit"],
                                                   new_len)),
                           state["commit_len"])
    # heartbeat resets election timer (deterministic jitter from tick+id)
    span = cfg_c["election_timeout_max"] - cfg_c["election_timeout_min"] + 1
    jitter = (tick + jnp.arange(N) * 7) % span
    election_timer = jnp.where(
        due, cfg_c["election_timeout_min"] + jitter,
        state["election_timer"])

    # ack back via the same relay path
    sec = state["sec_of"]
    # a warned secretary hands its fan-out back to the leader NOW, so
    # no in-flight batch is stranded when the kill lands (DESIGN.md §12;
    # `warn_timer < 0` is all-True whenever warnings are off)
    sec_alive = (sec >= 0) & state["alive"][jnp.maximum(sec, 0)] & \
        (state["role"][jnp.maximum(sec, 0)] == SECRETARY) & \
        (state["warn_timer"][jnp.maximum(sec, 0)] < 0)
    relay = jnp.where(sec_alive, sec, lid_c)
    lat = rtt[jnp.arange(N), relay] + rtt[relay, lid_c] * (relay != lid_c)
    ack_arrive_t = jnp.where(accept | nack, tick + lat,
                             state["ack_arrive_t"])
    ack_upto = jnp.where(accept, new_len,
                         jnp.where(nack, state["app_from_len"] // 2,
                                   state["ack_upto"]))

    app_arrive_t = jnp.where(delivered, -1, state["app_arrive_t"])
    return dict(state, log_term=log_term, log_key=log_key, log_val=log_val,
                log_len=new_len, term=term, role=role, commit_len=commit_len,
                election_timer=election_timer, ack_arrive_t=ack_arrive_t,
                ack_upto=ack_upto, app_arrive_t=app_arrive_t)


def commit_step(state, static, cfg_c, *, reference=False, backend="xla"):
    """Leader ingests due acks -> match_len; commits majority-replicated
    prefix (voters only); records entry commit times.

    The majority test is computed from the majority-th largest voter
    match_len (one (N,) sort) on the fast path — `counts(l) >= majority`
    iff `l <= that order statistic` since counts is non-increasing in l —
    instead of the PR-1 O(L·N) comparison matrix (`reference=True`).
    `backend="pallas"` computes the same order statistic blockwise with
    the voter mask applied in-register (`kernels/raft_tick`, DESIGN.md
    §8) — bit-identical (test invariant).

    2PC coupling (DESIGN.md §9): entries marked as cross-shard
    coordinators (`cross_shard_mark`) record their commit time shifted by
    `two_pc_ticks` — the prepare + commit round with the partner shard's
    leader — so the 2PC tax flows into the measured write-latency
    histogram per request instead of being added post hoc.  The charge is
    applied identically on the reference/xla/pallas paths (it is model
    semantics, not a formulation) and never feeds back into dynamics."""
    N = state["role"].shape[0]
    L = state["log_term"].shape[1]
    tick = state["tick"]
    lid = leader_id(state, static)
    lid_c = jnp.maximum(lid, 0)
    has_leader = lid >= 0

    ack_due = (state["ack_arrive_t"] >= 0) & (state["ack_arrive_t"] <= tick)
    # ack ingestion is budgeted the same way: direct acks consume leader
    # capacity, secretary-aggregated reports are O(#secretaries)
    sec = state["sec_of"]
    # a warned secretary hands its fan-out back to the leader NOW, so
    # no in-flight batch is stranded when the kill lands (DESIGN.md §12;
    # `warn_timer < 0` is all-True whenever warnings are off)
    sec_alive = (sec >= 0) & state["alive"][jnp.maximum(sec, 0)] & \
        (state["role"][jnp.maximum(sec, 0)] == SECRETARY) & \
        (state["warn_timer"][jnp.maximum(sec, 0)] < 0)
    direct_ack = ack_due & ~sec_alive
    rank = jnp.cumsum(direct_ack.astype(jnp.int32))
    ingest = (ack_due & sec_alive) | \
        (direct_ack & (rank <= static["msg_budget"]))
    match_len = jnp.where(ingest, jnp.maximum(state["match_len"],
                                              state["ack_upto"]),
                          state["match_len"])
    # nacks shrink match (ack_upto < match): allow decrease for retry
    match_len = jnp.where(ingest & (state["ack_upto"] <
                                    state["match_len"]),
                          state["ack_upto"], match_len)
    ack_arrive_t = jnp.where(ingest, -1, state["ack_arrive_t"])
    match_len = match_len.at[lid_c].set(
        jnp.where(has_leader, state["log_len"][lid_c], match_len[lid_c]))

    # commit = largest l such that #voters with match>=l is a majority,
    # restricted to entries of the current term (Raft §5.4.2)
    is_voter = jnp.asarray(static["is_voter"])
    lens = jnp.arange(L) + 1
    if backend == "pallas" and not reference:
        commit = rt_ops.commit_majority(
            match_len, is_voter & state["alive"],
            state["log_term"][lid_c], state["term"][lid_c],
            jnp.asarray(static["majority"], jnp.int32))
    else:
        if reference:
            counts = jnp.sum((match_len[None, :] >=
                              (jnp.arange(L) + 1)[:, None]) &
                             is_voter[None, :] & state["alive"][None, :],
                             axis=1)
            can = counts >= static["majority"]
        else:
            vmatch = jnp.where(is_voter & state["alive"], match_len, -1)
            kth = jnp.sort(vmatch)[::-1][
                jnp.maximum(static["majority"] - 1, 0)]
            can = lens <= kth
        term_ok = state["log_term"][lid_c, jnp.arange(L)] == \
            state["term"][lid_c]
        commit = jnp.max(jnp.where(can & term_ok, lens, 0))
    new_commit = jnp.where(has_leader,
                           jnp.maximum(state["commit_len"][lid_c], commit),
                           0)
    newly = (jnp.arange(L) >= state["commit_len"][lid_c]) & \
        (jnp.arange(L) < new_commit) & has_leader
    # cross-shard coordinators pay the two inter-site 2PC rounds before
    # the client sees the commit (DESIGN.md §9); intra-shard entries and
    # ungrouped members (cross_frac == 0) record plain `tick`
    cross = cross_shard_mark(jnp.arange(L), cfg_c["cross_frac"])
    commit_seen_t = tick + jnp.where(cross, cfg_c["two_pc_ticks"], 0)
    entry_commit_t = jnp.where(newly & (state["entry_commit_t"] < 0),
                               commit_seen_t, state["entry_commit_t"])
    commit_len = state["commit_len"].at[lid_c].set(
        jnp.where(has_leader, new_commit, state["commit_len"][lid_c]))
    n_new = jnp.where(has_leader,
                      new_commit - state["commit_len"][lid_c], 0)
    state = dict(state, match_len=match_len, ack_arrive_t=ack_arrive_t,
                 commit_len=commit_len, entry_commit_t=entry_commit_t,
                 writes_committed=state["writes_committed"] + n_new)
    # commit-advance + 2PC-commit seams -> ring + registry (§9/§14):
    # one event per tick the commit index moves (aux = new length) and
    # one per tick any cross-shard coordinators land in the advance
    n_cross = jnp.sum(newly & cross).astype(jnp.int32)
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_COMMIT, valid=n_new > 0, node=lid_c,
        term=state["term"][lid_c], aux=new_commit,
        counter="commit_advances")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_2PC_COMMIT, valid=n_cross > 0,
        node=lid_c, term=state["term"][lid_c], aux=n_cross,
        counter="twopc_committed", count=n_cross)
    state = trace_metrics.bump(state, "entries_committed", n_new)
    return state


def apply_step(state, static, cfg_c, *, reference=False, backend="xla"):
    """All nodes apply committed entries to their KV state machine
    (bounded per tick; Property 3.2 order = log order).  `reference=True`
    keeps the PR-1 Python-unrolled loop of A sequential scatters as the
    perf baseline; the fast path dedupes and scatters once.
    `backend="pallas"` replaces the scatter with an in-register
    last-wins select over (N, K) blocks (`kernels/raft_tick`, DESIGN.md
    §8) — bit-identical (test invariant)."""
    N, L = state["log_term"].shape
    A = static["max_apply"]
    base = state["applied_len"]                               # (N,)
    todo = jnp.minimum(state["commit_len"] - base, A)
    offs = jnp.arange(A)[None, :]
    idx = base[:, None] + offs
    valid = (offs < todo[:, None]) & (idx < L) & state["alive"][:, None]
    idx_c = jnp.clip(idx, 0, L - 1)
    keys = jnp.take_along_axis(state["log_key"], idx_c, axis=1)
    vals = jnp.take_along_axis(state["log_val"], idx_c, axis=1)
    rows = jnp.broadcast_to(jnp.arange(N)[:, None], keys.shape)
    K = state["kv"].shape[1]
    if backend == "pallas" and not reference:
        kv = rt_ops.apply_last_wins(state["kv"], keys, vals, valid)
    elif reference:
        # PR-1: apply sequentially over the A offsets to preserve order
        kv = state["kv"]
        for a in range(A):
            kv = kv.at[jnp.where(valid[:, a], jnp.arange(N), N),
                       jnp.where(valid[:, a], keys[:, a], K)].set(
                vals[:, a], mode="drop")
    else:
        # later entries win.  A single scatter with duplicate (row, key)
        # pairs has unspecified order, so dedupe first: drop any entry
        # that a LATER valid entry in the same row overwrites (O(A^2)
        # mask, A small), then scatter every surviving entry at once —
        # one HLO scatter instead of A sequential ones (compile time and
        # HLO size stay flat in max_apply).
        offs_a = jnp.arange(A)
        later = offs_a[:, None] < offs_a[None, :]             # (A, A): b > a
        overwritten = jnp.any(later[None, :, :] &
                              (keys[:, :, None] == keys[:, None, :]) &
                              valid[:, None, :], axis=2)      # (N, A)
        keep = valid & ~overwritten
        kv = state["kv"].at[jnp.where(keep, rows, N),
                            jnp.where(keep, keys, K)].set(vals, mode="drop")
    applied = base + jnp.maximum(todo, 0)
    # rolling applied-prefix digest (DESIGN.md §13): XOR in the mix of
    # every entry applied this tick.  Shared by all three formulations
    # (it is model semantics, not a formulation), RNG-free, and
    # independent of the digest-tier width O.
    out = dict(state, kv=kv, applied_len=applied)
    if "applied_digest" in state:      # minimal unit-test states omit it
        contrib = jnp.where(valid, entry_mix(idx_c, keys, vals),
                            jnp.uint32(0))                    # (N, A)
        digest = state["applied_digest"]
        for a in range(A):
            digest = digest ^ contrib[:, a]
        out["applied_digest"] = digest
    return out


def observer_sync_step(state, static, cfg_c):
    """Followers eagerly forward appended entries to their observers
    (paper Fig. 5 / §3.1 Step 6): observers mirror their follower's applied
    state machine with intra-site lag (rtt_intra=1 tick)."""
    is_obs = (state["role"] == OBSERVER) & state["alive"]
    fol = jnp.maximum(state["obs_of"], 0)
    fol_ok = (state["obs_of"] >= 0) & state["alive"][fol]
    sync = is_obs & fol_ok
    applied = jnp.where(sync, state["applied_len"][fol],
                        state["applied_len"])
    commit = jnp.where(sync, state["commit_len"][fol], state["commit_len"])
    log_len = jnp.where(sync, state["log_len"][fol], state["log_len"])
    kv = jnp.where(sync[:, None], state["kv"][fol], state["kv"])
    # observers mirror the log too (they apply the same commands in the
    # same order — Property 3.2 holds across observer replicas)
    lt = jnp.where(sync[:, None], state["log_term"][fol], state["log_term"])
    lk = jnp.where(sync[:, None], state["log_key"][fol], state["log_key"])
    lv = jnp.where(sync[:, None], state["log_val"][fol], state["log_val"])
    # the applied-prefix digest travels with the applied state it
    # fingerprints (DESIGN.md §13), so the prefix-mirror claim above is
    # checkable: observer digest == follower digest at the same applied
    dg = jnp.where(sync, state["applied_digest"][fol],
                   state["applied_digest"])
    return dict(state, applied_len=applied, commit_len=commit,
                log_len=log_len, kv=kv, log_term=lt, log_key=lk, log_val=lv,
                applied_digest=dg)


def anti_entropy_step(state, static, cfg_c, *, backend="xla"):
    """Batched anti-entropy rounds for the digest-tier observers
    (DESIGN.md §13; the sparse scale-out twin of `observer_sync_step`).

    A digest observer `o` syncs on ticks where
    `(tick + ae_phase[o]) % ae_interval == 0` — `ae_interval` and the
    `(O,)` phase schedule ride in cfg_c as jit-argument data, so gossip
    cadences sweep without recompiling (the §10 trace rule).  On a due
    round the observer adopts its source's `(applied_len, term,
    applied_digest)` triple — a few scalars per observer, never a log
    row, which is what lets O run 50X past the dense node count.  The
    adopt is monotone (an observer never regresses its applied index,
    e.g. when failing over to a less-caught-up voter), but the sync
    *timestamp* still advances on any completed round: freshness bounds
    time-since-contact, and the observer's own state is at least as new
    as the source's.  Source = the wired follower (`dobs_fol`), falling
    back in-graph to the first alive voter when the follower is down.
    No RNG is drawn; at O == 0 this is a python no-op.

    `backend="pallas"` fuses the due rule, the any-live-voter fallback,
    the monotone adoption, and the sync-hop RTT aging into one pass
    over the observer lanes (`kernels/ae_sync`, DESIGN.md §8) —
    bit-identical to the XLA gather formulation below (test
    invariant)."""
    O = state["dobs_alive"].shape[0] if "dobs_alive" in state else 0
    if O == 0:
        return state
    # the due rule / source selection, hoisted above the backend split
    # (RNG-free, a few O-wide gathers): the XLA path consumes it
    # directly, the pallas kernel recomputes it internally — and the
    # flight-recorder events below (DESIGN.md §14) read THESE values so
    # the decoded event stream is backend-uniform
    N = state["role"].shape[0]
    tick = state["tick"]
    is_voter = jnp.asarray(static["is_voter"])
    fol = state["dobs_fol"]
    fol_c = jnp.clip(fol, 0, N - 1)
    fol_ok = (fol >= 0) & state["alive"][fol_c] & is_voter[fol_c]
    alive_voter = is_voter & state["alive"]
    any_voter = jnp.any(alive_voter)
    fallback = jnp.argmax(alive_voter)
    eff = jnp.where(fol_ok, fol_c, fallback)
    interval = jnp.maximum(cfg_c["ae_interval"], 1)
    due = state["dobs_alive"] & (fol_ok | any_voter) & \
        (jnp.mod(tick + cfg_c["ae_phase"], interval) == 0)
    src_applied = state["applied_len"][eff]

    if backend == "pallas":
        applied, term, digest, synced = ae_ops.ae_sync(
            state["dobs_alive"], state["dobs_fol"], state["dobs_applied"],
            state["dobs_term"], state["dobs_digest"],
            state["dobs_synced_t"], cfg_c["ae_phase"],
            jnp.asarray(static["dobs_site"]), state["alive"],
            jnp.asarray(static["is_voter"]), state["applied_len"],
            state["term"], state["applied_digest"],
            jnp.asarray(static["site"]), jnp.asarray(static["site_rtt"]),
            state["tick"], cfg_c["ae_interval"])
        state = dict(state, dobs_applied=applied, dobs_term=term,
                     dobs_digest=digest, dobs_synced_t=synced)
        return _ae_trace(state, cfg_c, due, fol_ok, eff, src_applied)
    adopt = due & (src_applied >= state["dobs_applied"])
    applied = jnp.where(adopt, src_applied, state["dobs_applied"])
    term = jnp.where(adopt, state["term"][eff], state["dobs_term"])
    digest = jnp.where(adopt, state["applied_digest"][eff],
                       state["dobs_digest"])
    # the adopted state ages by the transfer hop (site-pair RTT): a sync
    # from the observer's own site costs rtt_intra, a cross-site
    # fallback costs the inter-site trip — so a remote fallback is
    # honestly staler and reroutes sooner under a tight bound
    hop = jnp.asarray(static["site_rtt"])[
        jnp.asarray(static["dobs_site"]),
        jnp.asarray(static["site"])[eff]]
    synced = jnp.where(due, tick - hop, state["dobs_synced_t"])
    state = dict(state, dobs_applied=applied, dobs_term=term,
                 dobs_digest=digest, dobs_synced_t=synced)
    return _ae_trace(state, cfg_c, due, fol_ok, eff, src_applied)


def _ae_trace(state, cfg_c, due, fol_ok, eff, src_applied):
    """Anti-entropy seam -> ring + registry (§13/§14): one `ae_sync`
    event per due observer slot (node lane = the SLOT index — the
    Perfetto exporter maps it to a site track via `static["dobs_site"]`;
    term lane = source node id; aux = source applied length), plus an
    `ae_fallback` event when the round used the any-voter fallback."""
    o_ids = jnp.arange(due.shape[0])
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_AE_SYNC, valid=due, node=o_ids,
        term=eff, aux=src_applied, counter="ae_rounds")
    return trace_ring.record(
        state, cfg_c, trace_ring.EV_AE_FALLBACK, valid=due & ~fol_ok,
        node=o_ids, term=eff, aux=src_applied, counter="ae_fallbacks")


def read_step(state, static, cfg_c):
    """Serve queued reads through the read-index round (DESIGN.md §11).

    Observers serve only if applied >= readindex (= leader commit at
    request time; approximated by current leader commit) — the observer
    apply-index wait; otherwise the read reroutes to the observer's
    follower (+rtt).  Latency = service wait (queue/capacity) + the
    readindex confirmation fence (via global secretary when present —
    §4.3).  Every served request's integer-tick latency lands in the
    unit-bin `read_lat_hist` — the read-side twin of the write
    histogram, same `period_ticks + 1 + HIST_TAIL` layout (DESIGN.md
    §7.1/§11), so `runtime.hist_stats` recovers read p95/p99 exactly.
    Digest-tier observers (DESIGN.md §13) serve under a *bounded
    staleness* contract instead: a digest slot serves its queue iff
    `tick - dobs_synced_t <= cfg_c["staleness_bound"]` — the anti-entropy
    round amortizes the readindex fence across the whole cohort, so a
    served digest read pays queue wait + unit service only, no per-read
    fence trip.  Each served request's staleness lands in the unit-bin
    `obs_stale_hist` (so staleness p99 is exact, and <= the bound by
    construction); a slot that is behind the bound (or dead/warned with
    a residual queue) reroutes to its follower's queue, counted in
    `obs_rerouted`.

    Returns `(state, (served, lat, obs_served, obs_stale))` — per-node
    and per-digest-slot raw samples this tick, consumed by the tick
    metrics for the numpy-recomputation pin tests
    (`tests/test_serving.py`, `tests/test_observers.py`)."""
    N = state["role"].shape[0]
    tick = state["tick"]
    lid = leader_id(state, static)
    lid_c = jnp.maximum(lid, 0)
    rtt = jnp.asarray(static["rtt"])
    cap = jnp.int32(static["work_capacity"])

    is_obs = (state["role"] == OBSERVER) & state["alive"]
    is_srv = ((state["role"] == FOLLOWER) | (state["role"] == LEADER)) & \
        state["alive"]
    readindex = state["commit_len"][lid_c]
    fresh = state["applied_len"] >= readindex
    can_serve = (is_obs & fresh) | is_srv

    served = jnp.where(can_serve, jnp.minimum(state["read_queue"], cap), 0)
    # stale observers reroute to their follower (1 extra hop)
    fol = jnp.maximum(state["obs_of"], 0)
    reroute = jnp.where(is_obs & ~fresh, state["read_queue"], 0)
    read_queue = state["read_queue"] - served - reroute
    read_queue = read_queue.at[fol].add(
        jnp.where(is_obs & ~fresh, reroute, 0), mode="drop")

    # latency model: queue wait + readindex confirmation.  With a global
    # secretary alive the leader needs no self-confirmation round (§4.3),
    # halving the observer readindex trip.
    any_sec = jnp.any((state["role"] == SECRETARY) & state["alive"])
    ri_rtt = rtt[jnp.arange(N), lid_c] * jnp.where(any_sec, 1, 2)
    wait = state["read_queue"] // jnp.maximum(cap, 1)
    lat = (wait + 1 + jnp.where(is_obs, ri_rtt, rtt[jnp.arange(N), lid_c]))
    lat_sum = jnp.sum(jnp.where(served > 0,
                                lat.astype(jnp.float32) * served, 0.0))
    lat_max = jnp.max(jnp.where(served > 0, lat.astype(jnp.float32), 0.0))
    # per-request histogram: `served` requests at integer latency `lat`
    # per node, overload tails clipped into the last bin
    H = state["read_lat_hist"].shape[0]
    bins = jnp.clip(lat, 0, H - 1)
    read_hist = state["read_lat_hist"].at[
        jnp.where(served > 0, bins, H)].add(served, mode="drop")

    # --- digest-tier serving (DESIGN.md §13; python no-op at O == 0) ----
    O = state["dobs_alive"].shape[0] if "dobs_alive" in state else 0
    extra = {}
    obs_served = jnp.zeros((O,), jnp.int32)
    obs_stale = jnp.zeros((O,), jnp.int32)
    if O:
        q = state["dobs_read_queue"]
        stale = tick - state["dobs_synced_t"]
        can_d = state["dobs_alive"] & \
            (stale <= cfg_c["staleness_bound"])
        obs_served = jnp.where(can_d, jnp.minimum(q, cap), 0)
        reroute_d = jnp.where(~can_d, q, 0)
        # failover target = same source rule as `anti_entropy_step`
        is_voter = jnp.asarray(static["is_voter"])
        fold = state["dobs_fol"]
        fold_c = jnp.clip(fold, 0, N - 1)
        fol_ok = (fold >= 0) & state["alive"][fold_c] & is_voter[fold_c]
        eff = jnp.where(fol_ok, fold_c,
                        jnp.argmax(is_voter & state["alive"]))
        read_queue = read_queue.at[
            jnp.where(reroute_d > 0, eff, N)].add(reroute_d, mode="drop")
        # latency: queue wait + unit service, served at the observer's
        # own site — the fence is amortized by the anti-entropy round
        wait_d = q // jnp.maximum(cap, 1)
        lat_d = wait_d + 1
        lat_sum = lat_sum + jnp.sum(jnp.where(
            obs_served > 0, lat_d.astype(jnp.float32) * obs_served, 0.0))
        lat_max = jnp.maximum(lat_max, jnp.max(jnp.where(
            obs_served > 0, lat_d.astype(jnp.float32), 0.0)))
        read_hist = read_hist.at[
            jnp.where(obs_served > 0, jnp.clip(lat_d, 0, H - 1), H)
        ].add(obs_served, mode="drop")
        obs_stale = jnp.where(obs_served > 0, stale, 0)
        extra = dict(
            dobs_read_queue=q - obs_served - reroute_d,
            obs_stale_hist=state["obs_stale_hist"].at[
                jnp.where(obs_served > 0, jnp.clip(stale, 0, H - 1), H)
            ].add(obs_served, mode="drop"),
            obs_reads_served=state["obs_reads_served"] +
            jnp.sum(obs_served),
            obs_rerouted=state["obs_rerouted"] + jnp.sum(reroute_d))

    total_served = jnp.sum(served)
    if O:
        total_served = total_served + jnp.sum(obs_served)
    state = dict(state, **extra, read_queue=read_queue,
                 reads_served=state["reads_served"] + total_served,
                 read_lat_sum=state["read_lat_sum"] + lat_sum,
                 read_lat_max=jnp.maximum(state["read_lat_max"], lat_max),
                 read_lat_hist=read_hist)
    return state, (served, lat, obs_served, obs_stale)


def election_step(state, static, cfg_c, rng):
    """Timeouts -> candidacy; RequestVote/grants with log restriction;
    majority of voters -> leader (Property 3.1)."""
    N = state["role"].shape[0]
    L = state["log_term"].shape[1]
    tick = state["tick"]
    rtt = jnp.asarray(static["rtt"])
    is_voter = jnp.asarray(static["is_voter"])
    r_timeout, = _rand(rng, 1)

    # --- timers ----------------------------------------------------------
    lid = leader_id(state, static)
    et = state["election_timer"] - 1
    timed_out = (et <= 0) & is_voter & state["alive"] & \
        ((state["role"] == FOLLOWER) | (state["role"] == CANDIDATE))
    # become candidate
    term = jnp.where(timed_out, state["term"] + 1, state["term"])
    role = jnp.where(timed_out, CANDIDATE, state["role"])
    voted_for = jnp.where(timed_out, jnp.arange(N), state["voted_for"])
    new_timeout = jax.random.randint(
        r_timeout, (N,), cfg_c["election_timeout_min"],
        cfg_c["election_timeout_max"] + 1)
    et = jnp.where(timed_out | (et <= 0), new_timeout, et)

    # candidates broadcast vote requests (one in-flight slot per voter;
    # higher term wins the slot)
    is_cand = (role == CANDIDATE) & state["alive"]
    cand_term = jnp.where(is_cand, term, -1)
    best_cand = jnp.argmax(cand_term)                         # highest term
    have_cand = jnp.max(cand_term) >= 0
    last_len = state["log_len"][best_cand]
    last_term = state["log_term"][best_cand,
                                  jnp.clip(last_len - 1, 0, L - 1)]
    newer = term[best_cand] > state["vreq_term"]
    place = have_cand & is_voter & newer & state["alive"]
    vreq_t = jnp.where(place, tick + rtt[best_cand], state["vreq_t"])
    vreq_from = jnp.where(place, best_cand, state["vreq_from"])
    vreq_term = jnp.where(place, term[best_cand], state["vreq_term"])
    vreq_lastterm = jnp.where(place, last_term, state["vreq_lastterm"])
    vreq_lastlen = jnp.where(place, last_len, state["vreq_lastlen"])

    # --- process due vote requests --------------------------------------
    due = (vreq_t >= 0) & (vreq_t <= tick) & state["alive"] & is_voter
    req_term = vreq_term
    higher = req_term > term
    # flight-recorder mask (§14): leaders demoted by a higher-term
    # request — captured before the role rewrite
    dem_higher = due & higher & (role == LEADER)
    term = jnp.where(due & higher, req_term, term)
    role = jnp.where(due & higher & (role == LEADER), FOLLOWER, role)
    role = jnp.where(due & higher & (role == CANDIDATE), FOLLOWER, role)
    voted_for = jnp.where(due & higher, -1, voted_for)
    my_last_len = state["log_len"]
    my_last_term = jnp.take_along_axis(
        state["log_term"], jnp.clip(my_last_len - 1, 0, L - 1)[:, None],
        axis=1)[:, 0]
    log_ok = (vreq_lastterm > my_last_term) | \
        ((vreq_lastterm == my_last_term) & (vreq_lastlen >= my_last_len))
    can_grant = due & (req_term >= term) & log_ok & \
        ((voted_for == -1) | (voted_for == vreq_from))
    voted_for = jnp.where(can_grant, vreq_from, voted_for)
    et = jnp.where(can_grant, new_timeout, et)      # granting defers timeout
    # schedule grant arrival at candidate
    grant_t = jnp.where(can_grant,
                        tick + rtt[jnp.arange(N),
                                   jnp.maximum(vreq_from, 0)],
                        state["grant_t"])
    grant_to = jnp.where(can_grant, vreq_from, state["grant_to"])
    grant_term = jnp.where(can_grant, req_term, state["grant_term"])
    vreq_t = jnp.where(due, -1, vreq_t)

    # --- candidates tally grants (accumulated across ticks) --------------
    g_due = (grant_t >= 0) & (grant_t <= tick)
    tgt = jnp.maximum(grant_to, 0)
    term_match = grant_term == term[tgt]
    arrivals = jnp.zeros((N,), jnp.int32).at[
        jnp.where(g_due & term_match, tgt, N)].add(1, mode="drop")
    vr = jnp.where(timed_out, 0, state["votes_received"])   # new candidacy
    vr = jnp.where(role == CANDIDATE, vr + arrivals, 0)
    votes = vr + 1                                           # self-vote
    win = (role == CANDIDATE) & state["alive"] & \
        (votes >= static["majority"])
    role = jnp.where(win, LEADER, role)
    grant_t = jnp.where(g_due, -1, grant_t)
    # demote any older-term leader the moment a newer one exists
    max_leader_term = jnp.max(jnp.where((role == LEADER) & state["alive"],
                                        term, -1))
    dem_older = (role == LEADER) & (term < max_leader_term)
    role = jnp.where((role == LEADER) & (term < max_leader_term),
                     FOLLOWER, role)
    # new leader: reset bookkeeping, stop secretaries (paper Step 1); the
    # manager re-provisions them next period (Step 2)
    any_new = jnp.any(win)
    match_len = jnp.where(any_new, jnp.zeros_like(state["match_len"]),
                          state["match_len"])
    sec_stop = any_new & (role == SECRETARY) & state["alive"]
    role = jnp.where(any_new & (role == SECRETARY), DEAD, role)
    alive = state["alive"] & ~(any_new & (state["role"] == SECRETARY))
    heartbeat_timer = jnp.where(win, 0, state["heartbeat_timer"])

    state = dict(state, alive=alive, term=term, role=role,
                 voted_for=voted_for, votes_received=vr,
                 election_timer=et, vreq_t=vreq_t, vreq_from=vreq_from,
                 vreq_term=vreq_term, vreq_lastterm=vreq_lastterm,
                 vreq_lastlen=vreq_lastlen, grant_t=grant_t,
                 grant_to=grant_to, grant_term=grant_term,
                 match_len=match_len, heartbeat_timer=heartbeat_timer)

    # election seam -> ring + registry (DESIGN.md §14): candidacies,
    # grants (aux = candidate), wins (aux = tallied votes), the two
    # leader-demotion rules, and the new-leader secretary stop — every
    # mask captured above at the point its rule fired
    nid = jnp.arange(N)
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_CANDIDACY, valid=timed_out, node=nid,
        term=term, counter="elections_started")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_GRANT, valid=can_grant, node=nid,
        term=req_term, aux=vreq_from, counter="votes_granted")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_ELECT, valid=win, node=nid,
        term=term, aux=votes, counter="leader_elected")
    state = trace_ring.record(
        state, cfg_c, trace_ring.EV_STEPDOWN,
        valid=dem_higher | dem_older, node=nid, term=term,
        counter="leader_stepdowns")
    return trace_ring.record(
        state, cfg_c, trace_ring.EV_SEC_STOP, valid=sec_stop, node=nid,
        term=term, counter="sec_stops")


def cost_step(state, static, cfg_c):
    """Accrue $ cost: on-demand voters + alive spot nodes (eq. 1).
    Digest-tier observers (DESIGN.md §13) bill as spot instances at their
    site's spot price and count toward the linear network term — they
    are cheap because they are spot and stateless, not free."""
    site = jnp.asarray(static["site"])
    is_voter = jnp.asarray(static["is_voter"])
    od_price = cfg_c["on_demand_price"][site]
    sp_price = state["spot_price"][site]
    spot_sum = jnp.sum(jnp.where(~is_voter & state["alive"], sp_price, 0.0))
    n_alive = jnp.sum(state["alive"])
    O = state["dobs_alive"].shape[0] if "dobs_alive" in state else 0
    if O:
        d_price = state["spot_price"][jnp.asarray(static["dobs_site"])]
        spot_sum = spot_sum + jnp.sum(jnp.where(state["dobs_alive"],
                                                d_price, 0.0))
        n_alive = n_alive + jnp.sum(state["dobs_alive"])
    per_tick = jnp.sum(jnp.where(is_voter & state["alive"], od_price, 0.0)) \
        + spot_sum
    per_tick = per_tick / cfg_c["ticks_per_hour"]
    # + C: linear network cost in total instances
    per_tick = per_tick * (1.0 + cfg_c["network_cost_coef"] * n_alive)
    return dict(state, cost_accrued=state["cost_accrued"] + per_tick)


def tick(state, static, cfg_c, rng, *, reference=False,
         backend="xla") -> Tuple[Dict, Dict]:
    """One full protocol tick. Returns (state, per-tick metrics).

    `reference=True` selects the PR-1 formulations of the follower adopt,
    the commit majority test, and the apply scatter — bit-identical
    results, kept as the epoch-loop perf baseline (DESIGN.md §7.1,
    `benchmarks/perf_fleet.py`); the equivalence is a test invariant
    (`tests/test_fleet.py`).  `backend` selects the implementation of
    the tick hot ops on the non-reference path: `"xla"` (the PR-2 fast
    formulations, default), `"pallas"` (the fused kernel families —
    `raft_tick`, `leader_fanout`, `ae_sync` — interpret-mode on CPU,
    DESIGN.md §8), or `"auto"` (pallas on TPU, xla elsewhere — the
    per-platform resolution rule); results are bit-identical across
    all of them (`tests/test_raft_tick_kernels.py`,
    `tests/test_wide_kernels.py`, `benchmarks/perf_tick.py`)."""
    backend = resolve_backend(backend)
    # reference runs pin the PR-1 ops AND the XLA forms of the paths
    # that predate the reference split (fan-out, anti-entropy)
    hot = "xla" if reference else backend
    r_spot, r_work, r_lead, r_elec = jax.random.split(rng, 4)
    state, killed = spot_step(state, static, cfg_c, r_spot)
    state, (n_w, n_r, r_key) = workload_step(state, static, cfg_c, r_work)
    state = election_step(state, static, cfg_c, r_elec)
    state = leader_step(state, static, cfg_c, r_lead, backend=hot)
    state = follower_step(state, static, cfg_c, reference=reference,
                          backend=backend)
    state = commit_step(state, static, cfg_c, reference=reference,
                        backend=backend)
    state = apply_step(state, static, cfg_c, reference=reference,
                       backend=backend)
    state = observer_sync_step(state, static, cfg_c)
    state = anti_entropy_step(state, static, cfg_c, backend=hot)
    state, (read_served, read_lat, obs_served, obs_stale) = \
        read_step(state, static, cfg_c)
    state = cost_step(state, static, cfg_c)
    state = dict(state, tick=state["tick"] + 1)

    lid = leader_id(state, static)
    metrics = {
        "has_leader": (lid >= 0).astype(jnp.int32),
        "leader_term": jnp.where(lid >= 0, state["term"][jnp.maximum(lid, 0)],
                                 -1),
        "n_leaders": jnp.sum((state["role"] == LEADER) & state["alive"]),
        "n_secretaries": jnp.sum((state["role"] == SECRETARY) &
                                 state["alive"]),
        "n_observers": jnp.sum((state["role"] == OBSERVER) & state["alive"]),
        "commit_len": jnp.max(state["commit_len"]),
        "write_queue": state["write_pending"],
        "read_queue": jnp.sum(state["read_queue"]),
        "killed": jnp.sum(killed),
        "cost": state["cost_accrued"],
        # raw per-node read service sample this tick (DESIGN.md §11):
        # the host-path reference for the read histogram pin test —
        # ignored by the in-scan digest reduction
        "read_served_tick": read_served,
        "read_lat_tick": read_lat,
        # digest-tier twins (DESIGN.md §13): per-slot serves and the
        # staleness of each served batch, for the numpy pin of
        # `obs_stale_hist` in `tests/test_observers.py`
        "obs_served_tick": obs_served,
        "obs_stale_tick": obs_stale,
        "n_obs_digest": jnp.sum(state["dobs_alive"]),
    }
    return state, metrics
