"""Wing & Gong linearizability checker for single-key KV histories.

An operation is `Op(kind, key, value, invoke_t, respond_t)`.  The checker
searches for a total order of operations that (1) respects real-time
precedence (op A precedes op B iff A.respond_t < B.invoke_t) and (2) is a
legal sequential KV history (each read returns the latest preceding write,
or the initial value).  Exponential in the worst case — meant for the
small histories the tests generate (<= ~15 concurrent ops).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Op:
    kind: str              # "w" | "r"
    key: int
    value: int
    invoke_t: float
    respond_t: float

    def __repr__(self):
        return (f"{self.kind}(k{self.key}={self.value})"
                f"@[{self.invoke_t},{self.respond_t}]")


def is_linearizable(history: Sequence[Op], initial: int = 0) -> bool:
    ops = list(history)
    n = len(ops)
    if n == 0:
        return True

    precedes = [[ops[a].respond_t < ops[b].invoke_t for b in range(n)]
                for a in range(n)]

    used = [False] * n
    order: List[int] = []

    def candidates():
        # minimal ops: not used, no unused predecessor
        out = []
        for i in range(n):
            if used[i]:
                continue
            if any(not used[j] and precedes[j][i] for j in range(n)):
                continue
            out.append(i)
        return out

    def legal(i: int, value_now: dict) -> bool:
        op = ops[i]
        if op.kind == "w":
            return True
        return value_now.get(op.key, initial) == op.value

    def search(value_now: dict) -> bool:
        if len(order) == n:
            return True
        for i in candidates():
            if not legal(i, value_now):
                continue
            op = ops[i]
            used[i] = True
            order.append(i)
            old = value_now.get(op.key, initial)
            if op.kind == "w":
                value_now[op.key] = op.value
            if search(value_now):
                return True
            if op.kind == "w":
                value_now[op.key] = old
            order.pop()
            used[i] = False
        return False

    return search({})


def history_from_sim_trace(write_log, probe_reads) -> List[Op]:
    """Build a checkable single-key history from sim artifacts.

    write_log: iterable of (key, value, submit_t, commit_t) for committed
    writes; probe_reads: iterable of (key, value, t) instantaneous reads.
    """
    ops: List[Op] = []
    for k, v, s, c in write_log:
        ops.append(Op("w", int(k), int(v), float(s), float(c)))
    for k, v, t in probe_reads:
        ops.append(Op("r", int(k), int(v), float(t), float(t)))
    return ops
