"""Global resource management — the paper's Algorithm 1 ("peek").

Faithful port of the pseudocode: every period T, from the collected
statistics (follower census F_i, secretary capacity f, write ratio zeta,
read growth A, budget vartheta, prices rho/beta), decide how many new
secretaries (dk_s) and observers (dk_o) to lease, prioritized by the write
ratio against varpi=30%.  Runs at epoch granularity on the host (control
plane), NumPy only.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cluster_config import ClusterConfig


@dataclasses.dataclass
class PeekStats:
    """Statistics collected over the last period T."""
    reads_prev: int
    reads_now: int
    writes_now: int
    followers_per_site: List[int]     # F_i
    k_s: int                          # current secretaries
    k_o: int                          # current observers
    budget: float                     # vartheta (remaining $ this period)
    spot_price: float                 # rho (mean across sites)
    on_demand_price: float            # beta


@dataclasses.dataclass
class PeekDecision:
    dk_s: int
    dk_o: int
    k: int                            # total new spot instances to lease
    k_s: int
    k_o: int
    budget_left: float


def algorithm1(cfg: ClusterConfig, st: PeekStats) -> PeekDecision:
    """The paper's Algorithm 1, line-for-line."""
    f = cfg.secretary_fanout
    varpi = cfg.write_ratio_threshold
    rho = st.spot_price
    theta = st.budget
    m = len(st.followers_per_site)

    # line 3: k_s' = sum_i (F_i + (f+1)/2) / f   (site needing >= (f+1)/2
    # followers rounds up to one secretary)
    k_s_needed = sum(int((F_i + (f + 1) // 2) // f)
                     for F_i in st.followers_per_site)
    dk_s = k_s_needed - st.k_s                                # line 4

    total = max(st.reads_now + st.writes_now, 1)
    zeta = st.writes_now / total
    dk_o = 0
    if zeta <= varpi:                                         # line 5: reads
        A = (st.reads_now - st.reads_prev) / max(st.reads_prev, 1)  # line 6
        if A > cfg.read_growth_deadband:                      # line 7
            dk_o = m                                          # line 8
            dk_o = min(dk_o, int(min(rho * dk_o, theta) / rho))  # line 9
        elif A < -cfg.read_growth_deadband:                   # line 10
            dk_o = max(-st.k_o, -m)                           # line 11
        theta = max(0.0, theta - rho * dk_o)                  # line 13
        dk_s = min(dk_s, int(theta / rho))                    # line 14
        theta = max(0.0, theta - rho * max(dk_s, 0))          # line 15
    else:                                                     # line 16: writes
        dk_s = min(dk_s, int(theta / rho))                    # line 17
        theta = max(0.0, theta - rho * max(dk_s, 0))          # line 18
        dk_o = min(m, int(theta / rho))                       # line 19
        theta = max(0.0, theta - rho * dk_o)                  # line 20
    dk_s = max(dk_s, -st.k_s)
    k_s = st.k_s + dk_s                                       # line 22
    k_o = st.k_o + dk_o                                       # line 23
    k = max(dk_s, 0) + max(dk_o, 0)                           # line 24
    return PeekDecision(dk_s=dk_s, dk_o=dk_o, k=k, k_s=k_s, k_o=k_o,
                        budget_left=theta)


def estimated_cost(cfg: ClusterConfig, k_s: int, k_o: int,
                   network_coef: float = 0.001) -> float:
    """Equation (1): cost = sum_i beta*F_i + beta + rho(k_s+k_o) + C."""
    beta = float(np.mean([s.on_demand_price for s in cfg.sites]))
    rho = float(np.mean([s.spot_price_mean for s in cfg.sites]))
    followers = sum(s.followers for s in cfg.sites)
    n = followers + 1 + k_s + k_o
    return beta * followers + beta + rho * (k_s + k_o) + network_coef * n


def spot_scores(cpu: np.ndarray, mem: np.ndarray, price: np.ndarray,
                revoke_prob: np.ndarray,
                l1: float = 1.0, l2: float = 1.0, l3: float = 1.0
                ) -> np.ndarray:
    """Equation (2): score = (l1*c + l2*phi + l3/price) / xi."""
    return (l1 * cpu + l2 * mem + l3 / np.maximum(price, 1e-6)) / \
        np.maximum(revoke_prob, 1e-3)


class RevocationPredictor:
    """EWMA per-site revocation-rate estimate (stands in for SpotTune).

    The default is a flat prior updated online from the epoch census;
    `calibrated` (or `market.calibrate.calibrate_predictor`, which also
    fits alpha) seeds the rates from a market trace's empirical per-site
    hazard instead (DESIGN.md §10)."""

    def __init__(self, n_sites: int, alpha: float = 0.3,
                 prior: float = 0.02):
        self.rate = np.full(n_sites, prior)
        self.alpha = alpha

    @classmethod
    def calibrated(cls, rates, alpha: float = 0.3) -> "RevocationPredictor":
        """Predictor seeded from per-site rates fitted offline against a
        trace, instead of the flat prior."""
        rates = np.atleast_1d(np.asarray(rates, float))
        p = cls(len(rates), alpha=alpha)
        p.rate = rates.copy()
        return p

    def update(self, revoked: np.ndarray, leased: np.ndarray) -> None:
        obs = revoked / np.maximum(leased, 1)
        mask = leased > 0
        self.rate[mask] = (1 - self.alpha) * self.rate[mask] + \
            self.alpha * obs[mask]

    def predict(self) -> np.ndarray:
        return self.rate.copy()
