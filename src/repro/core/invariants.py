"""Safety-property checkers (paper Properties 3.1–3.4) over sim traces.

These run on host-side numpy snapshots of cluster state (taken every tick
or every few ticks) and raise AssertionError with a diagnostic when a
property is violated.  Used by the hypothesis property tests.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.state import LEADER


def snapshot(state) -> Dict[str, np.ndarray]:
    keep = ("role", "term", "alive", "log_term", "log_key", "log_val",
            "log_len", "commit_len", "applied_len")
    return {k: np.asarray(state[k]) for k in keep}


def check_election_safety(trace: Sequence[Dict[str, np.ndarray]]) -> None:
    """Property 3.1: at most one leader per term, ever."""
    leader_of_term: Dict[int, int] = {}
    for t, snap in enumerate(trace):
        leaders = np.where((snap["role"] == LEADER) & snap["alive"])[0]
        terms = snap["term"][leaders]
        # no two simultaneous leaders with the same term
        assert len(set(terms)) == len(terms), \
            f"tick {t}: two leaders share a term: {list(zip(leaders, terms))}"
        for lid, term in zip(leaders, terms):
            prev = leader_of_term.get(int(term))
            assert prev is None or prev == int(lid), \
                f"tick {t}: term {term} had leader {prev}, now {lid}"
            leader_of_term[int(term)] = int(lid)


def check_log_matching(snap: Dict[str, np.ndarray]) -> None:
    """Property 3.3: if two logs share (index, term), they are identical
    up to that index."""
    n = snap["log_term"].shape[0]
    lens = snap["log_len"]
    for i in range(n):
        for j in range(i + 1, n):
            m = int(min(lens[i], lens[j]))
            if m == 0:
                continue
            ti = snap["log_term"][i, :m]
            tj = snap["log_term"][j, :m]
            same = ti == tj
            # find the last shared (index,term); everything before must match
            shared = np.where(same)[0]
            if shared.size == 0:
                continue
            last = shared[-1]
            if not same[:last + 1].all():
                continue  # diverged-then-reconverged impossible; skip holes
            assert (snap["log_key"][i, :last + 1] ==
                    snap["log_key"][j, :last + 1]).all() and \
                   (snap["log_val"][i, :last + 1] ==
                    snap["log_val"][j, :last + 1]).all(), \
                f"log matching violated between nodes {i},{j} " \
                f"at <= {last}"


def check_state_machine_safety(snap: Dict[str, np.ndarray]) -> None:
    """Property 3.2: every replica applies the same commands in the same
    order — applied prefixes agree (keys and values)."""
    n = snap["log_term"].shape[0]
    ap = snap["applied_len"]
    for i in range(n):
        for j in range(i + 1, n):
            m = int(min(ap[i], ap[j]))
            if m == 0:
                continue
            assert (snap["log_key"][i, :m] == snap["log_key"][j, :m]).all() \
                and (snap["log_val"][i, :m] ==
                     snap["log_val"][j, :m]).all() \
                and (snap["log_term"][i, :m] ==
                     snap["log_term"][j, :m]).all(), \
                f"state machine safety violated between {i},{j} upto {m}"


def check_commit_durability(trace: Sequence[Dict[str, np.ndarray]]) -> None:
    """Once committed at length c with content X, no later snapshot may show
    different content below c (within one log window/epoch)."""
    best: Dict[int, tuple] = {}
    for t, snap in enumerate(trace):
        c = int(snap["commit_len"].max())
        if c == 0:
            continue
        lid = int(np.argmax(snap["commit_len"]))
        key = snap["log_key"][lid, :c].copy()
        val = snap["log_val"][lid, :c].copy()
        for idx in range(c):
            k = (int(key[idx]), int(val[idx]))
            if idx in best:
                assert best[idx] == k, \
                    f"tick {t}: committed entry {idx} changed " \
                    f"{best[idx]} -> {k}"
            else:
                best[idx] = k


def check_all(trace: Sequence[Dict[str, np.ndarray]]) -> None:
    check_election_safety(trace)
    for snap in trace[:: max(len(trace) // 8, 1)]:
        check_log_matching(snap)
        check_state_machine_safety(snap)
    check_commit_durability(trace)
