"""Cluster/workload configuration for the BW-Raft consensus layer."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SiteConfig:
    """One geo-site (paper: EU-Frankfurt / Asia-Singapore / US-East/West)."""
    name: str
    followers: int                 # on-demand voter nodes at this site
    rtt_intra: int                 # ticks for intra-site message delivery
    rtt_inter: int                 # ticks to other sites
    on_demand_price: float         # $/node/period (beta)
    spot_price_mean: float         # $/node/period mean (rho)
    spot_price_vol: float = 0.35   # relative volatility of the price process
    spot_revoke_rate: float = 0.02  # baseline revocation prob / period (xi)


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    name: str
    sites: Tuple[SiteConfig, ...]
    secretary_fanout: int = 4          # f
    write_ratio_threshold: float = 0.30   # varpi
    read_growth_deadband: float = 0.10    # |A| deadband
    period_ticks: int = 100               # T
    budget_per_period: float = 2.0        # vartheta
    max_log: int = 4096                   # log capacity (entries)
    key_space: int = 1024                 # KV state-machine key space
    max_secretaries: int = 16
    max_observers: int = 64
    # timeouts must dominate WAN RTT (max ~10 ticks) + heartbeat interval
    election_timeout_min: int = 30        # ticks
    election_timeout_max: int = 60
    heartbeat_interval: int = 3

    @property
    def num_sites(self) -> int:
        return len(self.sites)

    @property
    def num_followers(self) -> int:
        return sum(s.followers for s in self.sites)

    @property
    def num_voters(self) -> int:
        return self.num_followers                 # leader is one of them

    @property
    def max_nodes(self) -> int:
        return self.num_followers + self.max_secretaries + self.max_observers
