"""BW-Raft runtime: jitted tick-scan epochs + host-side control plane.

One *epoch* = `cfg.period_ticks` protocol ticks (jitted `lax.scan`), after
which the control plane runs: collect stats ("peek", Algorithm 1), score
the spot-offer pool and select instances (MCSA, "peak"), lease them into
dead spot slots, wire secretaries/observers, compact the log window.
`mode="raft"` disables spot roles entirely (the Original baseline).

Compilation contract (DESIGN.md §7): the epoch function is compiled **once
per static shape** — the cache key is (cluster config, padding), and every
workload knob in `cfg_c` (rates, phi, prices, volatility, timeouts, the
(S, Tt) market-trace arrays of DESIGN.md §10) is a jit *argument*, so
rate/volatility/kill-rate/trace sweeps over one topology reuse the
compiled program.  For sweeps over many clusters in a single compiled
program, use `core/fleet.py`, which vmaps the same tick over a leading
batch axis; the host-side control plane below (`ClusterController`,
`lease_and_wire`, `build_report`, `compact_state`) is shared by both.

Epoch digest contract (DESIGN.md §7.1): the jitted epoch reduces its
per-tick metrics *inside* the scan and returns `(compacted_state, digest)`
where the digest is a few-KB pytree — counters, a write-latency histogram,
the final (N,) role/alive vectors and (S,) spot prices — independent of
the log window L and key space K.  Only the digest crosses the device→host
boundary per epoch (`report_from_digest`); the state pytree stays on
device, is compacted in-graph, and its input buffers are donated back to
XLA (`donate_argnums`), so epochs neither copy state in device memory nor
materialize it to host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import manager as mgr
from repro.kernels import resolve_backend
from repro.core import mcsa
from repro.core import step as step_mod
from repro.core import state as state_mod
from repro.core.cluster_config import ClusterConfig
from repro.core.state import (DEAD, FOLLOWER, LEADER, OBSERVER, SECRETARY,
                              HIST_TAIL)
from repro.trace import export as trace_export
from repro.trace import metrics as trace_metrics
from repro.trace import ring as trace_ring
from repro.workload import arrivals as workload_arrivals


class CountingJit:
    """`jax.jit` wrapper whose compile count survives jax upgrades.

    Prefers the private `Wrapped._cache_size()` when the installed jax
    still has it; otherwise falls back to counting distinct argument
    signatures (treedef + leaf shapes/dtypes — the jit cache key modulo
    weak types) observed at call time on this wrapper.  Used by every
    cached epoch function so `FleetSim.compile_count` /
    `fleet.total_compile_count` keep working across versions.
    """

    def __init__(self, fun, **jit_kwargs):
        self.fn = jax.jit(fun, **jit_kwargs)
        self._sigs = set()

    def __call__(self, *args):
        leaves, treedef = jax.tree.flatten(args)
        self._sigs.add((treedef,
                        tuple((jnp.shape(x), jnp.result_type(x))
                              for x in leaves)))
        return self.fn(*args)

    def cache_size(self) -> int:
        try:
            return int(self.fn._cache_size())
        except Exception:
            return len(self._sigs)


# HIST_TAIL moved to `state.py` with the read-histogram state (§11); it
# is re-exported here so `runtime.HIST_TAIL` keeps resolving — both the
# write and read latency histograms share the T + 1 + HIST_TAIL layout
# (`state.hist_bins`, DESIGN.md §7.1/§11).


def make_cfg_arrays(cfg: ClusterConfig, *, write_rate: float,
                    read_rate: float, phi: float = 0.0,
                    pad_nodes: int = 0,
                    pad_sites: int = 0, pad_keys: int = 0,
                    spot_price_vol: Optional[float] = None,
                    cross_shard_frac: float = 0.0,
                    two_pc_ticks: int = 0,
                    market: str = "process",
                    trace=None, trace_ticks: Optional[int] = None,
                    arrivals=None, arrival_ticks: Optional[int] = None,
                    keypop=None,
                    warning_ticks: int = 0, spot_bid=None,
                    bid_on_trace: bool = False,
                    faults=None, fault_ticks: Optional[int] = None,
                    n_observers: int = 0, pad_observers: int = 0,
                    staleness_bound: int = 16, ae_interval: int = 4,
                    ae_phase=None, trace_on: bool = False,
                    trace_mask=None) -> Dict:
    """Per-epoch dynamic knobs — all jit arguments, never baked into the
    compiled program.  `pad_sites` repeats the last site's prices so padded
    clusters share one (S,) shape (DESIGN.md §7).  `cross_shard_frac` /
    `two_pc_ticks` are the Multi-Raft 2PC coupling knobs (DESIGN.md §9):
    zero for ungrouped members, which keeps the tick bit-identical to the
    pre-group program.

    `market` selects the spot-market source (DESIGN.md §10):
    `"process"` runs the synthetic walk, `"trace"` replays the given
    `market.MarketTrace` — its (S, Tt) price/revocation arrays enter
    here as jit arguments (`price_trace` / `revoke_trace`, fitted to the
    padded site count), so swapping traces at one shape never recompiles.
    `trace_ticks` widens the trace arrays to a fleet-shared Tt (time
    wrap, `MarketTrace.fit_to`); process-only members carry an inert
    (S, max(trace_ticks, 1)) placeholder so mixed fleets still stack.

    `arrivals` selects the workload source (DESIGN.md §11): None keeps
    the closed-loop scalar knob (bit-identical to the pre-§11 tick); a
    `workload.OpenLoop` plan enters as the `write_curve`/`read_curve`
    jit-argument arrays, wrapped at the plan's own length, optionally
    widened to a fleet-shared `arrival_ticks` (replay-neutral, like
    market traces).  `keypop` is the write-key popularity: None keeps
    the uniform draw, a `workload.ZipfianKeys` rides in as the (K,)
    `key_cdf` the leader inverse-transform samples; `pad_keys` widens
    the CDF with a saturated (never-sampled) tail so padded fleets
    stack.

    Revocation-robustness knobs (DESIGN.md §12), all cfg_c data:
    `warning_ticks` is the advance-warning window W (0 = today's
    immediate kill, bit-identical); `spot_bid` overrides the per-site
    bid (default: `state.site_price_init`'s 1.5x-mean rule) — carried
    here instead of in state so per-epoch bid-policy updates never
    recompile; `bid_on_trace` re-derives trace-path revocations from
    the replayed prices vs the CURRENT bid (default False = verbatim
    replay of the trace's revocation columns); a trace with per-node
    `revoked_node` columns enters as `revoke_node_trace` (node rows
    round-robin, time wrap shared with the site arrays); `faults` is a
    deterministic `market.chaos.FaultSchedule` riding in as the (N, Tf)
    `fault_trace` jit-argument array (widened to a fleet-shared
    `fault_ticks` with inert False padding; the in-step lookup wraps at
    the array width, so build schedules covering the full run for
    one-shot semantics).

    Digest-tier observer knobs (DESIGN.md §13), all cfg_c data so
    staleness/cadence sweeps at one O never recompile:
    `staleness_bound` is the read-freshness contract in ticks (a digest
    observer serves iff `tick - last_sync <= bound`); `ae_interval` is
    the anti-entropy round period; `ae_phase` is the per-observer `(O,)`
    phase schedule (default `arange(O)` — maximally staggered cohorts;
    `O = n_observers + pad_observers` must match the shapes from
    `state.build_static`).  The bound must fit the unit-bin staleness
    histogram (`period_ticks + HIST_TAIL`).

    Flight-recorder knobs (DESIGN.md §14), both cfg_c data so toggling
    capture or remasking event classes never recompiles: `trace_on`
    gates ring capture (the metrics registry stays on either way);
    `trace_mask` is the per-event-class capture mask (default: all
    `trace.NCLASS` classes on — see `trace.ring.default_mask`)."""
    assert 0.0 <= cross_shard_frac <= 1.0, cross_shard_frac
    assert 0 <= two_pc_ticks <= HIST_TAIL, \
        f"two_pc_ticks={two_pc_ticks} exceeds the histogram tail " \
        f"(HIST_TAIL={HIST_TAIL}) — widen runtime.HIST_TAIL"
    assert market in ("process", "trace"), market
    assert market == "process" or trace is not None, \
        "market='trace' needs a market.MarketTrace (see market.load / " \
        "market/synthetic.py providers)"
    S = cfg.num_sites + pad_sites
    N = cfg.max_nodes + pad_nodes
    per_node = (trace is not None
                and getattr(trace, "revoked_node", None) is not None)
    if trace is not None:
        width = trace_ticks or trace.ticks
        fitted = trace.fit_to(S, width)
        price_trace = jnp.asarray(fitted.price, jnp.float32)
        revoke_trace = jnp.asarray(fitted.revoked, bool)
        # the member's OWN period: the in-step lookup wraps at this (a
        # jit argument), not at the fleet-shared array width, so a short
        # trace widened next to a longer one still replays its own
        # columns exactly (DESIGN.md §10 replay-neutral widening)
        trace_len = min(trace.ticks, width)
    else:
        price_trace = jnp.zeros((S, trace_ticks or 1), jnp.float32)
        revoke_trace = jnp.zeros((S, trace_ticks or 1), bool)
        trace_len = 1
    if per_node:
        revoke_node = jnp.asarray(
            trace.node_columns(N, int(price_trace.shape[1])), bool)
    else:
        revoke_node = jnp.zeros((N, int(price_trace.shape[1])), bool)
    if faults is not None:
        fault_len = fault_ticks or faults.ticks
        fault_trace = jnp.asarray(faults.fit_to(N, fault_len), bool)
    else:
        fault_len = 1
        fault_trace = jnp.zeros((N, fault_ticks or 1), bool)
    if spot_bid is None:
        bid = state_mod.site_price_init(cfg, S)[1]
    else:
        bid = np.asarray(spot_bid, np.float32).reshape(-1)
        if bid.size == 1:
            bid = np.full((S,), bid[0], np.float32)
        elif bid.size < S:           # padded sites repeat the last bid
            bid = np.concatenate(
                [bid, np.full((S - bid.size,), bid[-1], np.float32)])
        bid = bid[:S]
    if arrivals is not None:
        width = arrival_ticks or arrivals.ticks
        write_curve, read_curve, arrival_len = arrivals.fit_to(width)
    else:
        width = arrival_ticks or 1
        write_curve = np.zeros((width,), np.float32)
        read_curve = np.zeros((width,), np.float32)
        arrival_len = 1
    if keypop is not None:
        key_cdf = keypop.materialize(cfg.key_space, pad_keys)
    else:
        key_cdf = workload_arrivals.uniform_key_cdf(cfg.key_space, pad_keys)
    O = n_observers + pad_observers
    assert 0 <= staleness_bound <= cfg.period_ticks + HIST_TAIL, \
        f"staleness_bound={staleness_bound} exceeds the unit-bin " \
        f"staleness histogram ({cfg.period_ticks + HIST_TAIL})"
    assert ae_interval >= 1, ae_interval
    if ae_phase is None:
        phase = np.arange(O, dtype=np.int32)
    else:
        phase = np.asarray(ae_phase, np.int32).reshape(-1)
        assert phase.size == O, (phase.size, O)
    if trace_mask is None:
        mask = np.ones((trace_ring.NCLASS,), bool)
    else:
        mask = np.asarray(trace_mask, bool).reshape(-1)
        assert mask.size == trace_ring.NCLASS, \
            (mask.size, trace_ring.NCLASS)
    od = [s.on_demand_price for s in cfg.sites]
    sp = [s.spot_price_mean for s in cfg.sites]
    od = od + [od[-1]] * pad_sites
    sp = sp + [sp[-1]] * pad_sites
    vol = (cfg.sites[0].spot_price_vol if spot_price_vol is None
           else spot_price_vol)
    return {
        "open_loop": jnp.asarray(arrivals is not None),
        "write_curve": jnp.asarray(write_curve, jnp.float32),
        "read_curve": jnp.asarray(read_curve, jnp.float32),
        "arrival_len": jnp.int32(arrival_len),
        "key_zipf": jnp.asarray(keypop is not None),
        "key_cdf": jnp.asarray(key_cdf, jnp.float32),
        "market_trace": jnp.asarray(market == "trace"),
        "price_trace": price_trace,
        "revoke_trace": revoke_trace,
        "trace_len": jnp.int32(trace_len),
        # revocation-robustness data (DESIGN.md §12)
        "spot_bid": jnp.asarray(bid, jnp.float32),
        "warn_ticks": jnp.int32(warning_ticks),
        "bid_on_trace": jnp.asarray(bool(bid_on_trace)),
        "node_trace": jnp.asarray(per_node),
        "revoke_node_trace": revoke_node,
        "fault_on": jnp.asarray(faults is not None),
        "fault_trace": fault_trace,
        "fault_len": jnp.int32(fault_len),
        "write_rate": jnp.float32(write_rate),
        "read_rate": jnp.float32(read_rate),
        "phi": jnp.float32(phi),
        "heartbeat_interval": jnp.int32(cfg.heartbeat_interval),
        "election_timeout_min": jnp.int32(cfg.election_timeout_min),
        "election_timeout_max": jnp.int32(cfg.election_timeout_max),
        "on_demand_price": jnp.asarray(od, jnp.float32),
        "spot_price_mean": jnp.asarray(sp, jnp.float32),
        "spot_price_vol": jnp.float32(vol),
        "ticks_per_hour": jnp.float32(3600.0 / 0.01 / 100),  # 1 tick = 10ms
        "network_cost_coef": jnp.float32(0.0005),
        "cross_frac": jnp.float32(cross_shard_frac),
        "two_pc_ticks": jnp.int32(two_pc_ticks),
        # digest-tier observer contract (DESIGN.md §13)
        "staleness_bound": jnp.int32(staleness_bound),
        "ae_interval": jnp.int32(ae_interval),
        "ae_phase": jnp.asarray(phase, jnp.int32),
        # flight-recorder gate + per-class capture mask (DESIGN.md §14)
        "trace_on": jnp.asarray(bool(trace_on)),
        "trace_mask": jnp.asarray(mask),
    }


@dataclasses.dataclass
class EpochReport:
    epoch: int
    reads_arrived: int
    writes_arrived: int
    reads_served: int
    writes_committed: int
    read_lat_mean: float
    read_lat_max: float
    write_lat_mean: float
    write_lat_p95: float
    write_lat_p99: float
    cost: float
    n_secretaries: int
    n_observers: int
    leader_changes: int
    no_leader_ticks: int
    killed: int
    # read-path tail stats, recovered exactly from the per-request
    # read-latency histogram (DESIGN.md §11) — NaN when no read served
    read_lat_p95: float = float("nan")
    read_lat_p99: float = float("nan")
    # end-of-epoch warning census: nodes alive with a raised advance-
    # warning bit (DESIGN.md §12) — 0 whenever warning_ticks == 0
    n_warned: int = 0
    # digest-tier observer census (DESIGN.md §13) — all zero/NaN when
    # the tier is off (O == 0)
    obs_reads_served: int = 0
    obs_rerouted: int = 0
    obs_stale_p95: float = float("nan")
    obs_stale_p99: float = float("nan")
    n_obs_digest: int = 0
    # unified control-plane metrics registry (DESIGN.md §14): the named
    # counters of `trace.metrics`, reduced in-digest — new per-epoch
    # counters land here instead of growing this dataclass field by
    # field.  None only on reports predating the registry.
    metrics: Optional[Dict[str, int]] = None
    decision: Optional[mgr.PeekDecision] = None

    @property
    def goodput(self) -> float:
        return (self.reads_served + self.writes_committed) / 1.0


def build_report(epoch: int, st: Dict, ms: Dict,
                 cost_before: float,
                 leader_term0: Optional[int] = None) -> EpochReport:
    """Distill one cluster's post-epoch state + per-tick metrics (numpy,
    leaves shaped (T,)) into an EpochReport.

    This is the host-marshalling reference path: it needs the FULL state
    pytree (O(N·(L+K)) device→host bytes per cluster).  The hot path is
    `report_from_digest`, which consumes only the few-KB on-device digest
    (DESIGN.md §7.1); this function is kept for the `pipeline="host"`
    A/B fallback and the digest-equivalence tests.

    `leader_term0` is the PRE-epoch leader term (-1 = no leader): the
    `np.diff(leader_term)` change count is taken over the prepended
    series so a change landing on the epoch's first tick is counted,
    matching the fixed in-scan accumulator (`_digest_acc_init`).  None
    preserves the legacy within-epoch-only diff."""
    lt = np.asarray(ms["leader_term"])
    if leader_term0 is not None:
        lt = np.concatenate([[np.int64(leader_term0)],
                             lt.astype(np.int64)])
    sub_t = np.asarray(st["entry_submit_t"])
    com_t = np.asarray(st["entry_commit_t"])
    done = (sub_t >= 0) & (com_t >= 0)
    lat = (com_t[done] - sub_t[done]).astype(float)
    reads_served = int(st["reads_served"])
    _, _, read_p95, read_p99 = hist_stats(st["read_lat_hist"])
    _, _, stale_p95, stale_p99 = hist_stats(st["obs_stale_hist"])
    return EpochReport(
        read_lat_p95=read_p95,
        read_lat_p99=read_p99,
        n_warned=int((np.asarray(st["alive"]) &
                      (np.asarray(st["warn_timer"]) >= 0)).sum()),
        obs_reads_served=int(st["obs_reads_served"]),
        obs_rerouted=int(st["obs_rerouted"]),
        obs_stale_p95=stale_p95,
        obs_stale_p99=stale_p99,
        n_obs_digest=int(np.asarray(st["dobs_alive"]).sum()),
        epoch=epoch,
        reads_arrived=int(st["reads_arrived"]),
        writes_arrived=int(st["writes_arrived"]),
        reads_served=reads_served,
        writes_committed=int(done.sum()),
        read_lat_mean=float(st["read_lat_sum"] / max(reads_served, 1)),
        read_lat_max=float(st["read_lat_max"]),
        write_lat_mean=float(lat.mean()) if lat.size else float("nan"),
        write_lat_p95=float(np.percentile(lat, 95)) if lat.size
        else float("nan"),
        write_lat_p99=float(np.percentile(lat, 99)) if lat.size
        else float("nan"),
        cost=float(st["cost_accrued"]) - cost_before,
        n_secretaries=int(ms["n_secretaries"][-1]),
        n_observers=int(ms["n_observers"][-1]),
        leader_changes=int((np.diff(lt) > 0).sum()),
        no_leader_ticks=int((ms["has_leader"] == 0).sum()),
        killed=int(ms["killed"].sum()),
        metrics=(trace_metrics.as_dict(st["metrics_ctr"])
                 if "metrics_ctr" in st else None),
    )


def _digest_acc_init(leader_term0) -> Dict:
    """In-scan accumulators for the per-tick metric reductions, seeded
    with the PRE-epoch leader term (same `-1 = no leader` sentinel as
    the tick metric).  Seeding — instead of skipping the first tick —
    is the fix for the boundary blindness pinned by
    `tests/test_trace.py::test_leader_changes_first_tick_regression`: a
    leader change landing on the first tick after compaction used to be
    invisible to both this counter and the host `np.diff` form."""
    return {
        "killed": jnp.int32(0),
        "no_leader_ticks": jnp.int32(0),
        "leader_changes": jnp.int32(0),
        "prev_leader_term": jnp.asarray(leader_term0, jnp.int32),
    }


def _digest_acc_update(acc: Dict, m: Dict) -> Dict:
    """Fold one tick's metrics into the accumulators (replaces the
    T-stacked metric arrays of the host path: `leader_changes` is the
    in-scan equivalent of `(np.diff(leader_term) > 0).sum()` over the
    epoch-start-prepended term series)."""
    changed = m["leader_term"] > acc["prev_leader_term"]
    return {
        "killed": acc["killed"] + m["killed"].astype(jnp.int32),
        "no_leader_ticks": acc["no_leader_ticks"] +
        (m["has_leader"] == 0).astype(jnp.int32),
        "leader_changes": acc["leader_changes"] +
        changed.astype(jnp.int32),
        "prev_leader_term": m["leader_term"],
    }


def _finalize_digest(state: Dict, acc: Dict, cost_before, T: int,
                     cfg_c: Dict) -> Dict:
    """Build the epoch digest from the final (pre-compaction) state.

    The write-latency distribution becomes an exact per-tick histogram:
    latencies are integer ticks in [0, T + HIST_TAIL] (the tail holds the
    in-graph 2PC rounds of cross-shard commits, DESIGN.md §9), so
    `hist[b]` = number of committed entries with latency b fully
    determines the sorted latency sample — `report_from_digest` recovers
    mean/p95/p99 exactly.  The 2PC prepare/abort census counts entries
    marked as cross-shard coordinators: prepares = marked entries that
    reached the log, aborts = prepares whose commit never landed inside
    the epoch (the partner shard's held capacity is released uncommitted).
    """
    sub, com = state["entry_submit_t"], state["entry_commit_t"]
    done = (sub >= 0) & (com >= 0)
    H = T + 1 + HIST_TAIL
    lat = jnp.clip(com - sub, 0, H - 1)
    hist = jnp.zeros((H,), jnp.int32).at[
        jnp.where(done, lat, H)].add(1, mode="drop")
    marked = step_mod.cross_shard_mark(
        jnp.arange(sub.shape[0]), cfg_c["cross_frac"])
    prepared = marked & (sub >= 0)
    alive = state["alive"]
    return {
        "cross_arrived": state["cross_arrived"],
        "two_pc_prepares": jnp.sum(prepared).astype(jnp.int32),
        "two_pc_aborts": jnp.sum(prepared & (com < 0)).astype(jnp.int32),
        "reads_arrived": state["reads_arrived"],
        "writes_arrived": state["writes_arrived"],
        "reads_served": state["reads_served"],
        "read_lat_sum": state["read_lat_sum"],
        "read_lat_max": state["read_lat_max"],
        # per-request read latencies, accumulated tick by tick on device
        # (`step.read_step`) — same unit-bin layout as the write
        # histogram below (DESIGN.md §11)
        "read_lat_hist": state["read_lat_hist"],
        "write_lat_hist": hist,
        "cost_delta": state["cost_accrued"] - cost_before,
        "n_secretaries": jnp.sum((state["role"] == SECRETARY) &
                                 alive).astype(jnp.int32),
        "n_observers": jnp.sum((state["role"] == OBSERVER) &
                               alive).astype(jnp.int32),
        "killed": acc["killed"],
        "no_leader_ticks": acc["no_leader_ticks"],
        "leader_changes": acc["leader_changes"],
        # control-plane inputs: O(N) role/alive for lease_and_wire, O(S)
        # prices for Algorithm 1 — the only per-node data leaving device
        "role": state["role"],
        "alive": alive,
        "spot_price": state["spot_price"],
        # advance-warning census (DESIGN.md §12): which nodes carry a
        # raised warning bit at epoch end, so the control plane can
        # re-lease replacements BEFORE the kill lands
        "warned": alive & (state["warn_timer"] >= 0),
        "n_warned": jnp.sum(alive &
                            (state["warn_timer"] >= 0)).astype(jnp.int32),
        # digest-tier observer census (DESIGN.md §13): the staleness
        # histogram + three scalars — present (zeros) at O == 0 so the
        # digest pytree structure is uniform across fleet members.  The
        # (O,) leaves themselves never cross the boundary.
        "obs_stale_hist": state["obs_stale_hist"],
        "obs_reads_served": state["obs_reads_served"],
        "obs_rerouted": state["obs_rerouted"],
        "n_obs_digest": jnp.sum(state["dobs_alive"]).astype(jnp.int32),
        # flight-recorder registry + ring cursors (DESIGN.md §14): the
        # named counters become `EpochReport.metrics`; pos/emit ride
        # along so scan-mode runs keep per-epoch drop accounting even
        # though the ring itself is only fetched at drain time
        "trace_metrics": state["metrics_ctr"],
        "trace_pos": state["trace_pos"],
        "trace_emit": state["trace_emit"],
    }


def device_epoch(state: Dict, static, cfg_c: Dict, rng, T: int, *,
                 backend: str = "xla") -> Tuple[Dict, Dict]:
    """One fully device-resident epoch: T-tick scan with in-scan metric
    reduction, digest extraction, then in-graph log compaction.  Returns
    `(compacted_state, digest)`; meant to be jitted with the state buffers
    donated (DESIGN.md §7.1).  `backend` picks the tick hot-op
    implementation — `"xla"`, `"pallas"`, or `"auto"` (pallas on TPU,
    xla elsewhere — DESIGN.md §8).  The spot
    market (synthetic process or trace replay) is selected by `cfg_c` —
    the trace arrays are jit arguments, so a trace sweep reuses this
    compiled program (DESIGN.md §10)."""
    cost_before = state["cost_accrued"]
    # pre-epoch leader term, mirroring the tick metric's sentinel — the
    # seed that makes a first-tick leader change countable (see
    # `_digest_acc_init`)
    lid0 = state_mod.leader_id(state, static)
    lt0 = jnp.where(lid0 >= 0, state["term"][jnp.maximum(lid0, 0)], -1)

    def body(carry, r):
        st, acc = carry
        st, m = step_mod.tick(st, static, cfg_c, r, backend=backend)
        return (st, _digest_acc_update(acc, m)), None

    rngs = jax.random.split(rng, T)
    (state, acc), _ = jax.lax.scan(body, (state, _digest_acc_init(lt0)),
                                   rngs)
    digest = _finalize_digest(state, acc, cost_before, T, cfg_c)
    return compact_state(state), digest


def hist_percentile(counts: np.ndarray, q: float) -> float:
    """Exact `np.percentile(sample, q)` (linear interpolation) for an
    integer-valued sample given as a unit-width histogram: `counts[v]` =
    multiplicity of value v.  NaN on an empty histogram."""
    counts = np.asarray(counts)
    n = int(counts.sum())
    if n == 0:
        return float("nan")
    cum = np.cumsum(counts)
    rank = (n - 1) * q / 100.0
    lo, hi = int(np.floor(rank)), int(np.ceil(rank))
    vlo = int(np.searchsorted(cum, lo + 1))
    vhi = vlo if hi == lo else int(np.searchsorted(cum, hi + 1))
    return float(vlo + (rank - lo) * (vhi - vlo))


def hist_stats(hist) -> Tuple[int, float, float, float]:
    """(count, mean, p95, p99) of the integer sample encoded by a
    unit-bin histogram — the one place the digest's histogram layout
    (`_finalize_digest`, T + 1 + HIST_TAIL bins) is distilled; shared by
    `report_from_digest` and `multiraft.report_from_group_digest`.
    Mean/percentiles are NaN on an empty histogram."""
    hist = np.asarray(hist)
    n = int(hist.sum())
    lat_sum = float(hist @ np.arange(hist.shape[0], dtype=np.int64))
    mean = lat_sum / n if n else float("nan")
    return n, mean, hist_percentile(hist, 95), hist_percentile(hist, 99)


def goodput_under_deadline(hist, deadline: int) -> int:
    """Requests that finished within `deadline` ticks, read straight off a
    unit-bin latency histogram: ``sum(hist[:deadline+1])``.  The SLO-
    goodput metric of `benchmarks/perf_serving.py` (DESIGN.md §11);
    `tests/test_serving.py` pins it against a numpy recomputation over
    the raw per-request latencies."""
    hist = np.asarray(hist)
    d = min(int(deadline), hist.shape[0] - 1)
    if d < 0:
        return 0
    return int(hist[:d + 1].sum())


def report_from_digest(epoch: int, dg: Dict) -> EpochReport:
    """Distill one cluster's epoch digest (numpy leaves, O(T + N + S)
    bytes) into an EpochReport — the digest-path twin of `build_report`.
    Counters are exact; write-latency stats are recovered exactly from the
    unit-bin histogram (integer-tick latencies, see `_finalize_digest`)."""
    n_done, lat_mean, lat_p95, lat_p99 = hist_stats(dg["write_lat_hist"])
    reads_served = int(dg["reads_served"])
    _, _, read_p95, read_p99 = hist_stats(dg["read_lat_hist"])
    _, _, stale_p95, stale_p99 = hist_stats(dg["obs_stale_hist"])
    return EpochReport(
        read_lat_p95=read_p95,
        read_lat_p99=read_p99,
        n_warned=int(dg["n_warned"]),
        obs_reads_served=int(dg["obs_reads_served"]),
        obs_rerouted=int(dg["obs_rerouted"]),
        obs_stale_p95=stale_p95,
        obs_stale_p99=stale_p99,
        n_obs_digest=int(dg["n_obs_digest"]),
        epoch=epoch,
        reads_arrived=int(dg["reads_arrived"]),
        writes_arrived=int(dg["writes_arrived"]),
        reads_served=reads_served,
        writes_committed=n_done,
        read_lat_mean=float(dg["read_lat_sum"] / max(reads_served, 1)),
        read_lat_max=float(dg["read_lat_max"]),
        write_lat_mean=lat_mean,
        write_lat_p95=lat_p95,
        write_lat_p99=lat_p99,
        cost=float(dg["cost_delta"]),
        n_secretaries=int(dg["n_secretaries"]),
        n_observers=int(dg["n_observers"]),
        leader_changes=int(dg["leader_changes"]),
        no_leader_ticks=int(dg["no_leader_ticks"]),
        killed=int(dg["killed"]),
        metrics=(trace_metrics.as_dict(dg["trace_metrics"])
                 if "trace_metrics" in dg else None),
    )


def compact_state(state: Dict) -> Dict:
    """Epoch-boundary log compaction (state machines keep the data).

    Shape-generic — written with zeros_like/full_like only, so it works on
    a single cluster ((N, L) leaves) and on a batched fleet ((B, N, L)).

    Digest tier (DESIGN.md §13): the log window the digests fingerprint
    resets here, so `dobs_applied`/`dobs_digest` reset with it; and the
    epoch boundary is the in-graph re-lease point for the tier — digest
    observers are stateless and cheap, so every enabled slot comes back
    alive (`dobs_alive = dobs_enabled`) with its warning cleared, the
    sparse twin of the host-side `lease_and_wire`.  The last sync tick is
    kept: a revived slot stays stale (reroutes reads) until its first
    anti-entropy round lands."""
    return dict(
        state,
        dobs_applied=jnp.zeros_like(state["dobs_applied"]),
        dobs_term=jnp.zeros_like(state["dobs_term"]),
        dobs_digest=jnp.zeros_like(state["dobs_digest"]),
        dobs_alive=state["dobs_enabled"],
        dobs_warn=jnp.full_like(state["dobs_warn"], -1),
        obs_reads_served=jnp.zeros_like(state["obs_reads_served"]),
        obs_rerouted=jnp.zeros_like(state["obs_rerouted"]),
        obs_stale_hist=jnp.zeros_like(state["obs_stale_hist"]),
        log_term=jnp.zeros_like(state["log_term"]),
        log_key=jnp.zeros_like(state["log_key"]),
        log_val=jnp.zeros_like(state["log_val"]),
        log_len=jnp.zeros_like(state["log_len"]),
        commit_len=jnp.zeros_like(state["commit_len"]),
        applied_len=jnp.zeros_like(state["applied_len"]),
        applied_digest=jnp.zeros_like(state["applied_digest"]),
        match_len=jnp.zeros_like(state["match_len"]),
        app_arrive_t=jnp.full_like(state["app_arrive_t"], -1),
        ack_arrive_t=jnp.full_like(state["ack_arrive_t"], -1),
        entry_submit_t=jnp.full_like(state["entry_submit_t"], -1),
        entry_commit_t=jnp.full_like(state["entry_commit_t"], -1),
        reads_arrived=jnp.zeros_like(state["reads_arrived"]),
        writes_arrived=jnp.zeros_like(state["writes_arrived"]),
        cross_arrived=jnp.zeros_like(state["cross_arrived"]),
        reads_served=jnp.zeros_like(state["reads_served"]),
        writes_committed=jnp.zeros_like(state["writes_committed"]),
        read_lat_sum=jnp.zeros_like(state["read_lat_sum"]),
        read_lat_max=jnp.zeros_like(state["read_lat_max"]),
        read_lat_hist=jnp.zeros_like(state["read_lat_hist"]),
        # the metrics registry is per-epoch (its digest row was just
        # taken); the trace ring + cursor are NOT reset — the cursor is
        # monotone so host drains stay exact (DESIGN.md §14)
        metrics_ctr=jnp.zeros_like(state["metrics_ctr"]),
    )


def lease_and_wire(cfg: ClusterConfig, static, role: np.ndarray,
                   alive: np.ndarray, np_rng, predictor, leased: np.ndarray,
                   want_sec: int, want_obs: int,
                   warned: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray]:
    """Peak: score a spot-offer pool (eq. 2), MCSA-select, wire roles.

    Pure numpy control-plane step shared by BWRaftSim and FleetSim.
    Returns updated (role, alive, sec_of, obs_of); `leased` is a per-site
    lease census updated in place.  `warned` (optional (N,) bool, the
    digest's advance-warning census, DESIGN.md §12) excludes warned
    secretaries from the follower fan-out wiring so replacements leased
    this epoch take over BEFORE the kill lands; None or all-False is
    bit-identical to the pre-warning wiring.
    """
    site = static["site"]
    V = static["V"]
    n_sites = cfg.num_sites
    role = np.asarray(role).copy()
    alive = np.asarray(alive).copy()
    warned = (np.zeros(role.shape, bool) if warned is None
              else np.asarray(warned).astype(bool))

    def lease_slots(slot_mask, want):
        free = np.where(slot_mask & (role == DEAD))[0]
        if want <= 0 or len(free) == 0:
            return []
        pool = min(len(free) * 4, 256)
        offer_site = np_rng.integers(0, n_sites, pool)
        cpu = np_rng.uniform(1, 4, pool)
        mem = np_rng.uniform(1, 8, pool)
        price = np.array([cfg.sites[s].spot_price_mean for s in
                          offer_site]) * np_rng.uniform(0.6, 1.6, pool)
        revoke = predictor.predict()[offer_site]
        scores = mgr.spot_scores(cpu, mem, price, revoke)
        picked = mcsa.mcsa_topk(scores, min(want, len(free)), np_rng)
        chosen_sites = [int(offer_site[i]) for i in picked]
        slots = []
        for s_id in chosen_sites:
            cands = [f for f in free
                     if site[f] == s_id and f not in slots]
            if not cands:
                cands = [f for f in free if f not in slots]
            if cands:
                slots.append(int(cands[0]))
                leased[site[slots[-1]]] += 1
        return slots

    for s in lease_slots(static["is_secretary_slot"], want_sec):
        role[s] = SECRETARY
        alive[s] = True
    for s in lease_slots(static["is_observer_slot"], want_obs):
        role[s] = OBSERVER
        alive[s] = True

    # wire followers -> site secretary (round robin), observers -> a
    # follower at their site
    sec_of = np.full(role.shape, -1, np.int32)
    obs_of = np.full(role.shape, -1, np.int32)
    for s_id in range(n_sites):
        secs = [i for i in range(len(role))
                if role[i] == SECRETARY and alive[i] and not warned[i]
                and site[i] == s_id]
        fols = [i for i in range(V)
                if role[i] in (FOLLOWER, LEADER) and alive[i]
                and site[i] == s_id]
        if secs:
            for j, f in enumerate(fols):
                sec_of[f] = secs[j % len(secs)]
        obss = [i for i in range(len(role))
                if role[i] == OBSERVER and alive[i] and site[i] == s_id]
        if fols:
            for j, o in enumerate(obss):
                obs_of[o] = fols[j % len(fols)]
    # cross-site fallback wiring for observers at secretary-less sites
    all_fols = [i for i in range(V) if role[i] in (FOLLOWER, LEADER)
                and alive[i]]
    for o in range(len(role)):
        if role[o] == OBSERVER and alive[o] and obs_of[o] < 0 and all_fols:
            obs_of[o] = all_fols[o % len(all_fols)]
    return role, alive, sec_of, obs_of


class ClusterController:
    """Host-side per-cluster control plane ("peek" + "peak" bookkeeping).

    Owns the numpy RNG, the revocation predictor, the per-site lease
    census, and the read-growth history — everything Algorithm 1 needs
    between epochs.  One instance per simulated cluster, shared by the
    sequential `BWRaftSim` and every member of a batched `FleetSim`.
    """

    def __init__(self, cfg: ClusterConfig, static, *, seed: int,
                 predictor: Optional[mgr.RevocationPredictor] = None):
        self.cfg = cfg
        self.static = static
        self.np_rng = np.random.default_rng(seed + 1)
        # default: flat-prior EWMA; pass a trace-calibrated predictor
        # (`market.calibrate.calibrate_predictor`) to score spot offers
        # with per-site rates fitted offline (DESIGN.md §10)
        self.predictor = predictor if predictor is not None \
            else mgr.RevocationPredictor(cfg.num_sites)
        self.reads_prev = 0
        self.leased = np.zeros(cfg.num_sites, np.int64)

    def decide(self, rep: EpochReport, spot_price: float
               ) -> mgr.PeekDecision:
        """Algorithm 1 on this epoch's stats (call only when managing)."""
        self.predictor.update(
            np.full(self.cfg.num_sites,
                    rep.killed / max(self.cfg.num_sites, 1)),
            np.maximum(self.leased, 1))
        stats = mgr.PeekStats(
            reads_prev=self.reads_prev,
            reads_now=rep.reads_arrived,
            writes_now=rep.writes_arrived,
            followers_per_site=[s.followers for s in self.cfg.sites],
            k_s=rep.n_secretaries, k_o=rep.n_observers,
            budget=self.cfg.budget_per_period,
            spot_price=spot_price,
            on_demand_price=float(
                np.mean([s.on_demand_price for s in self.cfg.sites])),
        )
        return mgr.algorithm1(self.cfg, stats)

    def lease(self, role, alive, want_sec: int, want_obs: int,
              warned=None):
        return lease_and_wire(self.cfg, self.static, role, alive,
                              self.np_rng, self.predictor, self.leased,
                              want_sec, want_obs, warned=warned)

    def end_epoch(self, rep: EpochReport) -> None:
        self.reads_prev = rep.reads_arrived


_EPOCH_CACHE: Dict = {}


def _epoch_fn_for(cfg: ClusterConfig, static,
                  pads=(0, 0, 0, 0, 0, 0, trace_ring.DEFAULT_CAPACITY),
                  backend: str = "xla"):
    """One jitted epoch function per (cluster config, padding, backend) —
    cfg_c values are jit *arguments* (rate sweeps re-use the compiled
    program).  The returned function is the device-resident digest path:
    it compacts in-graph and donates the state buffers (DESIGN.md §7.1).
    `backend` is resolved first (DESIGN.md §8), so `"auto"` and its
    per-platform resolution share one compiled program."""
    backend = resolve_backend(backend)
    key = (cfg, pads, backend)
    if key not in _EPOCH_CACHE:
        def epoch_fn(state, rng, cfg_c):
            return device_epoch(state, static, cfg_c, rng, cfg.period_ticks,
                                backend=backend)
        _EPOCH_CACHE[key] = CountingJit(epoch_fn, donate_argnums=(0,))
    return _EPOCH_CACHE[key]


class BWRaftSim:
    """In-process BW-Raft cluster simulation (the paper's prototype).

    `pad_*` widen the state shapes with inert slots/sites/log tail so a
    solo run can reproduce exactly the shapes a `FleetSim` member gets when
    batched next to bigger clusters (DESIGN.md §7).  `backend` selects the
    tick hot-op implementation — `"xla"` (default), `"pallas"` (the
    fused kernel families, DESIGN.md §8), or `"auto"` (pallas on TPU,
    xla elsewhere — resolved at construction, `self.backend` holds the
    resolution); trajectories are bit-identical either way (test
    invariant).

    `market="trace"` replays a `market.MarketTrace` instead of the
    synthetic walk (DESIGN.md §10) — the trace rides in `cfg_c` as jit
    arguments, and a walk exported via
    `market/synthetic.export_walk_trace` at this seed replays
    bit-identically.  `predictor` optionally seeds the control plane
    with a trace-calibrated `RevocationPredictor`
    (`market.calibrate.calibrate_predictor`).
    """

    def __init__(self, cfg: ClusterConfig, *, mode: str = "bwraft",
                 write_rate: float = 8.0, read_rate: float = 32.0,
                 phi: float = 0.0, seed: int = 0,
                 manage_resources: bool = True,
                 pad_nodes: int = 0, pad_sites: int = 0,
                 pad_log: int = 0, pad_keys: int = 0,
                 spot_price_vol: Optional[float] = None,
                 prelease: Optional[Tuple[int, int]] = None,
                 backend: str = "xla",
                 cross_shard_frac: float = 0.0, two_pc_ticks: int = 0,
                 market: str = "process", trace=None, predictor=None,
                 arrivals=None, keypop=None,
                 warning_ticks: int = 0, spot_bid=None,
                 bid_on_trace: bool = False, faults=None,
                 fault_ticks: Optional[int] = None, bid_policy=None,
                 n_observers: int = 0, pad_observers: int = 0,
                 staleness_bound: int = 16, ae_interval: int = 4,
                 ae_phase=None, trace_on: bool = False, trace_mask=None,
                 trace_capacity: int = trace_ring.DEFAULT_CAPACITY):
        assert mode in ("bwraft", "raft")
        backend = resolve_backend(backend)
        self.cfg = cfg
        self.mode = mode
        self.backend = backend
        self.static = state_mod.build_static(cfg, pad_nodes=pad_nodes,
                                             pad_sites=pad_sites,
                                             n_obs_digest=n_observers,
                                             pad_obs=pad_observers,
                                             trace_capacity=trace_capacity)
        self.state = state_mod.init_state(cfg, self.static, pad_log=pad_log,
                                          pad_keys=pad_keys)
        self.cfg_c = make_cfg_arrays(cfg, write_rate=write_rate,
                                     read_rate=read_rate, phi=phi,
                                     pad_nodes=pad_nodes,
                                     pad_sites=pad_sites, pad_keys=pad_keys,
                                     spot_price_vol=spot_price_vol,
                                     cross_shard_frac=cross_shard_frac,
                                     two_pc_ticks=two_pc_ticks,
                                     market=market, trace=trace,
                                     arrivals=arrivals, keypop=keypop,
                                     warning_ticks=warning_ticks,
                                     spot_bid=spot_bid,
                                     bid_on_trace=bid_on_trace,
                                     faults=faults, fault_ticks=fault_ticks,
                                     n_observers=n_observers,
                                     pad_observers=pad_observers,
                                     staleness_bound=staleness_bound,
                                     ae_interval=ae_interval,
                                     ae_phase=ae_phase,
                                     trace_on=trace_on,
                                     trace_mask=trace_mask)
        # hazard-aware bid policy (DESIGN.md §12): an object with
        # `.update(predictor=, trace=, end_tick=, sites=)` returning the
        # next (S,) bids — applied per epoch through `set_bid`, which is
        # a cfg_c data swap (never recompiles)
        self.bid_policy = bid_policy
        self._trace = trace
        self.rng = jax.random.PRNGKey(seed)
        self.manage = manage_resources and mode == "bwraft"
        self.controller = ClusterController(cfg, self.static, seed=seed,
                                            predictor=predictor)
        self.epoch = 0
        self._reports: List[EpochReport] = []
        # most recent epoch digest (numpy leaves) — kept so benchmarks
        # and tests can reach the raw unit-bin latency histograms
        # (goodput-under-deadline, DESIGN.md §11) without re-marshalling
        self.last_digest: Optional[Dict] = None

        # flight-recorder drain state (DESIGN.md §14): events appended
        # here once per traced epoch by `run_epoch`'s single D2H fetch
        self._trace_cursor = trace_export.DrainCursor()
        self.trace_events: List[trace_export.TraceEvent] = []

        self._epoch_fn = _epoch_fn_for(
            cfg, self.static, (pad_nodes, pad_sites, pad_log, pad_keys,
                               n_observers, pad_observers, trace_capacity),
            backend=backend)
        if prelease is not None:
            # fixed-role mode: wire a static secretary/observer complement
            # once, before the run (no per-epoch management)
            self._lease(max(prelease[0], 0), max(prelease[1], 0))

    # ------------------------------------------------------------------ #
    def set_rates(self, write_rate=None, read_rate=None, phi=None):
        if write_rate is not None:
            self.cfg_c["write_rate"] = jnp.float32(write_rate)
        if read_rate is not None:
            self.cfg_c["read_rate"] = jnp.float32(read_rate)
        if phi is not None:
            self.cfg_c["phi"] = jnp.float32(phi)

    def set_arrivals(self, arrivals) -> None:
        """Swap the open-loop arrival plan in place.  Curves are jit
        arguments at a fixed width (the width the sim was built with),
        so the swap never recompiles (DESIGN.md §11) — the serving-side
        twin of swapping market traces at one shape."""
        width = int(self.cfg_c["write_curve"].shape[0])
        w, r, alen = arrivals.fit_to(width)
        self.cfg_c["open_loop"] = jnp.asarray(True)
        self.cfg_c["write_curve"] = jnp.asarray(w)
        self.cfg_c["read_curve"] = jnp.asarray(r)
        self.cfg_c["arrival_len"] = jnp.int32(alen)

    def set_bid(self, bids) -> None:
        """Swap the per-site spot bids in place — cfg_c data at a fixed
        (S,) shape, so bid-policy updates never recompile (DESIGN.md
        §12); the market-side twin of `set_arrivals`.  A scalar
        broadcasts; a short vector repeats its last site (the
        `site_price_init` padding rule)."""
        S = int(self.cfg_c["spot_bid"].shape[0])
        b = np.asarray(bids, np.float32).reshape(-1)
        if b.size == 1:
            b = np.full((S,), b[0], np.float32)
        elif b.size < S:
            b = np.concatenate(
                [b, np.full((S - b.size,), b[-1], np.float32)])
        self.cfg_c["spot_bid"] = jnp.asarray(b[:S], jnp.float32)

    def set_trace(self, on=None, mask=None) -> None:
        """Toggle flight-recorder capture / remask event classes in
        place — cfg_c data at fixed shapes, so flips never recompile
        (DESIGN.md §14); the observability twin of `set_rates` /
        `set_bid`.  `mask` accepts anything `trace.ring.default_mask`
        produces (an (NCLASS,) bool sequence)."""
        if on is not None:
            self.cfg_c["trace_on"] = jnp.asarray(bool(on))
        if mask is not None:
            m = np.asarray(mask, bool).reshape(-1)
            assert m.size == trace_ring.NCLASS, m.size
            self.cfg_c["trace_mask"] = jnp.asarray(m)

    def drain_trace(self) -> List[trace_export.TraceEvent]:
        """Decode the ring slots appended since the last drain (one D2H
        fetch of the three trace leaves); `run_epoch` calls this
        automatically while `trace_on` is set.  Exact per-class
        overwrite counts accumulate on `self.events_dropped`."""
        events = self._trace_cursor.drain(self.state)
        self.trace_events.extend(events)
        return events

    @property
    def events_dropped(self) -> Dict[str, int]:
        return self._trace_cursor.dropped_by_class()

    def _lease(self, want_sec: int, want_obs: int, warned=None) -> None:
        """Peak: score a spot-offer pool (eq. 2), MCSA-select, wire roles."""
        role, alive, sec_of, obs_of = self.controller.lease(
            np.asarray(self.state["role"]), np.asarray(self.state["alive"]),
            want_sec, want_obs, warned=warned)
        self.state = dict(self.state,
                          role=jnp.asarray(role),
                          alive=jnp.asarray(alive),
                          sec_of=jnp.asarray(sec_of),
                          obs_of=jnp.asarray(obs_of))

    def lease_fixed(self, want_sec: int, want_obs: int) -> None:
        """One-shot fixed-role wiring (the solo twin of
        `FleetSim.lease_fixed`): lease and wire a static complement now,
        typically after a stabilization epoch, with per-epoch management
        off — the fixed-role sweep recipe (fig12/fig13)."""
        self._lease(max(want_sec, 0), max(want_obs, 0))

    # ------------------------------------------------------------------ #
    def run_epoch(self) -> EpochReport:
        """One epoch on the digest path: the jitted scan compacts in-graph
        and donates the state buffers; only the few-KB digest is pulled to
        host (DESIGN.md §7.1 — no full log/kv/entry transfer)."""
        self.rng, sub = jax.random.split(self.rng)
        self.state, digest = self._epoch_fn(self.state, sub, self.cfg_c)
        dg = jax.tree.map(np.asarray, digest)
        self.last_digest = dg
        if bool(np.asarray(self.cfg_c["trace_on"])):
            # drain the ring from the RETURNED state (the donated input
            # buffers are gone) before the next epoch overwrites it —
            # the one extra D2H fetch tracing costs (DESIGN.md §14)
            self.drain_trace()

        rep = report_from_digest(self.epoch, dg)

        # ---- control plane: peek (Algorithm 1) + peak (MCSA lease) ------
        if self.manage:
            dec = self.controller.decide(
                rep, float(np.mean(dg["spot_price"][:self.cfg.num_sites])))
            rep.decision = dec
            # re-lease BEFORE the kill lands (DESIGN.md §12): warned
            # secretaries/observers get replacements on top of Algorithm
            # 1's delta, and warned secretaries drop out of the wiring;
            # with no warnings raised this is exactly the pre-§12 lease
            warned = np.asarray(dg["warned"])
            roles = np.asarray(dg["role"])
            self._lease(
                max(dec.dk_s, 0) + int(((roles == SECRETARY) &
                                        warned).sum()),
                max(dec.dk_o, 0) + int(((roles == OBSERVER) &
                                        warned).sum()),
                warned=warned)
        if self.bid_policy is not None:
            self.set_bid(self.bid_policy.update(
                predictor=self.controller.predictor, trace=self._trace,
                end_tick=(self.epoch + 1) * self.cfg.period_ticks,
                sites=int(self.cfg_c["spot_bid"].shape[0])))
        self.controller.end_epoch(rep)

        self.epoch += 1
        self._reports.append(rep)
        return rep

    def run(self, epochs: int) -> List[EpochReport]:
        return [self.run_epoch() for _ in range(epochs)]

    @property
    def reports(self) -> List[EpochReport]:
        return self._reports
