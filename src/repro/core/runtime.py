"""BW-Raft runtime: jitted tick-scan epochs + host-side control plane.

One *epoch* = `cfg.period_ticks` protocol ticks (jitted `lax.scan`), after
which the control plane runs: collect stats ("peek", Algorithm 1), score
the spot-offer pool and select instances (MCSA, "peak"), lease them into
dead spot slots, wire secretaries/observers, compact the log window.
`mode="raft"` disables spot roles entirely (the Original baseline).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import manager as mgr
from repro.core import mcsa
from repro.core import step as step_mod
from repro.core import state as state_mod
from repro.core.cluster_config import ClusterConfig
from repro.core.state import (DEAD, FOLLOWER, LEADER, OBSERVER, SECRETARY)


def make_cfg_arrays(cfg: ClusterConfig, *, write_rate: float,
                    read_rate: float, phi: float = 0.0) -> Dict:
    S = cfg.num_sites
    return {
        "write_rate": jnp.float32(write_rate),
        "read_rate": jnp.float32(read_rate),
        "phi": jnp.float32(phi),
        "heartbeat_interval": jnp.int32(cfg.heartbeat_interval),
        "election_timeout_min": jnp.int32(cfg.election_timeout_min),
        "election_timeout_max": jnp.int32(cfg.election_timeout_max),
        "on_demand_price": jnp.asarray(
            [s.on_demand_price for s in cfg.sites], jnp.float32),
        "spot_price_mean": jnp.asarray(
            [s.spot_price_mean for s in cfg.sites], jnp.float32),
        "spot_price_vol": jnp.float32(cfg.sites[0].spot_price_vol),
        "ticks_per_hour": jnp.float32(3600.0 / 0.01 / 100),  # 1 tick = 10ms
        "network_cost_coef": jnp.float32(0.0005),
    }


@dataclasses.dataclass
class EpochReport:
    epoch: int
    reads_arrived: int
    writes_arrived: int
    reads_served: int
    writes_committed: int
    read_lat_mean: float
    read_lat_max: float
    write_lat_mean: float
    write_lat_p95: float
    write_lat_p99: float
    cost: float
    n_secretaries: int
    n_observers: int
    leader_changes: int
    no_leader_ticks: int
    killed: int
    decision: Optional[mgr.PeekDecision] = None

    @property
    def goodput(self) -> float:
        return (self.reads_served + self.writes_committed) / 1.0


_EPOCH_CACHE: Dict = {}


def _epoch_fn_for(cfg: ClusterConfig, static):
    """One jitted epoch function per cluster config — cfg_c values are jit
    *arguments* (rate sweeps re-use the compiled program)."""
    if cfg not in _EPOCH_CACHE:
        @jax.jit
        def epoch_fn(state, rng, cfg_c):
            def body(carry, r):
                st, _ = carry
                st, m = step_mod.tick(st, static, cfg_c, r)
                return (st, 0), m
            rngs = jax.random.split(rng, cfg.period_ticks)
            (state, _), ms = jax.lax.scan(body, (state, 0), rngs)
            return state, ms
        _EPOCH_CACHE[cfg] = epoch_fn
    return _EPOCH_CACHE[cfg]


class BWRaftSim:
    """In-process BW-Raft cluster simulation (the paper's prototype)."""

    def __init__(self, cfg: ClusterConfig, *, mode: str = "bwraft",
                 write_rate: float = 8.0, read_rate: float = 32.0,
                 phi: float = 0.0, seed: int = 0,
                 manage_resources: bool = True):
        assert mode in ("bwraft", "raft")
        self.cfg = cfg
        self.mode = mode
        self.static = state_mod.build_static(cfg)
        self.state = state_mod.init_state(cfg, self.static)
        self.cfg_c = make_cfg_arrays(cfg, write_rate=write_rate,
                                     read_rate=read_rate, phi=phi)
        self.rng = jax.random.PRNGKey(seed)
        self.np_rng = np.random.default_rng(seed + 1)
        self.manage = manage_resources and mode == "bwraft"
        self.predictor = mgr.RevocationPredictor(cfg.num_sites)
        self.epoch = 0
        self.reads_prev = 0
        self._reports: List[EpochReport] = []
        self._leased = np.zeros(cfg.num_sites, np.int64)
        self._revoked = np.zeros(cfg.num_sites, np.int64)

        self._epoch_fn = _epoch_fn_for(cfg, self.static)

    # ------------------------------------------------------------------ #
    def set_rates(self, write_rate=None, read_rate=None, phi=None):
        if write_rate is not None:
            self.cfg_c["write_rate"] = jnp.float32(write_rate)
        if read_rate is not None:
            self.cfg_c["read_rate"] = jnp.float32(read_rate)
        if phi is not None:
            self.cfg_c["phi"] = jnp.float32(phi)

    def _lease(self, want_sec: int, want_obs: int) -> None:
        """Peak: score a spot-offer pool (eq. 2), MCSA-select, wire roles."""
        st = jax.tree.map(np.asarray, self.state)
        cfg, static = self.cfg, self.static
        site = static["site"]
        V = static["V"]
        n_sites = cfg.num_sites

        def lease_slots(slot_mask, want, role_val):
            free = np.where(slot_mask & (st["role"] == DEAD))[0]
            if want <= 0 or len(free) == 0:
                return []
            pool = min(len(free) * 4, 256)
            offer_site = self.np_rng.integers(0, n_sites, pool)
            cpu = self.np_rng.uniform(1, 4, pool)
            mem = self.np_rng.uniform(1, 8, pool)
            price = np.array([cfg.sites[s].spot_price_mean for s in
                              offer_site]) * self.np_rng.uniform(
                0.6, 1.6, pool)
            revoke = self.predictor.predict()[offer_site]
            scores = mgr.spot_scores(cpu, mem, price, revoke)
            picked = mcsa.mcsa_topk(scores, min(want, len(free)),
                                    self.np_rng)
            chosen_sites = [int(offer_site[i]) for i in picked]
            slots = []
            for s_id in chosen_sites:
                cands = [f for f in free
                         if site[f] == s_id and f not in slots]
                if not cands:
                    cands = [f for f in free if f not in slots]
                if cands:
                    slots.append(int(cands[0]))
                    self._leased[site[slots[-1]]] += 1
            return slots

        sec_slots = lease_slots(static["is_secretary_slot"], want_sec,
                                SECRETARY)
        obs_slots = lease_slots(static["is_observer_slot"], want_obs,
                                OBSERVER)

        role = st["role"].copy()
        alive = st["alive"].copy()
        for s in sec_slots:
            role[s] = SECRETARY
            alive[s] = True
        for s in obs_slots:
            role[s] = OBSERVER
            alive[s] = True

        # wire followers -> site secretary (round robin), observers -> a
        # follower at their site
        sec_of = np.full(role.shape, -1, np.int32)
        obs_of = np.full(role.shape, -1, np.int32)
        for s_id in range(n_sites):
            secs = [i for i in range(len(role))
                    if role[i] == SECRETARY and alive[i] and site[i] == s_id]
            fols = [i for i in range(V)
                    if role[i] in (FOLLOWER, LEADER) and alive[i]
                    and site[i] == s_id]
            if secs:
                for j, f in enumerate(fols):
                    sec_of[f] = secs[j % len(secs)]
            obss = [i for i in range(len(role))
                    if role[i] == OBSERVER and alive[i] and site[i] == s_id]
            if fols:
                for j, o in enumerate(obss):
                    obs_of[o] = fols[j % len(fols)]
        # cross-site fallback wiring for observers at secretary-less sites
        all_fols = [i for i in range(V) if role[i] in (FOLLOWER, LEADER)
                    and alive[i]]
        for o in range(len(role)):
            if role[o] == OBSERVER and alive[o] and obs_of[o] < 0 and \
                    all_fols:
                obs_of[o] = all_fols[o % len(all_fols)]

        self.state = dict(self.state,
                          role=jnp.asarray(role),
                          alive=jnp.asarray(alive),
                          sec_of=jnp.asarray(sec_of),
                          obs_of=jnp.asarray(obs_of))

    def _compact(self) -> None:
        """Epoch-boundary log compaction (state machines keep the data)."""
        st = self.state
        L = st["log_term"].shape[1]
        N = st["log_term"].shape[0]
        z = jnp.zeros((N,), jnp.int32)
        self.state = dict(
            st,
            log_term=jnp.zeros_like(st["log_term"]),
            log_key=jnp.zeros_like(st["log_key"]),
            log_val=jnp.zeros_like(st["log_val"]),
            log_len=z, commit_len=z, applied_len=z, match_len=z,
            app_arrive_t=jnp.full((N,), -1, jnp.int32),
            ack_arrive_t=jnp.full((N,), -1, jnp.int32),
            entry_submit_t=jnp.full((L,), -1, jnp.int32),
            entry_commit_t=jnp.full((L,), -1, jnp.int32),
            reads_arrived=jnp.zeros((), jnp.int32),
            writes_arrived=jnp.zeros((), jnp.int32),
            reads_served=jnp.zeros((), jnp.int32),
            writes_committed=jnp.zeros((), jnp.int32),
            read_lat_sum=jnp.zeros((), jnp.float32),
            read_lat_max=jnp.zeros((), jnp.float32),
        )

    # ------------------------------------------------------------------ #
    def run_epoch(self) -> EpochReport:
        self.rng, sub = jax.random.split(self.rng)
        cost_before = float(self.state["cost_accrued"])
        self.state, ms = self._epoch_fn(self.state, sub, self.cfg_c)
        st = jax.tree.map(np.asarray, self.state)
        ms = jax.tree.map(np.asarray, ms)

        # write latency from the entry timeline
        sub_t = st["entry_submit_t"]
        com_t = st["entry_commit_t"]
        done = (sub_t >= 0) & (com_t >= 0)
        lat = (com_t[done] - sub_t[done]).astype(float)
        reads_served = int(st["reads_served"])
        rep = EpochReport(
            epoch=self.epoch,
            reads_arrived=int(st["reads_arrived"]),
            writes_arrived=int(st["writes_arrived"]),
            reads_served=reads_served,
            writes_committed=int(done.sum()),
            read_lat_mean=float(st["read_lat_sum"] / max(reads_served, 1)),
            read_lat_max=float(st["read_lat_max"]),
            write_lat_mean=float(lat.mean()) if lat.size else float("nan"),
            write_lat_p95=float(np.percentile(lat, 95)) if lat.size
            else float("nan"),
            write_lat_p99=float(np.percentile(lat, 99)) if lat.size
            else float("nan"),
            cost=float(st["cost_accrued"]) - cost_before,
            n_secretaries=int(ms["n_secretaries"][-1]),
            n_observers=int(ms["n_observers"][-1]),
            leader_changes=int((np.diff(ms["leader_term"]) > 0).sum()),
            no_leader_ticks=int((ms["has_leader"] == 0).sum()),
            killed=int(ms["killed"].sum()),
        )

        # ---- control plane: peek (Algorithm 1) + peak (MCSA lease) ------
        if self.manage:
            self._revoked += np.bincount(
                self.static["site"][~np.asarray(st["alive"])],
                minlength=self.cfg.num_sites) * 0  # placeholder census
            self.predictor.update(
                np.full(self.cfg.num_sites, rep.killed /
                        max(self.cfg.num_sites, 1)),
                np.maximum(self._leased, 1))
            stats = mgr.PeekStats(
                reads_prev=self.reads_prev,
                reads_now=rep.reads_arrived,
                writes_now=rep.writes_arrived,
                followers_per_site=[s.followers for s in self.cfg.sites],
                k_s=rep.n_secretaries, k_o=rep.n_observers,
                budget=self.cfg.budget_per_period,
                spot_price=float(np.mean(st["spot_price"])),
                on_demand_price=float(
                    np.mean([s.on_demand_price for s in self.cfg.sites])),
            )
            dec = mgr.algorithm1(self.cfg, stats)
            rep.decision = dec
            self._lease(max(dec.dk_s, 0), max(dec.dk_o, 0))
        self.reads_prev = rep.reads_arrived

        self._compact()
        self.epoch += 1
        self._reports.append(rep)
        return rep

    def run(self, epochs: int) -> List[EpochReport]:
        return [self.run_epoch() for _ in range(epochs)]

    @property
    def reports(self) -> List[EpochReport]:
        return self._reports
