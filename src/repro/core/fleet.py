"""Batched fleet simulator: B independent BW-Raft clusters in ONE program.

The paper's headline results are sweep-shaped — goodput/cost versus node
count, write ratio, spot volatility, and kill rate — yet a sequential
`BWRaftSim` pays one Python-driven jitted epoch per point.  `FleetSim`
vmaps the same `core/step.tick` over a leading batch axis of B clusters so
an entire sweep grid advances in a single `lax.scan` epoch.

Compilation contract (DESIGN.md §7): the batched epoch function is
compiled **once per static shape**.  The cache key is

    (B, N, S, L, K, period_ticks, shared capacity scalars)

where N/S/L/K are the node/site/log/key-space sizes **padded to the max
across the batch**.  Everything else — per-cluster rates, phi, prices,
volatility, timeouts, voter majorities, RTT matrices — enters as jit
*arguments*, so changing the sweep grid, the seeds, or even the member
topologies (at equal padded shapes) never recompiles.  Check
`FleetSim.compile_count` (the example `examples/sweep_fleet.py` asserts
it is exactly 1 for a 32-cluster sweep).

Padding/masking rules (DESIGN.md §7): smaller clusters are padded with
inert node slots (non-voter, non-leasable, forever DEAD — every step rule
masks on `alive`), price-only padded sites, and dead log/key tail space.
Batched results are element-wise equal to sequential `BWRaftSim` runs of
the same padded shapes and seeds (`tests/test_fleet.py` proves it): the
per-member RNG streams are split identically, and member dynamics never
couple across the batch axis.

The host-side control plane (Algorithm 1 "peek", MCSA "peak" leasing, log
compaction) still runs per member between epochs, reusing
`runtime.ClusterController` — only the tick-scan hot path is batched.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import state as state_mod
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig
from repro.core.runtime import (ClusterController, EpochReport,
                                build_report, compact_state,
                                make_cfg_arrays)

# static scalars every member must agree on (baked into the compiled
# program; per-node capacities from state.build_static)
_SHARED_STATIC_KEYS = ("work_capacity", "msg_budget", "entries_per_msg",
                       "max_ship", "max_apply")
# per-member static arrays that become jit arguments (batch axis 0)
_BATCHED_STATIC_KEYS = ("site", "is_voter", "rtt", "majority")

# spec fields sweepable via FleetSim.from_sweep axes
_SWEEP_AXES = ("mode", "write_rate", "read_rate", "phi", "seed",
               "manage_resources", "spot_price_vol", "budget_per_period")


@dataclasses.dataclass(frozen=True)
class MemberSpec:
    """One cluster in the fleet: topology + workload knobs + seed."""
    cfg: ClusterConfig
    mode: str = "bwraft"
    write_rate: float = 8.0
    read_rate: float = 32.0
    phi: float = 0.0
    seed: int = 0
    manage_resources: bool = True
    spot_price_vol: Optional[float] = None      # None -> cfg.sites[0]
    budget_per_period: Optional[float] = None   # None -> cfg value

    @property
    def manage(self) -> bool:
        return self.manage_resources and self.mode == "bwraft"


@dataclasses.dataclass(frozen=True)
class FleetShapes:
    B: int
    N: int   # nodes, padded to max over members
    S: int   # sites, padded
    L: int   # log window, padded
    K: int   # KV key space, padded
    T: int   # period_ticks (must be equal across members)


_FLEET_EPOCH_CACHE: Dict = {}


def total_compile_count() -> int:
    """Compiled batched-epoch programs across every fleet shape this
    process has run — the one place that touches jit cache internals."""
    return sum(int(fn._cache_size()) for fn in _FLEET_EPOCH_CACHE.values())


def _fleet_epoch_fn(shapes: FleetShapes, shared: Dict):
    """The one-compile-per-static-shape entry point: a jitted, vmapped
    `period_ticks`-scan over the whole fleet.  `shared` (python ints) is
    closed over; batched statics and cfg_c are runtime arguments."""
    key = (shapes, tuple(sorted(shared.items())))
    if key not in _FLEET_EPOCH_CACHE:
        @jax.jit
        def epoch_fn(state, rngs, bstatic, cfg_c):
            def one_epoch(st, rng, bstat, cc):
                static = {**shared, **bstat}

                def body(carry, r):
                    s, m = step_mod.tick(carry, static, cc, r)
                    return s, m
                ticks = jax.random.split(rng, shapes.T)
                return jax.lax.scan(body, st, ticks)
            return jax.vmap(one_epoch)(state, rngs, bstatic, cfg_c)
        _FLEET_EPOCH_CACHE[key] = epoch_fn
    return _FLEET_EPOCH_CACHE[key]


class _Member:
    """Host-side bookkeeping for one fleet slot."""

    def __init__(self, spec: MemberSpec, shapes: FleetShapes):
        assert spec.mode in ("bwraft", "raft")
        cfg = spec.cfg
        if spec.budget_per_period is not None:
            cfg = dataclasses.replace(
                cfg, budget_per_period=spec.budget_per_period)
        self.spec = spec
        self.cfg = cfg
        self.pads = {
            "pad_nodes": shapes.N - cfg.max_nodes,
            "pad_sites": shapes.S - cfg.num_sites,
            "pad_log": shapes.L - cfg.max_log,
            "pad_keys": shapes.K - cfg.key_space,
        }
        assert all(p >= 0 for p in self.pads.values()), \
            f"member {cfg.name} exceeds fleet shapes {shapes}"
        self.static = state_mod.build_static(
            cfg, pad_nodes=self.pads["pad_nodes"],
            pad_sites=self.pads["pad_sites"])
        self.state0 = state_mod.init_state(
            cfg, self.static, pad_log=self.pads["pad_log"],
            pad_keys=self.pads["pad_keys"])
        self.cfg_c = make_cfg_arrays(
            cfg, write_rate=spec.write_rate, read_rate=spec.read_rate,
            phi=spec.phi, pad_sites=self.pads["pad_sites"],
            spot_price_vol=spec.spot_price_vol)
        self.rng = jax.random.PRNGKey(spec.seed)
        self.controller = ClusterController(cfg, self.static,
                                            seed=spec.seed)
        self.manage = spec.manage
        self.epoch = 0
        self.reports: List[EpochReport] = []


class FleetSim:
    """B independent clusters stepped in one jitted, vmapped program.

    Per-member dynamics are identical to a sequential `BWRaftSim` with the
    same padded shapes and seed; the control plane runs per member on the
    host between epochs.
    """

    def __init__(self, specs: Sequence[MemberSpec]):
        specs = list(specs)
        assert specs, "fleet needs at least one member"
        periods = {s.cfg.period_ticks for s in specs}
        assert len(periods) == 1, \
            f"all members must share period_ticks, got {periods}"
        self.shapes = FleetShapes(
            B=len(specs),
            N=max(s.cfg.max_nodes for s in specs),
            S=max(s.cfg.num_sites for s in specs),
            L=max(s.cfg.max_log for s in specs),
            K=max(s.cfg.key_space for s in specs),
            T=periods.pop(),
        )
        self.members = [_Member(s, self.shapes) for s in specs]

        self._shared = {k: self.members[0].static[k]
                        for k in _SHARED_STATIC_KEYS}
        for m in self.members[1:]:
            for k in _SHARED_STATIC_KEYS:
                assert m.static[k] == self._shared[k], \
                    f"member {m.cfg.name} disagrees on static {k}"

        self._bstatic = {
            k: (jnp.asarray([m.static[k] for m in self.members], jnp.int32)
                if k == "majority" else                      # scalar per member
                jnp.stack([jnp.asarray(m.static[k]) for m in self.members]))
            for k in _BATCHED_STATIC_KEYS
        }
        self._state = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[m.state0 for m in self.members])
        self._cfg_c = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[m.cfg_c for m in self.members])
        self._epoch_fn = _fleet_epoch_fn(self.shapes, self._shared)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_sweep(cls, configs, axes: Optional[Dict] = None,
                   **defaults) -> "FleetSim":
        """Cross-product sweep constructor.

        `configs`: one ClusterConfig or a sequence of them.  `axes`: dict
        mapping a MemberSpec field name (write_rate / read_rate / phi /
        seed / mode / spot_price_vol / budget_per_period / ...) to the
        values to sweep; the member list is configs x product(axes).
        `defaults` fill the remaining MemberSpec fields.
        """
        if isinstance(configs, ClusterConfig):
            configs = [configs]
        axes = dict(axes or {})
        for name in axes:
            assert name in _SWEEP_AXES, \
                f"unknown sweep axis {name!r}; valid: {_SWEEP_AXES}"
        names = list(axes.keys())
        specs = []
        for cfg in configs:
            for combo in itertools.product(*axes.values()):
                specs.append(MemberSpec(cfg=cfg, **defaults,
                                        **dict(zip(names, combo))))
        return cls(specs)

    @classmethod
    def sweep(cls, configs, axes: Optional[Dict] = None, *,
              epochs: int = 5, **defaults) -> List[List[EpochReport]]:
        """One-call sweep: build the fleet and run it.  Returns reports
        indexed [member][epoch]; member order is configs-major, then the
        cross product of `axes` in insertion order."""
        return cls.from_sweep(configs, axes, **defaults).run(epochs)

    # ------------------------------------------------------------------ #
    @property
    def compile_count(self) -> int:
        """How many programs the underlying epoch function has compiled
        (1 after any number of epochs/sweeps at this static shape)."""
        return int(self._epoch_fn._cache_size())

    def pads_for(self, i: int) -> Dict[str, int]:
        """Padding a solo BWRaftSim needs to reproduce member i exactly."""
        return dict(self.members[i].pads)

    @property
    def state(self) -> Dict:
        """Batched state pytree (leading axis = member)."""
        return self._state

    # ------------------------------------------------------------------ #
    def run_epoch(self) -> List[EpochReport]:
        subs = []
        for m in self.members:
            m.rng, sub = jax.random.split(m.rng)
            subs.append(sub)
        rngs = jnp.stack(subs)
        cost_before = np.asarray(self._state["cost_accrued"])

        self._state, ms = self._epoch_fn(self._state, rngs, self._bstatic,
                                         self._cfg_c)
        st_np = jax.tree.map(np.asarray, self._state)
        ms_np = jax.tree.map(np.asarray, ms)

        role = st_np["role"].copy()
        alive = st_np["alive"].copy()
        sec_of = st_np["sec_of"].copy()
        obs_of = st_np["obs_of"].copy()

        out = []
        for i, m in enumerate(self.members):
            sti = {k: v[i] for k, v in st_np.items()}
            msi = {k: v[i] for k, v in ms_np.items()}
            rep = build_report(m.epoch, sti, msi, float(cost_before[i]))
            if m.manage:
                dec = m.controller.decide(
                    rep,
                    float(np.mean(sti["spot_price"][:m.cfg.num_sites])))
                rep.decision = dec
                role[i], alive[i], sec_of[i], obs_of[i] = m.controller.lease(
                    role[i], alive[i], max(dec.dk_s, 0), max(dec.dk_o, 0))
            m.controller.end_epoch(rep)
            m.epoch += 1
            m.reports.append(rep)
            out.append(rep)

        self._state = compact_state(dict(
            self._state,
            role=jnp.asarray(role), alive=jnp.asarray(alive),
            sec_of=jnp.asarray(sec_of), obs_of=jnp.asarray(obs_of)))
        return out

    def run(self, epochs: int) -> List[List[EpochReport]]:
        """Run `epochs` epochs; returns the reports of *this call* indexed
        [member][epoch] (matching BWRaftSim.run; the full history stays on
        `self.reports`)."""
        start = len(self.members[0].reports)
        for _ in range(epochs):
            self.run_epoch()
        return [list(m.reports[start:]) for m in self.members]

    @property
    def reports(self) -> List[List[EpochReport]]:
        return [list(m.reports) for m in self.members]
