"""Batched fleet simulator: B independent BW-Raft clusters in ONE program.

The paper's headline results are sweep-shaped — goodput/cost versus node
count, write ratio, spot volatility, and kill rate — yet a sequential
`BWRaftSim` pays one Python-driven jitted epoch per point.  `FleetSim`
vmaps the same `core/step.tick` over a leading batch axis of B clusters so
an entire sweep grid advances in a single `lax.scan` epoch.

Compilation contract (DESIGN.md §7): the batched epoch function is
compiled **once per static shape**.  The cache key is

    (B, N, S, L, K, period_ticks, shared capacity scalars)

where N/S/L/K are the node/site/log/key-space sizes **padded to the max
across the batch**.  Everything else — per-cluster rates, phi, prices,
volatility, timeouts, voter majorities, RTT matrices, the (S, Tt)
market-trace arrays (DESIGN.md §10) — enters as jit *arguments*, so
changing the sweep grid, the seeds, the traces, or even the member
topologies (at equal padded shapes) never recompiles.  Check
`FleetSim.compile_count` (the example `examples/sweep_fleet.py` asserts
it is exactly 1 for a 32-cluster sweep).

Epoch pipeline (DESIGN.md §7.1): the default `pipeline="device"` keeps the
whole epoch loop device-resident — per-tick metrics reduce inside the
scan, log compaction is fused into the jitted epoch, and the state pytree
is donated back to XLA, so the only per-epoch device→host traffic is a
few-KB digest per member (`runtime.report_from_digest`).  When no member
manages resources (plain-Raft baselines, fixed-role `prelease` sweeps) a
whole `run(E)` collapses into ONE dispatch: a scan over E epochs with
in-graph compaction between them.  `pipeline="host"` retains the PR-1
host-marshalling path (full state + T-stacked metrics pulled to host each
epoch) for A/B benchmarking (`benchmarks/perf_fleet.py`) and the
digest-equivalence tests.

Padding/masking rules (DESIGN.md §7): smaller clusters are padded with
inert node slots (non-voter, non-leasable, forever DEAD — every step rule
masks on `alive`), price-only padded sites, and dead log/key tail space.
Batched results are element-wise equal to sequential `BWRaftSim` runs of
the same padded shapes and seeds (`tests/test_fleet.py` proves it): the
per-member RNG streams are split identically, and member dynamics never
couple across the batch axis.

The host-side control plane (Algorithm 1 "peek", MCSA "peak" leasing)
still runs per member between epochs, reusing `runtime.ClusterController`
— it reads the (N,) role/alive vectors from the digest and writes back
only the four (B, N) role/wiring arrays for the members that manage.

Shard groups (DESIGN.md §9): members with `group_id >= 0` are the shards
of ONE Multi-Raft system.  The epoch function reduces their digests to
per-group digests in-graph (segment ops over the batch axis, same
compiled dispatch) and `group_reports` serves them as `MultiRaftReport`s
— a whole S-shard x B-system baseline sweep is one program, its 2PC
rounds measured per request by the tick itself.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import state as state_mod
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig
from repro.core.runtime import (ClusterController, CountingJit, EpochReport,
                                build_report, compact_state, device_epoch,
                                make_cfg_arrays, report_from_digest)
from repro.core.state import pytree_nbytes
from repro.kernels import resolve_backend
from repro.kernels.group_digest import ops as gd_ops
from repro.trace import export as trace_export
from repro.trace import ring as trace_ring

# static scalars every member must agree on (baked into the compiled
# program; per-node capacities from state.build_static)
_SHARED_STATIC_KEYS = ("work_capacity", "msg_budget", "entries_per_msg",
                       "max_ship", "max_apply")
# per-member static arrays that become jit arguments (batch axis 0);
# site_rtt/dobs_site are the digest-tier addressing tables (DESIGN.md §13)
_BATCHED_STATIC_KEYS = ("site", "is_voter", "rtt", "majority",
                        "site_rtt", "dobs_site")

# spec fields sweepable via FleetSim.from_sweep axes
_SWEEP_AXES = ("mode", "write_rate", "read_rate", "phi", "seed",
               "manage_resources", "spot_price_vol", "budget_per_period",
               "market", "trace", "arrivals", "keypop",
               "warning_ticks", "bid_policy", "faults", "bid_on_trace",
               "n_observers", "staleness_bound", "ae_interval",
               "trace_on")


@dataclasses.dataclass(frozen=True)
class MemberSpec:
    """One cluster in the fleet: topology + workload knobs + seed."""
    cfg: ClusterConfig
    mode: str = "bwraft"
    write_rate: float = 8.0
    read_rate: float = 32.0
    phi: float = 0.0
    seed: int = 0
    manage_resources: bool = True
    spot_price_vol: Optional[float] = None      # None -> cfg.sites[0]
    budget_per_period: Optional[float] = None   # None -> cfg value
    # fixed-role mode: wire (n_secretaries, n_observers) once at t=0 and
    # never manage again — eligible for the single-dispatch multi-epoch
    # scan when combined with manage_resources=False (DESIGN.md §7.1)
    prelease: Optional[Tuple[int, int]] = None
    # shard-group identity (DESIGN.md §9): members sharing a group_id >= 0
    # are the shards of ONE Multi-Raft system — the fleet reduces their
    # digests to a per-group digest in-graph and reports them as a single
    # `MultiRaftReport`.  `shards_per_group` is the declared group size
    # (validated against the actual member count — the ragged-group
    # guard); `cross_shard_frac` is the 2PC coupling fraction χ;
    # `two_pc_ticks` overrides the 2PC round trip (None -> derived from
    # the topology via `multiraft.two_pc_penalty`).
    group_id: int = -1
    shards_per_group: int = 1
    cross_shard_frac: float = 0.0
    two_pc_ticks: Optional[int] = None
    # spot-market source (DESIGN.md §10): "process" runs the synthetic
    # walk, "trace" replays this member's `market.MarketTrace` — the
    # (S, Tt) price/revocation arrays ride in cfg_c as jit arguments
    # (every member's arrays are fitted to the fleet-wide max trace
    # length, time-wrapped, so one batched program serves any mix of
    # traced and process members and a B-trace sweep is one dispatch)
    market: str = "process"
    trace: Optional[object] = None          # market.MarketTrace
    # open-loop workload source (DESIGN.md §11): None keeps the closed-
    # loop scalar rates above; a `workload.OpenLoop` plan rides in cfg_c
    # as per-tick rate curves, every member's curves fitted to the
    # fleet-wide max plan length the way market traces are — one batched
    # program serves any mix of open- and closed-loop members.  `keypop`
    # (a `workload.ZipfianKeys`) skews the leader's write-key draws; None
    # keeps the uniform draw.
    arrivals: Optional[object] = None       # workload.OpenLoop
    keypop: Optional[object] = None         # workload.ZipfianKeys
    # revocation robustness (DESIGN.md §12): `warning_ticks` is the
    # advance-warning window W (cfg_c data — a W sweep is one program);
    # `bid_on_trace` re-derives trace revocations from replayed prices
    # vs the member's CURRENT bid; `bid_policy` (e.g.
    # `market.calibrate.HazardAwareBid`, eq=False so the frozen spec
    # stays hashable) recomputes the (S,) bids per epoch — a cfg_c row
    # write, never a recompile, but it does exclude the fleet from the
    # multi-epoch single-dispatch scan; `faults` is a deterministic
    # `market.chaos.FaultSchedule` riding in cfg_c like market traces
    warning_ticks: int = 0
    bid_on_trace: bool = False
    bid_policy: Optional[object] = None     # market.calibrate.HazardAwareBid
    faults: Optional[object] = None         # market.chaos.FaultSchedule
    # digest-tier observer count (DESIGN.md §13): sparse (O,)-shaped
    # slots that sync via anti-entropy under a staleness bound — a sweep
    # axis; members pad to the fleet-wide max O, so mixed observer
    # counts stay one compiled program.  `staleness_bound`/`ae_interval`
    # are cfg_c data (swaps never recompile).
    n_observers: int = 0
    staleness_bound: int = 16
    ae_interval: int = 4
    # flight recorder (DESIGN.md §14): `trace_on`/`trace_mask` are cfg_c
    # data (flips never recompile; a traced/untraced mix is one batched
    # program); `trace_capacity` is the per-member ring depth — members
    # pad to the fleet-wide max, the one compile-key trace knob.  The
    # mask is a length-NCLASS bool tuple (tuple, not array, so the
    # frozen spec stays hashable); None = all classes.
    trace_on: bool = False
    trace_mask: Optional[Tuple[bool, ...]] = None
    trace_capacity: int = trace_ring.DEFAULT_CAPACITY

    @property
    def manage(self) -> bool:
        return self.manage_resources and self.mode == "bwraft"


@dataclasses.dataclass(frozen=True)
class FleetShapes:
    B: int
    N: int   # nodes, padded to max over members
    S: int   # sites, padded
    L: int   # log window, padded
    K: int   # KV key space, padded
    T: int   # period_ticks (must be equal across members)
    O: int = 0   # digest-tier observer slots, padded (DESIGN.md §13)
    C: int = trace_ring.DEFAULT_CAPACITY  # trace ring depth (§14), padded


# (kind, shapes, shared scalars[, E]) -> CountingJit
_FLEET_EPOCH_CACHE: Dict = {}


def total_compile_count() -> int:
    """Compiled batched-epoch programs across every fleet shape and
    pipeline this process has run (robust to jax versions without the
    private jit cache introspection — see `runtime.CountingJit`)."""
    return sum(fn.cache_size() for fn in _FLEET_EPOCH_CACHE.values())


# per-member digest fields reduced to a per-group digest in-graph
# (DESIGN.md §9): everything a MultiRaftReport needs, pooled over the
# shards of each group by a segment sum (read_lat_max by a segment max)
_GROUP_SUM_KEYS = ("write_lat_hist", "read_lat_hist", "reads_arrived",
                   "writes_arrived", "reads_served", "read_lat_sum",
                   "cost_delta", "killed", "no_leader_ticks",
                   "leader_changes", "cross_arrived", "two_pc_prepares",
                   "two_pc_aborts", "trace_metrics")


# float digest leaves: summed (order-sensitive — the kernel accumulates
# in ascending member order, which is scatter-add order) + the one max
_GROUP_FLOAT_KEYS = ("read_lat_sum", "cost_delta")
_GROUP_INT_KEYS = tuple(k for k in _GROUP_SUM_KEYS
                        if k not in _GROUP_FLOAT_KEYS)


def _group_digest(digest: Dict, gids, n_groups: int,
                  backend: str = "xla") -> Dict:
    """Reduce per-member digest leaves (B, ...) to per-group leaves
    (G, ...).  Ungrouped members carry segment id G and are dropped by
    the segment ops — the masking rule that makes ragged group sizes and
    mixed grouped/ungrouped fleets shape-free (DESIGN.md §9).

    `backend="pallas"` packs the leaves into one (B, F) int32 matrix
    plus a (B, 3) float32 matrix and runs the single blockwise masked
    reduction of `kernels/group_digest` instead of the per-leaf
    `segment_sum`/`segment_max` pair — bit-identical, floats included
    (test invariant, DESIGN.md §8)."""
    if backend == "pallas":
        parts, widths = [], []
        for k in _GROUP_INT_KEYS:
            v = jnp.asarray(digest[k], jnp.int32)
            v = v[:, None] if v.ndim == 1 else v
            parts.append(v)
            widths.append(v.shape[1])
        int_mat = jnp.concatenate(parts, axis=1)
        flt_mat = jnp.stack([digest[k] for k in _GROUP_FLOAT_KEYS] +
                            [digest["read_lat_max"]], axis=1)
        g_int, g_sum, g_max = gd_ops.group_reduce(gids, int_mat, flt_mat,
                                                  n_groups=n_groups)
        out, off = {}, 0
        for k, w in zip(_GROUP_INT_KEYS, widths):
            leaf = g_int[:, off:off + w]
            out[k] = leaf[:, 0] if jnp.asarray(digest[k]).ndim == 1 \
                else leaf
            off += w
        for i, k in enumerate(_GROUP_FLOAT_KEYS):
            out[k] = g_sum[:, i]
        out["read_lat_max"] = g_max[:, len(_GROUP_FLOAT_KEYS)]
        return out
    out = {k: jax.ops.segment_sum(digest[k], gids, num_segments=n_groups)
           for k in _GROUP_SUM_KEYS}
    out["read_lat_max"] = jax.ops.segment_max(
        digest["read_lat_max"], gids, num_segments=n_groups)
    return out


def _vmapped_epoch(shapes: FleetShapes, shared: Dict, backend: str = "xla",
                   n_groups: int = 0):
    """One device epoch vmapped over the batch axis — the single body
    shared by the per-epoch and multi-epoch pipelines, so their dynamics
    can never diverge.  `backend` picks the tick hot-op implementation
    (DESIGN.md §8); the Pallas kernels batch under vmap like any op.
    With `n_groups > 0` the epoch takes a trailing (B,) segment-id
    argument and the digest gains a `"group"` subtree — the in-graph
    grouped reduction (DESIGN.md §9), fused into the same program so a
    sharded sweep stays one dispatch per epoch."""
    backend = resolve_backend(backend)

    def epoch(state, rngs, bstatic, cfg_c):
        def one_epoch(st, rng, bstat, cc):
            static = {**shared, **bstat}
            return device_epoch(st, static, cc, rng, shapes.T,
                                backend=backend)
        return jax.vmap(one_epoch)(state, rngs, bstatic, cfg_c)
    if n_groups == 0:
        return epoch

    def grouped_epoch(state, rngs, bstatic, cfg_c, gids):
        state, digest = epoch(state, rngs, bstatic, cfg_c)
        return state, dict(digest,
                           group=_group_digest(digest, gids, n_groups,
                                               backend=backend))
    return grouped_epoch


def _fleet_epoch_fn(shapes: FleetShapes, shared: Dict,
                    backend: str = "xla", n_groups: int = 0,
                    widths: Tuple[int, ...] = ()):
    """Digest pipeline: a jitted, vmapped, fully device-resident epoch —
    in-scan metric reduction, in-graph compaction, donated state buffers.
    Returns `(compacted_state, digest)` with digest leaves batched over B.
    One compile per (static shape, backend, group count, cfg_c array
    widths); `shared` (python ints) is closed over, batched statics,
    cfg_c, and the group segment ids are runtime arguments.  `widths`
    (the fleet's trace/arrival/fault-schedule tick widths, §10–§12) are
    jit-static shapes of the cfg_c arguments, so they belong in the
    cache key — two same-shape fleets at different widths are different
    programs and must not share one compile counter.  `backend` is
    resolved first (DESIGN.md §8), so `"auto"` and its per-platform
    resolution share one compiled program."""
    backend = resolve_backend(backend)
    key = ("device", shapes, tuple(sorted(shared.items())), backend,
           n_groups, widths)
    if key not in _FLEET_EPOCH_CACHE:
        _FLEET_EPOCH_CACHE[key] = CountingJit(
            _vmapped_epoch(shapes, shared, backend, n_groups),
            donate_argnums=(0,))
    return _FLEET_EPOCH_CACHE[key]


def _fleet_multi_epoch_fn(shapes: FleetShapes, shared: Dict, epochs: int,
                          backend: str = "xla", n_groups: int = 0,
                          widths: Tuple[int, ...] = ()):
    """Single-dispatch fast path: scan-of-scans over `epochs` device
    epochs (compaction in-graph between them) for fleets with no managing
    member.  Digest leaves come back stacked (E, B, ...) — group leaves,
    when present, (E, G, ...)."""
    backend = resolve_backend(backend)
    key = ("multi", shapes, tuple(sorted(shared.items())), epochs, backend,
           n_groups, widths)
    if key not in _FLEET_EPOCH_CACHE:
        epoch = _vmapped_epoch(shapes, shared, backend, n_groups)

        if n_groups == 0:
            def multi_fn(state, rngs, bstatic, cfg_c):
                def epoch_body(st, rngs_b):
                    return epoch(st, rngs_b, bstatic, cfg_c)
                return jax.lax.scan(epoch_body, state, rngs)
        else:
            def multi_fn(state, rngs, bstatic, cfg_c, gids):
                def epoch_body(st, rngs_b):
                    return epoch(st, rngs_b, bstatic, cfg_c, gids)
                return jax.lax.scan(epoch_body, state, rngs)
        _FLEET_EPOCH_CACHE[key] = CountingJit(multi_fn, donate_argnums=(0,))
    return _FLEET_EPOCH_CACHE[key]


def _fleet_epoch_fn_host(shapes: FleetShapes, shared: Dict,
                         widths: Tuple[int, ...] = ()):
    """The PR-1 reference path, op for op: the original tick formulations
    (`step.tick(reference=True)`), per-tick metrics stacked over T,
    compaction as a separate dispatch, no donation.  Kept for A/B
    benchmarking and the digest-equivalence tests (DESIGN.md §7.1)."""
    key = ("host", shapes, tuple(sorted(shared.items())), widths)
    if key not in _FLEET_EPOCH_CACHE:
        def epoch_fn(state, rngs, bstatic, cfg_c):
            def one_epoch(st, rng, bstat, cc):
                static = {**shared, **bstat}

                def body(carry, r):
                    s, m = step_mod.tick(carry, static, cc, r,
                                         reference=True)
                    return s, m
                ticks = jax.random.split(rng, shapes.T)
                return jax.lax.scan(body, st, ticks)
            return jax.vmap(one_epoch)(state, rngs, bstatic, cfg_c)
        _FLEET_EPOCH_CACHE[key] = CountingJit(epoch_fn)
    return _FLEET_EPOCH_CACHE[key]


class _Member:
    """Host-side bookkeeping for one fleet slot.  `trace_ticks` is the
    fleet-wide market-trace width every member's cfg_c arrays share
    (DESIGN.md §10); `arrival_ticks` the fleet-wide arrival-curve width
    (DESIGN.md §11)."""

    def __init__(self, spec: MemberSpec, shapes: FleetShapes,
                 trace_ticks: int = 1, arrival_ticks: int = 1,
                 fault_ticks: int = 1):
        assert spec.mode in ("bwraft", "raft")
        cfg = spec.cfg
        if spec.budget_per_period is not None:
            cfg = dataclasses.replace(
                cfg, budget_per_period=spec.budget_per_period)
        self.spec = spec
        self.cfg = cfg
        self.pads = {
            "pad_nodes": shapes.N - cfg.max_nodes,
            "pad_sites": shapes.S - cfg.num_sites,
            "pad_log": shapes.L - cfg.max_log,
            "pad_keys": shapes.K - cfg.key_space,
            "pad_observers": shapes.O - spec.n_observers,
        }
        assert all(p >= 0 for p in self.pads.values()), \
            f"member {cfg.name} exceeds fleet shapes {shapes}"
        self.static = state_mod.build_static(
            cfg, pad_nodes=self.pads["pad_nodes"],
            pad_sites=self.pads["pad_sites"],
            n_obs_digest=spec.n_observers,
            pad_obs=self.pads["pad_observers"],
            trace_capacity=shapes.C)
        self.state0 = state_mod.init_state(
            cfg, self.static, pad_log=self.pads["pad_log"],
            pad_keys=self.pads["pad_keys"])
        if spec.two_pc_ticks is not None:
            two_pc = spec.two_pc_ticks
        elif spec.group_id >= 0:
            from repro.core.multiraft import two_pc_penalty
            two_pc = two_pc_penalty(cfg)
        else:
            two_pc = 0
        self.cfg_c = make_cfg_arrays(
            cfg, write_rate=spec.write_rate, read_rate=spec.read_rate,
            phi=spec.phi, pad_nodes=self.pads["pad_nodes"],
            pad_sites=self.pads["pad_sites"],
            pad_keys=self.pads["pad_keys"],
            spot_price_vol=spec.spot_price_vol,
            cross_shard_frac=spec.cross_shard_frac, two_pc_ticks=two_pc,
            market=spec.market, trace=spec.trace, trace_ticks=trace_ticks,
            arrivals=spec.arrivals, arrival_ticks=arrival_ticks,
            keypop=spec.keypop,
            warning_ticks=spec.warning_ticks,
            bid_on_trace=spec.bid_on_trace,
            faults=spec.faults, fault_ticks=fault_ticks,
            n_observers=spec.n_observers,
            pad_observers=self.pads["pad_observers"],
            staleness_bound=spec.staleness_bound,
            ae_interval=spec.ae_interval,
            trace_on=spec.trace_on, trace_mask=spec.trace_mask)
        self.rng = jax.random.PRNGKey(spec.seed)
        self.controller = ClusterController(cfg, self.static,
                                            seed=spec.seed)
        if spec.prelease is not None:
            role, alive, sec_of, obs_of = self.controller.lease(
                np.asarray(self.state0["role"]),
                np.asarray(self.state0["alive"]),
                max(spec.prelease[0], 0), max(spec.prelease[1], 0))
            self.state0 = dict(self.state0,
                               role=jnp.asarray(role),
                               alive=jnp.asarray(alive),
                               sec_of=jnp.asarray(sec_of),
                               obs_of=jnp.asarray(obs_of))
        self.manage = spec.manage
        self.epoch = 0
        self.reports: List[EpochReport] = []


class FleetSim:
    """B independent clusters stepped in one jitted, vmapped program.

    Per-member dynamics are identical to a sequential `BWRaftSim` with the
    same padded shapes and seed; the control plane runs per member on the
    host between epochs.  `pipeline` selects the epoch implementation:
    `"device"` (default) is the digest path — donated state, in-graph
    compaction, O(digest) device→host traffic — `"host"` the PR-1
    full-marshalling reference (DESIGN.md §7.1).  `backend` selects the
    tick hot-op implementation on the device pipeline: `"xla"`
    (default), `"pallas"` (the fused kernel families, DESIGN.md §8), or
    `"auto"` (pallas on TPU, xla elsewhere — resolved at construction,
    `self.backend` holds the resolution) — trajectories are
    bit-identical either way (test invariant).
    """

    def __init__(self, specs: Sequence[MemberSpec], *,
                 pipeline: str = "device", backend: str = "xla"):
        assert pipeline in ("device", "host"), pipeline
        backend = resolve_backend(backend)
        assert backend == "xla" or pipeline == "device", \
            "the pallas backend applies to the device pipeline only " \
            "(the host pipeline is the frozen PR-1 reference)"
        self.backend = backend
        specs = list(specs)
        assert specs, "fleet needs at least one member"
        periods = {s.cfg.period_ticks for s in specs}
        assert len(periods) == 1, \
            f"all members must share period_ticks, got {periods}"
        self.pipeline = pipeline
        self.shapes = FleetShapes(
            B=len(specs),
            N=max(s.cfg.max_nodes for s in specs),
            S=max(s.cfg.num_sites for s in specs),
            L=max(s.cfg.max_log for s in specs),
            K=max(s.cfg.key_space for s in specs),
            T=periods.pop(),
            O=max(s.n_observers for s in specs),
            C=max(s.trace_capacity for s in specs),
        )
        # fleet-shared market-trace width (DESIGN.md §10): every member's
        # cfg_c trace arrays stack to (B, S, Tt); shorter traces time-wrap
        # (`MarketTrace.fit_to`, matching the in-step modulo lookup) and
        # process members carry inert placeholders of the same width
        self.trace_ticks = max(
            [s.trace.ticks for s in specs if s.trace is not None],
            default=1)
        # fleet-shared arrival-curve width (DESIGN.md §11): every member's
        # cfg_c rate curves stack to (B, Ta); shorter plans time-wrap
        # (`OpenLoop.fit_to`, matching the in-step modulo lookup) and
        # closed-loop members carry inert zero curves of the same width
        self.arrival_ticks = max(
            [s.arrivals.ticks for s in specs if s.arrivals is not None],
            default=1)
        # fleet-shared fault-schedule width (DESIGN.md §12): members'
        # (N, Tf) kill schedules stack like market traces; schedule-free
        # members carry inert all-False placeholders of the same width
        self.fault_ticks = max(
            [s.faults.ticks for s in specs if s.faults is not None],
            default=1)
        self.members = [_Member(s, self.shapes, self.trace_ticks,
                                self.arrival_ticks, self.fault_ticks)
                        for s in specs]

        # ---- shard groups (DESIGN.md §9) -----------------------------
        # members with group_id >= 0 are Multi-Raft shards; groups may be
        # ragged (different sizes) and interleave with ungrouped members.
        order = sorted({s.group_id for s in specs if s.group_id >= 0})
        self.groups: Dict[int, List[int]] = {
            g: [i for i, s in enumerate(specs) if s.group_id == g]
            for g in order}
        self.n_groups = len(order)
        self._group_chi: Dict[int, float] = {}
        for g, idxs in self.groups.items():
            gspecs = [specs[i] for i in idxs]
            assert all(s.mode == "raft" for s in gspecs), \
                f"group {g}: Multi-Raft shards must be mode='raft'"
            assert all(not s.manage for s in gspecs), \
                f"group {g}: shard members must not manage resources"
            sizes = {s.shards_per_group for s in gspecs}
            assert sizes == {len(idxs)}, \
                f"group {g}: declared shards_per_group {sizes} != actual " \
                f"member count {len(idxs)} (ragged-group guard)"
            chis = {s.cross_shard_frac for s in gspecs}
            assert len(chis) == 1, \
                f"group {g}: shards disagree on cross_shard_frac {chis}"
            self._group_chi[g] = chis.pop()
            taxes = {int(self.members[i].cfg_c["two_pc_ticks"])
                     for i in idxs}
            assert len(taxes) == 1, \
                f"group {g}: shards disagree on two_pc_ticks {taxes} — " \
                f"one 2PC charge per system (DESIGN.md §9)"
        # segment ids: group slot in `order`, or n_groups for ungrouped
        # members (dropped by the in-graph segment reduction)
        self._gids = jnp.asarray(
            [order.index(s.group_id) if s.group_id >= 0 else self.n_groups
             for s in specs], jnp.int32)
        self._group_reports: Dict[int, List] = {g: [] for g in order}

        self._shared = {k: self.members[0].static[k]
                        for k in _SHARED_STATIC_KEYS}
        for m in self.members[1:]:
            for k in _SHARED_STATIC_KEYS:
                assert m.static[k] == self._shared[k], \
                    f"member {m.cfg.name} disagrees on static {k}"

        self._bstatic = {
            k: (jnp.asarray([m.static[k] for m in self.members], jnp.int32)
                if k == "majority" else                      # scalar per member
                jnp.stack([jnp.asarray(m.static[k]) for m in self.members]))
            for k in _BATCHED_STATIC_KEYS
        }
        self._state = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[m.state0 for m in self.members])
        self._cfg_c = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[m.cfg_c for m in self.members])
        assert pipeline == "device" or self.n_groups == 0, \
            "shard groups need the digest pipeline (the host pipeline " \
            "is the frozen PR-1 reference and has no group reduction)"
        widths = (self.trace_ticks, self.arrival_ticks, self.fault_ticks)
        self._epoch_fn = (_fleet_epoch_fn(self.shapes, self._shared,
                                          backend, self.n_groups, widths)
                          if pipeline == "device" else
                          _fleet_epoch_fn_host(self.shapes, self._shared,
                                               widths))
        # cumulative device->host bytes fetched for report building
        # (digest leaves on the device path, full state + T-stacked
        # metrics on the host path) — perf_fleet.py reads the deltas
        self.d2h_bytes = 0
        # most recent epoch's per-member digest (numpy, leading axis =
        # member; group subtree popped off separately) — raw-histogram
        # access for goodput-under-deadline (DESIGN.md §11).  Digest
        # pipeline only; stays None on the host path.
        self.last_digest: Optional[Dict] = None
        self.last_group_digest: Optional[Dict] = None
        # flight recorder (DESIGN.md §14): one incremental ring reader
        # per member; `run_epoch` auto-drains whenever any member's
        # trace_on is set, appending typed events to `trace_events`
        self._trace_cursors = [trace_export.DrainCursor(member=i)
                               for i in range(len(self.members))]
        self.trace_events: List[trace_export.TraceEvent] = []

    # ------------------------------------------------------------------ #
    @classmethod
    def from_sweep(cls, configs, axes: Optional[Dict] = None,
                   pipeline: str = "device", backend: str = "xla",
                   **defaults) -> "FleetSim":
        """Cross-product sweep constructor.

        `configs`: one ClusterConfig or a sequence of them.  `axes`: dict
        mapping a MemberSpec field name (write_rate / read_rate / phi /
        seed / mode / spot_price_vol / budget_per_period / ...) to the
        values to sweep; the member list is configs x product(axes).
        `defaults` fill the remaining MemberSpec fields.  `backend`
        accepts `"auto"` (pallas on TPU, xla elsewhere — DESIGN.md §8);
        the constructed fleet's `.backend` is the resolution.
        """
        if isinstance(configs, ClusterConfig):
            configs = [configs]
        axes = dict(axes or {})
        for name in axes:
            assert name in _SWEEP_AXES, \
                f"unknown sweep axis {name!r}; valid: {_SWEEP_AXES}"
        names = list(axes.keys())
        specs = []
        for cfg in configs:
            for combo in itertools.product(*axes.values()):
                specs.append(MemberSpec(cfg=cfg, **defaults,
                                        **dict(zip(names, combo))))
        return cls(specs, pipeline=pipeline, backend=backend)

    @classmethod
    def sweep(cls, configs, axes: Optional[Dict] = None, *,
              epochs: int = 5, **defaults) -> List[List[EpochReport]]:
        """One-call sweep: build the fleet and run it.  Returns reports
        indexed [member][epoch]; member order is configs-major, then the
        cross product of `axes` in insertion order."""
        return cls.from_sweep(configs, axes, **defaults).run(epochs)

    # ------------------------------------------------------------------ #
    @property
    def compile_count(self) -> int:
        """How many programs the underlying per-epoch function has
        compiled (1 after any number of epochs/sweeps at this static
        shape); the multi-epoch fast path caches separately — see
        `total_compile_count`."""
        return self._epoch_fn.cache_size()

    def pads_for(self, i: int) -> Dict[str, int]:
        """Padding a solo BWRaftSim needs to reproduce member i exactly."""
        return dict(self.members[i].pads)

    @property
    def state(self) -> Dict:
        """Batched state pytree (leading axis = member)."""
        return self._state

    def _split_epoch_rngs(self) -> jnp.ndarray:
        subs = []
        for m in self.members:
            m.rng, sub = jax.random.split(m.rng)
            subs.append(sub)
        return jnp.stack(subs)

    # ------------------------------------------------------------------ #
    def _epoch_args(self) -> Tuple:
        return ((self._gids,) if self.n_groups else ())

    def _append_group_reports(self, gdg: Dict) -> None:
        """Distill one epoch's per-group digest rows (numpy leaves,
        leading axis = group slot) into MultiRaftReports."""
        from repro.core.multiraft import report_from_group_digest
        for slot, g in enumerate(sorted(self.groups)):
            rows = {k: v[slot] for k, v in gdg.items()}
            self._group_reports[g].append(report_from_group_digest(
                len(self._group_reports[g]), rows, self._group_chi[g]))

    @property
    def group_reports(self) -> Dict[int, List]:
        """Per-group `MultiRaftReport` history, keyed by the members'
        `group_id` (DESIGN.md §9).  Digest pipeline only."""
        return {g: list(reps) for g, reps in self._group_reports.items()}

    def run_epoch(self) -> List[EpochReport]:
        if self.pipeline == "host":
            return self._run_epoch_host()
        rngs = self._split_epoch_rngs()
        self._state, digest = self._epoch_fn(self._state, rngs,
                                             self._bstatic, self._cfg_c,
                                             *self._epoch_args())
        dg = jax.tree.map(np.asarray, digest)
        self.d2h_bytes += pytree_nbytes(dg)
        if self.n_groups:
            self.last_group_digest = dg.pop("group")
            self._append_group_reports(self.last_group_digest)
        self.last_digest = dg
        if bool(np.asarray(self._cfg_c["trace_on"]).any()):
            self.drain_trace()

        managed_rows: List[int] = []
        managed_vals: List[Tuple] = []
        out = []
        for i, m in enumerate(self.members):
            dgi = {k: v[i] for k, v in dg.items()}
            rep = report_from_digest(m.epoch, dgi)
            if m.manage:
                dec = m.controller.decide(
                    rep,
                    float(np.mean(dgi["spot_price"][:m.cfg.num_sites])))
                rep.decision = dec
                managed_rows.append(i)
                # warned census (DESIGN.md §12): replace warned
                # secretaries/observers on top of Algorithm 1's delta
                # and drop warned secretaries from the wiring — inert
                # (exact pre-§12 lease) when no warnings are raised
                warned = np.asarray(dgi["warned"])
                roles = np.asarray(dgi["role"])
                managed_vals.append(m.controller.lease(
                    dgi["role"], dgi["alive"],
                    max(dec.dk_s, 0) + int(((roles == state_mod.SECRETARY)
                                            & warned).sum()),
                    max(dec.dk_o, 0) + int(((roles == state_mod.OBSERVER)
                                            & warned).sum()),
                    warned=warned))
            m.controller.end_epoch(rep)
            m.epoch += 1
            m.reports.append(rep)
            out.append(rep)
        self._apply_bid_policies()

        if managed_rows:
            # write back ONLY the managed members' role/wiring rows — the
            # rest of the state never leaves (or re-enters) the device
            idx = jnp.asarray(managed_rows, jnp.int32)
            upd = {name: jnp.asarray(np.stack([v[j] for v in managed_vals]))
                   for j, name in enumerate(("role", "alive", "sec_of",
                                             "obs_of"))}
            self._state = dict(
                self._state,
                **{name: self._state[name].at[idx].set(arr)
                   for name, arr in upd.items()})
        return out

    def _run_epoch_host(self) -> List[EpochReport]:
        """PR-1 reference epoch: full state + per-tick metric stacks are
        materialized to host, the report is built from raw entry
        timelines, and compaction is a separate post-hoc dispatch."""
        rngs = self._split_epoch_rngs()
        cost_before = np.asarray(self._state["cost_accrued"])
        # pre-epoch leader terms, so build_report's np.diff counts a
        # leader change on the FIRST tick of the epoch too — the host
        # twin of the digest accumulator's seeded prev_leader_term
        # (DESIGN.md §14, first-tick blindness fix)
        role0 = np.asarray(self._state["role"])
        alive0 = np.asarray(self._state["alive"])
        term0 = np.asarray(self._state["term"])
        self.d2h_bytes += role0.nbytes + alive0.nbytes + term0.nbytes
        ids = np.arange(role0.shape[1])
        lid0 = np.where((role0 == state_mod.LEADER) & alive0,
                        ids[None, :], -1).max(axis=1)
        lt0 = np.where(lid0 >= 0,
                       term0[np.arange(role0.shape[0]),
                             np.maximum(lid0, 0)], -1)

        self._state, ms = self._epoch_fn(self._state, rngs, self._bstatic,
                                         self._cfg_c)
        st_np = jax.tree.map(np.asarray, self._state)
        ms_np = jax.tree.map(np.asarray, ms)
        self.d2h_bytes += (pytree_nbytes(st_np) + pytree_nbytes(ms_np) +
                           cost_before.nbytes)

        role = st_np["role"].copy()
        alive = st_np["alive"].copy()
        sec_of = st_np["sec_of"].copy()
        obs_of = st_np["obs_of"].copy()

        out = []
        for i, m in enumerate(self.members):
            sti = {k: v[i] for k, v in st_np.items()}
            msi = {k: v[i] for k, v in ms_np.items()}
            rep = build_report(m.epoch, sti, msi, float(cost_before[i]),
                               leader_term0=int(lt0[i]))
            if m.manage:
                dec = m.controller.decide(
                    rep,
                    float(np.mean(sti["spot_price"][:m.cfg.num_sites])))
                rep.decision = dec
                # same warned-aware lease as the digest path (§12), so
                # the two pipelines stay decision-equal under warnings
                warned = sti["alive"] & (sti["warn_timer"] >= 0)
                role[i], alive[i], sec_of[i], obs_of[i] = m.controller.lease(
                    role[i], alive[i],
                    max(dec.dk_s, 0) + int(((role[i] == state_mod.SECRETARY)
                                            & warned).sum()),
                    max(dec.dk_o, 0) + int(((role[i] == state_mod.OBSERVER)
                                            & warned).sum()),
                    warned=warned)
            m.controller.end_epoch(rep)
            m.epoch += 1
            m.reports.append(rep)
            out.append(rep)
        self._apply_bid_policies()

        self._state = compact_state(dict(
            self._state,
            role=jnp.asarray(role), alive=jnp.asarray(alive),
            sec_of=jnp.asarray(sec_of), obs_of=jnp.asarray(obs_of)))
        if bool(np.asarray(self._cfg_c["trace_on"]).any()):
            self.drain_trace()
        return out

    # ------------------------------------------------------------------ #
    def set_trace(self, on: Optional[bool] = None,
                  mask: Optional[Sequence[bool]] = None,
                  members: Optional[Sequence[int]] = None) -> None:
        """Flip the flight recorder for `members` (default: all) — a
        cfg_c row write at a fixed shape, so toggling mid-run NEVER
        recompiles the batched program (DESIGN.md §14)."""
        idx = jnp.asarray(
            list(range(len(self.members))) if members is None
            else list(members), jnp.int32)
        if on is not None:
            self._cfg_c["trace_on"] = \
                self._cfg_c["trace_on"].at[idx].set(bool(on))
        if mask is not None:
            m = jnp.asarray(mask, bool)
            assert m.shape == (trace_ring.NCLASS,), \
                f"trace mask must be ({trace_ring.NCLASS},), got {m.shape}"
            self._cfg_c["trace_mask"] = \
                self._cfg_c["trace_mask"].at[idx].set(m)

    def drain_trace(self) -> List[trace_export.TraceEvent]:
        """One D2H fetch of every member's ring + cursors; returns (and
        appends to `trace_events`) the events since the last drain, in
        per-member emission order (DESIGN.md §14)."""
        ev = np.asarray(self._state["trace_ev"])
        pos = np.asarray(self._state["trace_pos"])
        emit = np.asarray(self._state["trace_emit"])
        self.d2h_bytes += ev.nbytes + pos.nbytes + emit.nbytes
        new: List[trace_export.TraceEvent] = []
        for i, cur in enumerate(self._trace_cursors):
            new.extend(cur.drain({"trace_ev": ev[i], "trace_pos": pos[i],
                                  "trace_emit": emit[i]}))
        self.trace_events.extend(new)
        return new

    @property
    def events_dropped(self) -> List[Dict[str, int]]:
        """Exact per-member, per-class ring-overwrite counts."""
        return [c.dropped_by_class() for c in self._trace_cursors]

    def _apply_bid_policies(self) -> None:
        """Per-epoch hazard-aware bid updates (DESIGN.md §12): recompute
        each policy member's (S,) bids on the host and write ONLY those
        members' `spot_bid` cfg_c rows back.  cfg_c is jit-argument data
        at a fixed shape, so the swap never recompiles (the market-side
        twin of the manage write-back above)."""
        rows, vals = [], []
        for i, m in enumerate(self.members):
            if m.spec.bid_policy is None:
                continue
            rows.append(i)
            vals.append(np.asarray(m.spec.bid_policy.update(
                predictor=m.controller.predictor, trace=m.spec.trace,
                end_tick=m.epoch * m.cfg.period_ticks,
                sites=self.shapes.S), np.float32))
        if rows:
            idx = jnp.asarray(rows, jnp.int32)
            self._cfg_c["spot_bid"] = self._cfg_c["spot_bid"].at[idx].set(
                jnp.asarray(np.stack(vals), jnp.float32))

    def lease_fixed(self, want_sec: int, want_obs: int) -> None:
        """One-shot fixed-role wiring for every member: lease/wire
        `want_sec` secretaries and `want_obs` observers on the host and
        write the four (B, N) role/wiring arrays back.  The fixed-role
        recipe for sweep grids (fig12/fig13): run one epoch so leadership
        stabilizes (the FIRST election stops preleased secretaries —
        paper Step 1), wire the complement once, then run the rest of the
        sweep as a single dispatch.  O(B·N) transfer, once per run."""
        role = np.asarray(self._state["role"]).copy()
        alive = np.asarray(self._state["alive"]).copy()
        sec_of = np.asarray(self._state["sec_of"]).copy()
        obs_of = np.asarray(self._state["obs_of"]).copy()
        for i, m in enumerate(self.members):
            role[i], alive[i], sec_of[i], obs_of[i] = m.controller.lease(
                role[i], alive[i], max(want_sec, 0), max(want_obs, 0))
        self._state = dict(self._state,
                           role=jnp.asarray(role), alive=jnp.asarray(alive),
                           sec_of=jnp.asarray(sec_of),
                           obs_of=jnp.asarray(obs_of))

    # ------------------------------------------------------------------ #
    @property
    def single_dispatch_eligible(self) -> bool:
        """True when `run(E)` can collapse into one device dispatch: the
        digest pipeline with no member running the per-epoch control
        plane (plain-Raft baselines, fixed-role `prelease` sweeps) and
        no per-epoch bid policy (bid updates are host writes between
        epochs, DESIGN.md §12)."""
        return (self.pipeline == "device" and
                not any(m.manage for m in self.members) and
                not any(m.spec.bid_policy is not None
                        for m in self.members))

    def _run_scan(self, epochs: int) -> None:
        """The multi-epoch fast path: ONE dispatch scans over `epochs`
        device epochs (in-graph compaction between them) and returns the
        digests stacked (E, B, ...)."""
        fn = _fleet_multi_epoch_fn(self.shapes, self._shared, epochs,
                                   self.backend, self.n_groups,
                                   (self.trace_ticks, self.arrival_ticks,
                                    self.fault_ticks))
        # identical split order to the epoch-by-epoch path, so the two are
        # trajectory-equal at the same seeds (tests/test_fleet.py)
        rngs = jnp.stack([self._split_epoch_rngs() for _ in range(epochs)])
        self._state, digests = fn(self._state, rngs, self._bstatic,
                                  self._cfg_c, *self._epoch_args())
        dg = jax.tree.map(np.asarray, digests)
        self.d2h_bytes += pytree_nbytes(dg)
        gdg = dg.pop("group") if self.n_groups else None
        self.last_digest = {k: v[-1] for k, v in dg.items()}
        if gdg is not None:
            self.last_group_digest = {k: v[-1] for k, v in gdg.items()}
        for e in range(epochs):
            if gdg is not None:
                self._append_group_reports({k: v[e] for k, v in
                                            gdg.items()})
            for i, m in enumerate(self.members):
                rep = report_from_digest(
                    m.epoch, {k: v[e, i] for k, v in dg.items()})
                m.controller.end_epoch(rep)
                m.epoch += 1
                m.reports.append(rep)
        if bool(np.asarray(self._cfg_c["trace_on"]).any()):
            self.drain_trace()

    def run(self, epochs: int, *,
            single_dispatch: Optional[bool] = None
            ) -> List[List[EpochReport]]:
        """Run `epochs` epochs; returns the reports of *this call* indexed
        [member][epoch] (matching BWRaftSim.run; the full history stays on
        `self.reports`).  `single_dispatch=None` auto-selects the
        multi-epoch scan whenever it is eligible; pass False to force the
        epoch-by-epoch loop (A/B testing), True to assert eligibility."""
        if single_dispatch is None:
            single_dispatch = epochs > 1 and self.single_dispatch_eligible
        if single_dispatch:
            assert self.single_dispatch_eligible, \
                "single-dispatch run needs pipeline='device' and no " \
                "managing member"
        start = len(self.members[0].reports)
        if single_dispatch:
            self._run_scan(epochs)
        else:
            for _ in range(epochs):
                self.run_epoch()
        return [list(m.reports[start:]) for m in self.members]

    @property
    def reports(self) -> List[List[EpochReport]]:
        return [list(m.reports) for m in self.members]
