"""Multi-Raft baseline: key-space sharding over S independent Raft groups.

The state-of-the-art scale-out the paper compares against (§2.1): each
shard is a full Raft over its *own* on-demand node set (every scale-out
step replicates the entire footprint — the cost problem), with 2-phase
commit between shard leaders for cross-shard writes.  2PC is modeled as a
latency/capacity tax (DESIGN.md §6): a cross-shard write consumes commit
capacity in both shards and pays two extra inter-site commit rounds.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.cluster_config import ClusterConfig
from repro.core.runtime import BWRaftSim, EpochReport


@dataclasses.dataclass
class MultiRaftReport:
    epoch: int
    writes_committed: int
    writes_arrived: int
    reads_served: int
    reads_arrived: int
    write_lat_mean: float
    write_lat_p95: float
    write_lat_p99: float
    read_lat_mean: float
    cost: float

    @property
    def goodput(self) -> float:
        return self.reads_served + self.writes_committed


class MultiRaftSim:
    """S independent Raft shards + 2PC cross-shard write model."""

    def __init__(self, cfg: ClusterConfig, *, shards: int = 2,
                 write_rate: float = 8.0, read_rate: float = 32.0,
                 cross_shard_frac: float = 0.1, seed: int = 0):
        self.cfg = cfg
        self.shards = shards
        self.chi = cross_shard_frac
        # cross-shard writes execute in both shards: effective per-shard
        # write rate includes the duplicated prepares
        w_eff = write_rate * (1 + cross_shard_frac) / shards
        self.sims = [
            BWRaftSim(cfg, mode="raft", write_rate=w_eff,
                      read_rate=read_rate / shards, seed=seed + 17 * i,
                      manage_resources=False)
            for i in range(shards)
        ]
        # 2PC penalty: prepare + commit round between shard leaders
        rtts = [s.rtt_inter for s in cfg.sites]
        self.two_pc_penalty = 2 * int(np.mean(rtts))
        self.epoch = 0
        self.np_rng = np.random.default_rng(seed + 999)

    def run_epoch(self) -> MultiRaftReport:
        reps: List[EpochReport] = [s.run_epoch() for s in self.sims]
        lat_mean = float(np.nanmean([r.write_lat_mean for r in reps]))
        lat_p95 = float(np.nanmax([r.write_lat_p95 for r in reps]))
        lat_p99 = float(np.nanmax([r.write_lat_p99 for r in reps]))
        # cross-shard writes pay the 2PC penalty; the blended mean/p95 shift
        chi = self.chi
        lat_mean = lat_mean + chi * self.two_pc_penalty
        lat_p95 = lat_p95 + self.two_pc_penalty       # tail is cross-shard
        lat_p99 = lat_p99 + self.two_pc_penalty
        rep = MultiRaftReport(
            epoch=self.epoch,
            writes_committed=int(sum(r.writes_committed for r in reps) /
                                 (1 + chi)),
            writes_arrived=int(sum(r.writes_arrived for r in reps) /
                               (1 + chi)),
            reads_served=sum(r.reads_served for r in reps),
            reads_arrived=sum(r.reads_arrived for r in reps),
            write_lat_mean=lat_mean, write_lat_p95=lat_p95,
            write_lat_p99=lat_p99,
            read_lat_mean=float(np.mean([r.read_lat_mean for r in reps])),
            cost=sum(r.cost for r in reps),
        )
        self.epoch += 1
        return rep

    def run(self, epochs: int) -> List[MultiRaftReport]:
        return [self.run_epoch() for _ in range(epochs)]
