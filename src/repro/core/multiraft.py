"""Multi-Raft baseline: key-space sharding over S independent Raft groups.

The state-of-the-art scale-out the paper compares against (§2.1): each
shard is a full Raft over its *own* on-demand node set (every scale-out
step replicates the entire footprint — the cost problem), with 2-phase
commit between shard leaders for cross-shard writes.

Two engines share the shard model (DESIGN.md §6.3 and §9):

- **Grouped fleet (default, DESIGN.md §9).**  `MultiRaftSim` is a thin
  wrapper over a `fleet.FleetSim` whose members carry a shard-group
  identity: all S shards advance in ONE compiled, vmapped program, the
  2PC coupling runs in-graph — a cross-shard write samples a prepare in
  its home shard, holds commit capacity in the partner shard (the
  duplicated-prepare rate inflation of `shard_workload`), and pays the
  two inter-site rounds as *measured* per-request latency in the
  unit-bin digest histogram — and the per-shard digests are reduced to
  one group digest on device.  Multi-Raft p95/p99 therefore come out of
  the same digest machinery as BW-Raft.
- **Sequential host reference (frozen).**  `engine="sequential"` steps
  one `BWRaftSim` (mode="raft") per shard on the host and blends the
  reports with `aggregate_shards`, which applies the 2PC tax post hoc —
  the pre-group behavior, kept as the equivalence reference
  (DESIGN.md §9 invariant: the grouped engine matches it exactly on
  committed/arrived counts and to within one histogram bin on latency
  means; `tests/test_multiraft.py`).

`shard_specs` remains the batched entry point for joining this
Multi-Raft instance to a larger fleet (e.g. next to the BW-Raft and
plain-Raft members it is compared against, `benchmarks/common`); with
the default `group_id >= 0` the fleet builds the group digest and the
`MultiRaftReport`s itself (`FleetSim.group_reports`).
`aggregate_shards` is reference-only: it backs the sequential engine and
the NaN-policy regression tests.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster_config import ClusterConfig
from repro.core.runtime import BWRaftSim, EpochReport, hist_stats
from repro.trace import metrics as trace_metrics


@dataclasses.dataclass
class MultiRaftReport:
    epoch: int
    writes_committed: int
    writes_arrived: int
    reads_served: int
    reads_arrived: int
    write_lat_mean: float
    write_lat_p95: float
    write_lat_p99: float
    read_lat_mean: float
    cost: float
    # read-path tails from the group's pooled read histogram (grouped
    # engine only, DESIGN.md §11); NaN on the sequential reference
    read_lat_p95: float = float("nan")
    read_lat_p99: float = float("nan")
    # 2PC census (grouped engine only — measured in-graph, DESIGN.md §9):
    # cross-shard coordinator arrivals, prepares sampled by coordinators,
    # and prepares whose commit never landed inside the epoch (the
    # partner shard's held capacity released uncommitted)
    cross_arrived: int = 0
    two_pc_prepares: int = 0
    two_pc_aborts: int = 0
    # group-pooled flight-recorder counters (DESIGN.md §14): the shards'
    # `trace.metrics` registries summed in the same in-graph group
    # reduction as the digest leaves; None on the sequential reference
    metrics: Optional[Dict[str, int]] = None

    @property
    def goodput(self) -> float:
        return self.reads_served + self.writes_committed


def shard_workload(write_rate: float, read_rate: float, shards: int,
                   cross_shard_frac: float) -> tuple[float, float]:
    """Per-shard effective rates: cross-shard writes execute in both
    shards, so the duplicated prepares inflate the write rate — this is
    the "hold commit capacity in the partner shard" half of the 2PC
    coupling (DESIGN.md §9): `w_eff * shards == write_rate * (1 + chi)`,
    a pinned invariant (`tests/test_multiraft.py`)."""
    w_eff = write_rate * (1 + cross_shard_frac) / shards
    return w_eff, read_rate / shards


def two_pc_penalty(cfg: ClusterConfig) -> int:
    """2PC tax in ticks: prepare + commit round between shard leaders."""
    rtts = [s.rtt_inter for s in cfg.sites]
    return 2 * int(np.mean(rtts))


def shard_specs(cfg: ClusterConfig, *, shards: int = 2,
                write_rate: float = 8.0, read_rate: float = 32.0,
                cross_shard_frac: float = 0.1, seed: int = 0,
                group_id: int = 0, arrivals=None, keypop=None,
                n_observers: int = 0, staleness_bound: int = 16,
                ae_interval: int = 4) -> List:
    """The batched entry point: this Multi-Raft instance as `shards`
    fleet members (mode="raft", unmanaged) for a single vmapped program.

    With `group_id >= 0` (default) the members carry the shard-group
    identity of DESIGN.md §9: the fleet couples them with the in-graph
    2PC step and reduces their digests to per-group `MultiRaftReport`s
    (`FleetSim.group_reports[group_id]`).  Pass `group_id=-1` for the
    pre-group behavior (independent members; blend the per-shard
    EpochReports with the reference-only `aggregate_shards`).

    `arrivals` (a system-wide `workload.OpenLoop` plan) is divided over
    the shards with the same `shard_workload` factors as the scalar
    rates — each shard replays the plan's shape at 1/shards intensity,
    writes inflated by (1 + chi) for the duplicated prepares
    (DESIGN.md §11); `keypop` passes through to every shard.

    `n_observers`/`staleness_bound`/`ae_interval` attach a digest-tier
    observer rack (DESIGN.md §13) to *each* shard member — shards scale
    their read fan-out independently, so the tier rides per-member."""
    from repro.core.fleet import MemberSpec  # deferred: fleet imports runtime
    w_eff, r_eff = shard_workload(write_rate, read_rate, shards,
                                  cross_shard_frac)
    shard_plan = (arrivals.scaled((1 + cross_shard_frac) / shards,
                                  1.0 / shards)
                  if arrivals is not None else None)
    grouped = group_id >= 0
    return [MemberSpec(cfg=cfg, mode="raft", write_rate=w_eff,
                       read_rate=r_eff, seed=seed + 17 * i,
                       manage_resources=False,
                       arrivals=shard_plan, keypop=keypop,
                       n_observers=n_observers,
                       staleness_bound=staleness_bound,
                       ae_interval=ae_interval,
                       group_id=group_id,
                       shards_per_group=shards if grouped else 1,
                       cross_shard_frac=cross_shard_frac if grouped
                       else 0.0)
            for i in range(shards)]


def _nan_blend(values, reduce) -> float:
    """Uniform NaN policy for blending per-shard latency stats: NaN rows
    (a shard that committed nothing) are excluded; all-NaN blends to NaN
    without numpy's all-NaN RuntimeWarning."""
    arr = np.asarray(values, dtype=float)
    if np.isnan(arr).all():
        return float("nan")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return float(reduce(arr))


def aggregate_shards(epoch: int, reps: Sequence[EpochReport],
                     cfg: ClusterConfig,
                     cross_shard_frac: float = 0.1) -> MultiRaftReport:
    """Reference-only (DESIGN.md §9): blend per-shard reports into one
    Multi-Raft report, applying the 2PC latency tax *post hoc* and
    deduplicating the cross-shard write prepares.  The grouped fleet
    engine replaces this with the in-graph coupling + group digest
    (`report_from_group_digest`); this stays as the frozen equivalence
    target and the `--sequential` fallback.

    NaN policy (uniform): every latency blend is NaN-aware — a shard
    with zero committed writes (all-NaN latency row) is excluded from
    the blend instead of poisoning it; all-NaN in, NaN out."""
    chi = cross_shard_frac
    # no cross-shard traffic, no 2PC rounds: the tail shift below is
    # "the tail IS the cross-shard traffic", which needs chi > 0
    tax = two_pc_penalty(cfg) if chi > 0 else 0
    lat_mean = _nan_blend([r.write_lat_mean for r in reps], np.nanmean)
    lat_p95 = _nan_blend([r.write_lat_p95 for r in reps], np.nanmax)
    lat_p99 = _nan_blend([r.write_lat_p99 for r in reps], np.nanmax)
    # cross-shard writes pay the 2PC penalty; the blended mean/p95 shift
    lat_mean = lat_mean + chi * tax
    lat_p95 = lat_p95 + tax                       # tail is cross-shard
    lat_p99 = lat_p99 + tax
    return MultiRaftReport(
        epoch=epoch,
        writes_committed=int(sum(r.writes_committed for r in reps) /
                             (1 + chi)),
        writes_arrived=int(sum(r.writes_arrived for r in reps) / (1 + chi)),
        reads_served=sum(r.reads_served for r in reps),
        reads_arrived=sum(r.reads_arrived for r in reps),
        write_lat_mean=lat_mean, write_lat_p95=lat_p95,
        write_lat_p99=lat_p99,
        read_lat_mean=_nan_blend([r.read_lat_mean for r in reps],
                                 np.nanmean),
        cost=sum(r.cost for r in reps),
    )


def report_from_group_digest(epoch: int, gdg: Dict,
                             cross_shard_frac: float) -> MultiRaftReport:
    """Distill one shard group's pooled epoch digest (numpy leaves,
    reduced over the group's members in-graph — DESIGN.md §9) into a
    `MultiRaftReport`.

    Counts deduplicate the cross-shard prepares by 1/(1+chi) with the
    *same arithmetic* as `aggregate_shards`, so grouped == sequential is
    exact on counts.  Latency stats come straight from the pooled
    unit-bin histogram, whose cross-shard entries already carry the
    measured 2PC rounds (`step.commit_step`) — the measured twin of the
    reference's post-hoc `+ chi * tax` shift (equal in the mean to
    within one bin; the tail percentiles are the *measured* improvement
    over the reference's synthetic `+ tax`)."""
    chi = cross_shard_frac
    n_done, lat_mean, lat_p95, lat_p99 = hist_stats(gdg["write_lat_hist"])
    reads_served = int(gdg["reads_served"])
    _, _, read_p95, read_p99 = hist_stats(gdg["read_lat_hist"])
    return MultiRaftReport(
        read_lat_p95=read_p95,
        read_lat_p99=read_p99,
        epoch=epoch,
        writes_committed=int(n_done / (1 + chi)),
        writes_arrived=int(int(gdg["writes_arrived"]) / (1 + chi)),
        reads_served=reads_served,
        reads_arrived=int(gdg["reads_arrived"]),
        write_lat_mean=lat_mean,
        write_lat_p95=lat_p95,
        write_lat_p99=lat_p99,
        read_lat_mean=float(gdg["read_lat_sum"]) / max(reads_served, 1),
        cost=float(gdg["cost_delta"]),
        cross_arrived=int(gdg["cross_arrived"]),
        two_pc_prepares=int(gdg["two_pc_prepares"]),
        two_pc_aborts=int(gdg["two_pc_aborts"]),
        metrics=(trace_metrics.as_dict(gdg["trace_metrics"])
                 if "trace_metrics" in gdg else None),
    )


class MultiRaftSim:
    """S Raft shards + 2PC cross-shard write model (DESIGN.md §6.3, §9).

    `engine="fleet"` (default): a thin wrapper over a grouped
    `fleet.FleetSim` — one compiled dispatch advances every shard and
    reduces the group digest in-graph; `run(E)` of an unmanaged group is
    eligible for the single-dispatch multi-epoch scan (DESIGN.md §7.1).
    `engine="sequential"`: the frozen host reference — one `BWRaftSim`
    per shard stepped one after another, blended by `aggregate_shards`.
    """

    def __init__(self, cfg: ClusterConfig, *, shards: int = 2,
                 write_rate: float = 8.0, read_rate: float = 32.0,
                 cross_shard_frac: float = 0.1, seed: int = 0,
                 engine: str = "fleet", backend: str = "xla",
                 n_observers: int = 0, staleness_bound: int = 16,
                 ae_interval: int = 4):
        assert engine in ("fleet", "sequential"), engine
        self.cfg = cfg
        self.shards = shards
        self.chi = cross_shard_frac
        self.engine = engine
        self.two_pc_penalty = two_pc_penalty(cfg)
        self.epoch = 0
        if engine == "fleet":
            from repro.core.fleet import FleetSim
            self.fleet = FleetSim(
                shard_specs(cfg, shards=shards, write_rate=write_rate,
                            read_rate=read_rate,
                            cross_shard_frac=cross_shard_frac, seed=seed,
                            group_id=0, n_observers=n_observers,
                            staleness_bound=staleness_bound,
                            ae_interval=ae_interval),
                backend=backend)
            self.sims: List[BWRaftSim] = []
            return
        w_eff, r_eff = shard_workload(write_rate, read_rate, shards,
                                      cross_shard_frac)
        self.sims = [
            BWRaftSim(cfg, mode="raft", write_rate=w_eff,
                      read_rate=r_eff, seed=seed + 17 * i,
                      manage_resources=False, backend=backend,
                      n_observers=n_observers,
                      staleness_bound=staleness_bound,
                      ae_interval=ae_interval)
            for i in range(shards)
        ]
        self.np_rng = np.random.default_rng(seed + 999)

    def run_epoch(self) -> MultiRaftReport:
        if self.engine == "fleet":
            self.fleet.run_epoch()
            self.epoch += 1
            return self.fleet.group_reports[0][-1]
        reps: List[EpochReport] = [s.run_epoch() for s in self.sims]
        rep = aggregate_shards(self.epoch, reps, self.cfg, self.chi)
        self.epoch += 1
        return rep

    def run(self, epochs: int) -> List[MultiRaftReport]:
        if self.engine == "fleet":
            start = self.epoch
            self.fleet.run(epochs)       # auto single dispatch when able
            self.epoch += epochs
            return self.fleet.group_reports[0][start:]
        return [self.run_epoch() for _ in range(epochs)]
