"""Multi-Raft baseline: key-space sharding over S independent Raft groups.

The state-of-the-art scale-out the paper compares against (§2.1): each
shard is a full Raft over its *own* on-demand node set (every scale-out
step replicates the entire footprint — the cost problem), with 2-phase
commit between shard leaders for cross-shard writes.  2PC is modeled as a
latency/capacity tax (DESIGN.md §6): a cross-shard write consumes commit
capacity in both shards and pays two extra inter-site commit rounds.

Two entry points share the same shard model and aggregation:

- `MultiRaftSim` — sequential: one `BWRaftSim` (mode="raft") per shard,
  stepped one after another on the host.
- `shard_specs` + `aggregate_shards` — batched: the same shards expressed
  as `fleet.MemberSpec`s, so a `FleetSim` can step every baseline shard in
  the same compiled program as the BW-Raft clusters it is compared
  against (see `benchmarks/common.run_systems`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.cluster_config import ClusterConfig
from repro.core.runtime import BWRaftSim, EpochReport


@dataclasses.dataclass
class MultiRaftReport:
    epoch: int
    writes_committed: int
    writes_arrived: int
    reads_served: int
    reads_arrived: int
    write_lat_mean: float
    write_lat_p95: float
    write_lat_p99: float
    read_lat_mean: float
    cost: float

    @property
    def goodput(self) -> float:
        return self.reads_served + self.writes_committed


def shard_workload(write_rate: float, read_rate: float, shards: int,
                   cross_shard_frac: float) -> tuple:
    """Per-shard effective rates: cross-shard writes execute in both
    shards, so the duplicated prepares inflate the write rate."""
    w_eff = write_rate * (1 + cross_shard_frac) / shards
    return w_eff, read_rate / shards


def two_pc_penalty(cfg: ClusterConfig) -> int:
    """2PC tax in ticks: prepare + commit round between shard leaders."""
    rtts = [s.rtt_inter for s in cfg.sites]
    return 2 * int(np.mean(rtts))


def shard_specs(cfg: ClusterConfig, *, shards: int = 2,
                write_rate: float = 8.0, read_rate: float = 32.0,
                cross_shard_frac: float = 0.1, seed: int = 0) -> List:
    """The batched entry point: this Multi-Raft instance as `shards`
    fleet members (mode="raft", unmanaged) for a single vmapped program.
    Feed the resulting per-shard EpochReports to `aggregate_shards`."""
    from repro.core.fleet import MemberSpec  # deferred: fleet imports runtime
    w_eff, r_eff = shard_workload(write_rate, read_rate, shards,
                                  cross_shard_frac)
    return [MemberSpec(cfg=cfg, mode="raft", write_rate=w_eff,
                       read_rate=r_eff, seed=seed + 17 * i,
                       manage_resources=False)
            for i in range(shards)]


def aggregate_shards(epoch: int, reps: Sequence[EpochReport],
                     cfg: ClusterConfig,
                     cross_shard_frac: float = 0.1) -> MultiRaftReport:
    """Blend per-shard reports into one Multi-Raft report, applying the
    2PC latency tax and deduplicating the cross-shard write prepares."""
    chi = cross_shard_frac
    tax = two_pc_penalty(cfg)
    lat_mean = float(np.nanmean([r.write_lat_mean for r in reps]))
    lat_p95 = float(np.nanmax([r.write_lat_p95 for r in reps]))
    lat_p99 = float(np.nanmax([r.write_lat_p99 for r in reps]))
    # cross-shard writes pay the 2PC penalty; the blended mean/p95 shift
    lat_mean = lat_mean + chi * tax
    lat_p95 = lat_p95 + tax                       # tail is cross-shard
    lat_p99 = lat_p99 + tax
    return MultiRaftReport(
        epoch=epoch,
        writes_committed=int(sum(r.writes_committed for r in reps) /
                             (1 + chi)),
        writes_arrived=int(sum(r.writes_arrived for r in reps) / (1 + chi)),
        reads_served=sum(r.reads_served for r in reps),
        reads_arrived=sum(r.reads_arrived for r in reps),
        write_lat_mean=lat_mean, write_lat_p95=lat_p95,
        write_lat_p99=lat_p99,
        read_lat_mean=float(np.mean([r.read_lat_mean for r in reps])),
        cost=sum(r.cost for r in reps),
    )


class MultiRaftSim:
    """S independent Raft shards + 2PC cross-shard write model."""

    def __init__(self, cfg: ClusterConfig, *, shards: int = 2,
                 write_rate: float = 8.0, read_rate: float = 32.0,
                 cross_shard_frac: float = 0.1, seed: int = 0):
        self.cfg = cfg
        self.shards = shards
        self.chi = cross_shard_frac
        w_eff, r_eff = shard_workload(write_rate, read_rate, shards,
                                      cross_shard_frac)
        self.sims = [
            BWRaftSim(cfg, mode="raft", write_rate=w_eff,
                      read_rate=r_eff, seed=seed + 17 * i,
                      manage_resources=False)
            for i in range(shards)
        ]
        self.two_pc_penalty = two_pc_penalty(cfg)
        self.epoch = 0
        self.np_rng = np.random.default_rng(seed + 999)

    def run_epoch(self) -> MultiRaftReport:
        reps: List[EpochReport] = [s.run_epoch() for s in self.sims]
        rep = aggregate_shards(self.epoch, reps, self.cfg, self.chi)
        self.epoch += 1
        return rep

    def run(self, epochs: int) -> List[MultiRaftReport]:
        return [self.run_epoch() for _ in range(epochs)]
