"""BW-Raft cluster state: a struct-of-arrays pytree, leading axis = node.

Node layout: ids [0, V) are the on-demand *voters* (leader / followers /
candidates — one per `SiteConfig.followers`), ids [V, V+MS) are secretary
slots, ids [V+MS, N) are observer slots.  Spot slots are DEAD until the
resource manager leases an instance into them; revocation kills them.

The log is windowed per epoch (entries reset at epoch boundaries after the
KV state machine has absorbed them — Raft log compaction); entry global
submit/commit ticks live in `entry_submit_t` / `entry_commit_t` for latency
accounting.

Padding (the batched fleet axis, DESIGN.md §7): `build_static` /
`init_state` accept `pad_*` counts so clusters of different sizes can share
one static shape and be stacked under `jax.vmap` (see `core/fleet.py`).
Padded node slots are not voters and not secretary/observer slots, start
DEAD, and are never leased — every step rule masks on `alive`, so they are
inert.  Padded sites exist only in the price arrays (no node maps to them);
padded log/key capacity is dead tail space.  Padding changes the *shapes*
of random draws, so a padded run follows a different (equally distributed)
sample path than an unpadded one — batched-vs-sequential equality holds
between runs of identical padded shapes.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cluster_config import ClusterConfig
from repro.trace import ring as trace_ring

# roles
FOLLOWER, CANDIDATE, LEADER, SECRETARY, OBSERVER, DEAD = range(6)
NONE = jnp.int32(-1)

# extra unit bins past T in the latency histograms, so in-graph latency
# surcharges (the 2PC rounds of DESIGN.md §9, the read-index fence of
# §11) land in measurable bins instead of clipping; `make_cfg_arrays`
# asserts every member's `two_pc_ticks` fits.  Static (part of the state
# and digest shapes), shared by every member of a fleet.  Both the write
# histogram (built at digest time from entry submit/commit ticks) and the
# read histogram (`state["read_lat_hist"]`, accumulated per tick by
# `step.read_step`) are `period_ticks + 1 + HIST_TAIL` unit bins wide —
# one layout, one recovery routine (`runtime.hist_stats`).
HIST_TAIL = 64

# position-keyed entry mix constants for the rolling applied-prefix digest
# (DESIGN.md §13): each applied entry contributes
# `mix(pos, key, val)` and the node digest is the XOR of the mixes of its
# applied prefix.  XOR is commutative, so the fold is order-free to
# *compute*, but because the position is mixed in, two digests are equal
# iff the underlying (pos, key, val) prefixes are equal (up to the
# astronomically unlikely XOR collision) — prefix-equality semantics with
# O(1) per-entry update cost.  Odd multiplicative constants from the
# splitmix/murmur family; uint32 wraparound is the hash.
_MIX_POS = 0x9E3779B1
_MIX_KEY = 0x85EBCA77
_MIX_VAL = 0xC2B2AE3D


def entry_mix(pos, key, val, xp=jnp):
    """uint32 mix of one log entry at position `pos` (DESIGN.md §13).
    `xp` selects the array namespace so tests can recompute digests in
    numpy bit-identically to the in-graph fold."""
    u = lambda x: xp.asarray(x).astype(xp.uint32)
    return ((u(pos) + xp.uint32(1)) * xp.uint32(_MIX_POS)
            ^ (u(key) + xp.uint32(1)) * xp.uint32(_MIX_KEY)
            ^ (u(val) + xp.uint32(1)) * xp.uint32(_MIX_VAL))


def prefix_digest(keys, vals, upto, xp=jnp):
    """Digest of the applied prefix `[0, upto)` of one log row — the
    reference (recompute-from-scratch) form of the rolling digest that
    `step.apply_step` maintains incrementally (DESIGN.md §13).  Works on
    numpy or jnp rows; `tests/test_observers.py` pins the incremental
    chain against this."""
    keys = xp.asarray(keys)
    pos = xp.arange(keys.shape[0])
    mixes = entry_mix(pos, keys, vals, xp=xp)
    take = pos < xp.asarray(upto)
    zero = xp.zeros((), xp.uint32)
    return xp.bitwise_xor.reduce(xp.where(take, mixes, zero)) \
        if xp is np else \
        jax.lax.reduce(xp.where(take, mixes, zero), zero,
                       jnp.bitwise_xor, (0,))


def hist_bins(cfg: ClusterConfig) -> int:
    """Latency-histogram width for this cluster: unit bins covering
    [0, period_ticks + HIST_TAIL], shared by the write and read
    histograms (DESIGN.md §7.1, §11)."""
    return cfg.period_ticks + 1 + HIST_TAIL


def build_static(cfg: ClusterConfig, *, pad_nodes: int = 0,
                 pad_sites: int = 0, n_obs_digest: int = 0,
                 pad_obs: int = 0,
                 trace_capacity: int = trace_ring.DEFAULT_CAPACITY
                 ) -> Dict[str, np.ndarray]:
    """Static per-node tables (site, voter mask, rtt matrix, capacities).

    `pad_nodes` appends that many inert node slots (not voters, not
    leasable, forever DEAD); `pad_sites` widens only the price arrays
    downstream (`S` here) — padded slots still map to *real* sites so the
    RTT matrix stays meaningful.

    `trace_capacity` sizes the flight-recorder ring (DESIGN.md §14) —
    the ONLY trace knob that is compile-key material (a static shape);
    the on/off flag and per-class mask are cfg_c data.

    `n_obs_digest` provisions that many *digest-tier* observer slots
    (DESIGN.md §13): unlike the dense node slots above, a digest observer
    carries no `(L,)` log row — only a handful of `(O,)` scalars — so `O`
    can run into the thousands without touching the dense shapes.
    `pad_obs` appends inert digest slots (never enabled) so members with
    different observer counts can share one fleet shape, exactly like
    `pad_nodes`.
    """
    V = cfg.num_voters
    MS, MO = cfg.max_secretaries, cfg.max_observers
    R = V + MS + MO                     # real slots
    N = R + pad_nodes
    site = np.zeros((N,), np.int32)
    i = 0
    for s_idx, s in enumerate(cfg.sites):
        for _ in range(s.followers):
            site[i] = s_idx
            i += 1
    # spot + padding slots round-robin over the real sites
    for j in range(V, N):
        site[j] = (j - V) % cfg.num_sites
    is_voter = np.zeros((N,), bool)
    is_voter[:V] = True
    is_secretary_slot = np.zeros((N,), bool)
    is_secretary_slot[V:V + MS] = True
    is_observer_slot = np.zeros((N,), bool)
    is_observer_slot[V + MS:R] = True

    rtt = np.zeros((N, N), np.int32)
    for a in range(N):
        for b in range(N):
            sa, sb = site[a], site[b]
            if sa == sb:
                rtt[a, b] = cfg.sites[sa].rtt_intra
            else:
                rtt[a, b] = (cfg.sites[sa].rtt_inter
                             + cfg.sites[sb].rtt_inter) // 2

    # site-pair RTT matrix (S, S): the digest tier is addressed by SITE,
    # not node id (there is no per-observer row in `rtt` — that matrix is
    # O(N^2) and the whole point of the tier is that O >> N), so read
    # latency for digest observers looks up `site_rtt[obs_site, x]`
    # (DESIGN.md §13).  Padded sites repeat the last real site, matching
    # `site_price_init`.
    S = cfg.num_sites + pad_sites
    site_of = [min(s, cfg.num_sites - 1) for s in range(S)]
    site_rtt = np.zeros((S, S), np.int32)
    for a in range(S):
        for b in range(S):
            sa, sb = site_of[a], site_of[b]
            if sa == sb:
                site_rtt[a, b] = cfg.sites[sa].rtt_intra
            else:
                site_rtt[a, b] = (cfg.sites[sa].rtt_inter
                                  + cfg.sites[sb].rtt_inter) // 2

    # digest-tier observer placement: round-robin over the real sites
    # (padded digest slots included — they are masked dead, the site id
    # just keeps the gather in range)
    O = n_obs_digest + pad_obs
    dobs_site = (np.arange(O, dtype=np.int32) % cfg.num_sites
                 if O else np.zeros((0,), np.int32))
    return {
        "site": site, "is_voter": is_voter,
        "is_secretary_slot": is_secretary_slot,
        "is_observer_slot": is_observer_slot,
        "rtt": rtt, "site_rtt": site_rtt,
        "dobs_site": dobs_site, "O": O, "O_live": n_obs_digest,
        "trace_cap": int(trace_capacity),
        "N": N, "V": V,
        "S": S,
        "majority": V // 2 + 1,
        "work_capacity": 8,       # reads a node can serve per tick
        "msg_budget": 16,         # fan-out msg-units a node sends per tick
        "entries_per_msg": 32,    # batch payload per msg-unit (bytes model)
        "max_ship": 256,          # entries shipped per append batch
        "max_apply": 8,           # state-machine applies per tick
    }


def site_price_init(cfg: ClusterConfig, S: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Initial per-site spot price and bid, (S,) float32 each — padded
    sites (S > cfg.num_sites) repeat the last real site's parameters.
    The bid rule (1.5x the site's mean price) lives here so `init_state`
    and the market providers (`market/synthetic.py`, the AWS loader's
    derived revocations) stay on one definition (DESIGN.md §10)."""
    site_of = [min(s, cfg.num_sites - 1) for s in range(S)]
    price0 = np.asarray(
        [cfg.sites[site_of[s]].spot_price_mean for s in range(S)],
        np.float32)
    bid = np.asarray(
        [cfg.sites[site_of[s]].spot_price_mean * 1.5 for s in range(S)],
        np.float32)
    return price0, bid


def init_state(cfg: ClusterConfig, static, *, pad_log: int = 0,
               pad_keys: int = 0) -> Dict[str, jnp.ndarray]:
    """Initial cluster state.  `pad_log`/`pad_keys` widen the log window and
    KV key space (dead tail capacity); the site axis follows static["S"]
    (padded sites get the last real site's price parameters)."""
    N, V = static["N"], static["V"]
    L, K = cfg.max_log + pad_log, cfg.key_space + pad_keys
    S = static.get("S", cfg.num_sites)
    price0, bid0 = site_price_init(cfg, S)
    z = lambda *sh: jnp.zeros(sh, jnp.int32)
    st = {
        "tick": jnp.zeros((), jnp.int32),
        "role": jnp.where(jnp.asarray(static["is_voter"]),
                          jnp.full((N,), FOLLOWER, jnp.int32),
                          jnp.full((N,), DEAD, jnp.int32)),
        "alive": jnp.asarray(static["is_voter"]),
        "term": z(N),
        "voted_for": jnp.full((N,), -1, jnp.int32),
        "votes_received": z(N),
        "log_term": z(N, L),
        "log_key": z(N, L),
        "log_val": z(N, L),
        "log_len": z(N),
        "commit_len": z(N),          # commit *length* known at node
        "applied_len": z(N),
        "kv": z(N, K),
        # timers
        # staggered initial timers: avoids simultaneous-candidate storms
        "election_timer": (jnp.int32(cfg.election_timeout_min) +
                           (jnp.arange(N, dtype=jnp.int32) * 7) %
                           jnp.int32(cfg.election_timeout_max -
                                     cfg.election_timeout_min + 1)),
        "heartbeat_timer": z(N),
        # leader bookkeeping (valid for current leader row semantics)
        "match_len": z(N),           # replicated length per node (leader view)
        # in-flight append batches (one slot per node)
        "app_arrive_t": jnp.full((N,), -1, jnp.int32),
        "app_from_len": z(N),        # sender match_len when shipped
        "app_upto": z(N),            # shipped log length
        "app_term": z(N),            # sender's term
        "app_commit": z(N),          # sender's commit length (piggyback)
        # in-flight acks to the commit authority (leader or via secretary)
        "ack_arrive_t": jnp.full((N,), -1, jnp.int32),
        "ack_upto": z(N),
        # vote traffic (one in-flight request slot per voter)
        "vreq_t": jnp.full((N,), -1, jnp.int32),
        "vreq_from": jnp.full((N,), -1, jnp.int32),
        "vreq_term": z(N),
        "vreq_lastterm": z(N),
        "vreq_lastlen": z(N),
        "grant_t": jnp.full((N,), -1, jnp.int32),   # per-voter grant arrival
        "grant_to": jnp.full((N,), -1, jnp.int32),
        "grant_term": z(N),
        # role wiring
        "sec_of": jnp.full((N,), -1, jnp.int32),    # follower -> secretary id
        "obs_of": jnp.full((N,), -1, jnp.int32),    # observer -> follower id
        # queueing / service accounting
        "read_queue": z(N),
        "write_pending": jnp.zeros((), jnp.int32),   # global client queue
        "leader_work": z(N),
        # per-entry timing (global logical log, window L)
        "entry_submit_t": jnp.full((L,), -1, jnp.int32),
        "entry_commit_t": jnp.full((L,), -1, jnp.int32),
        # spot market
        "spot_price": jnp.asarray(price0, jnp.float32),
        # kept as a state leaf for golden-trajectory compatibility; the
        # dynamics read cfg_c["spot_bid"] (jit-argument data) so bid
        # policies can update per epoch without recompiling (DESIGN.md §12)
        "spot_bid": jnp.asarray(bid0, jnp.float32),
        # advance-warning countdown (DESIGN.md §12): -1 = no warning;
        # >= 0 = revocation signal raised, kill lands when it hits 0
        "warn_timer": jnp.full((N,), -1, jnp.int32),
        # workload stats accumulators (reset each period by the manager)
        "reads_arrived": jnp.zeros((), jnp.int32),
        "writes_arrived": jnp.zeros((), jnp.int32),
        # cross-shard 2PC coordinator arrivals (Multi-Raft groups only;
        # stays 0 when cfg_c["cross_frac"] == 0 — DESIGN.md §9)
        "cross_arrived": jnp.zeros((), jnp.int32),
        "reads_served": jnp.zeros((), jnp.int32),
        "writes_committed": jnp.zeros((), jnp.int32),
        # read latency accounting: aggregate moments plus the unit-bin
        # per-request histogram the read path samples into (DESIGN.md
        # §11) — the read-side twin of the write histogram the digest
        # builds from entry_submit_t/entry_commit_t
        "read_lat_sum": jnp.zeros((), jnp.float32),
        "read_lat_max": jnp.zeros((), jnp.float32),
        "read_lat_hist": z(hist_bins(cfg)),
        "cost_accrued": jnp.zeros((), jnp.float32),
        # rolling applied-prefix digest per dense node (DESIGN.md §13):
        # XOR of `entry_mix` over the applied prefix, updated
        # incrementally by `step.apply_step`.  Maintained unconditionally
        # (it is RNG-free and O-independent) so the digest tier can
        # adopt it without the voters knowing observers exist.
        "applied_digest": jnp.zeros((N,), jnp.uint32),
    }
    st.update(_digest_tier_init(cfg, static))
    # flight-recorder ring + metrics registry (DESIGN.md §14): NOT reset
    # by `compact_state` — the cursor stays monotone across epochs so
    # the host drain windows (and events_dropped) stay exact
    st.update(trace_ring.trace_leaves(
        static.get("trace_cap", trace_ring.DEFAULT_CAPACITY)))
    return st


def _digest_tier_init(cfg: ClusterConfig, static) -> Dict[str, jnp.ndarray]:
    """Digest-tier observer leaves, leading axis O (DESIGN.md §13).  A
    digest observer holds no log row — just an applied index, a term, the
    applied-prefix digest, its last sync tick, a warning timer, and a read
    queue — so O scales into the thousands at ~28 bytes per slot.  All
    leaves exist (length 0) even when the tier is off, keeping the pytree
    structure uniform across members of one fleet."""
    O = int(static.get("O", 0))
    O_live = int(static.get("O_live", 0))
    V = static["V"]
    dobs_site = np.asarray(static.get("dobs_site", np.zeros((0,), np.int32)))
    site = np.asarray(static["site"])
    # wiring: each enabled digest observer follows a voter at its own
    # site, round-robin within the site (fallback: round-robin over all
    # voters if a site hosts none).  Recorded as a state leaf like
    # `obs_of`, so an epoch-boundary re-wire stays possible in-graph.
    dobs_fol = np.full((O,), -1, np.int32)
    taken: Dict[int, int] = {}
    for o in range(O_live):
        d = int(dobs_site[o])
        voters = [v for v in range(V) if site[v] == d]
        if voters:
            k = taken.get(d, 0)
            dobs_fol[o] = voters[k % len(voters)]
            taken[d] = k + 1
        else:
            dobs_fol[o] = o % V
    enabled = np.arange(O) < O_live
    z = lambda *sh: jnp.zeros(sh, jnp.int32)
    return {
        "dobs_enabled": jnp.asarray(enabled),
        "dobs_alive": jnp.asarray(enabled),
        "dobs_fol": jnp.asarray(dobs_fol),
        "dobs_applied": z(O),
        "dobs_term": z(O),
        "dobs_digest": jnp.zeros((O,), jnp.uint32),
        "dobs_synced_t": z(O),
        # advance-warning countdown, digest-tier twin of `warn_timer`
        # (DESIGN.md §12/§13): -1 = no warning
        "dobs_warn": jnp.full((O,), -1, jnp.int32),
        "dobs_read_queue": z(O),
        # per-epoch digest-tier serving census (reset by compaction)
        "obs_reads_served": jnp.zeros((), jnp.int32),
        "obs_rerouted": jnp.zeros((), jnp.int32),
        # unit-bin staleness histogram over served digest-tier reads:
        # same width/recovery as the latency histograms (DESIGN.md §7.1)
        "obs_stale_hist": z(hist_bins(cfg)),
    }


def leader_id(state, static):
    """Current leader id or -1 (max over one-hot; at most one by safety)."""
    is_leader = (state["role"] == LEADER) & state["alive"]
    ids = jnp.arange(is_leader.shape[0])
    return jnp.max(jnp.where(is_leader, ids, -1))


def pytree_nbytes(tree) -> int:
    """Total payload bytes of an array pytree, computed from shapes/dtypes
    only (never forces a device→host transfer).  Used for the epoch-digest
    transfer accounting (DESIGN.md §7.1): `FleetSim.d2h_bytes` and
    `benchmarks/perf_fleet.py` report digest-vs-state sizes through it."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = jnp.shape(leaf)
        total += int(np.prod(shape, dtype=np.int64)) * \
            np.dtype(jnp.result_type(leaf)).itemsize
    return total
