"""Algorithm 2 — MCSA (Multiple-Choice Secretary Algorithm), "peak".

Two implementations:

* `mcsa_topk` — faithful port of the paper's recursive pseudocode:
  k>1 splits the range at a Binomial(len, 1/2) point and recurses
  (floor(k/2) left, k-floor(k/2) right); k==1 runs the classic 1/e rule
  (observe floor(len/e), then take the first element beating the observed
  max, falling back to the last observed max).  O(n), online.
* `secretary_1e_stream` — a jit/scan-able single-choice variant used
  inside jitted simulations.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp


def _one_choice(score: np.ndarray, L: int, R: int,
                picked: List[int]) -> None:
    """Classic 1/e-rule on score[L..R] inclusive (paper lines 7-25)."""
    ln = R - L + 1
    if ln <= 0:
        return
    n = int(ln / math.e)
    mx = score[L]
    mx_idx = L
    for i in range(L, L + n):                       # observation phase
        if score[i] > mx:
            mx, mx_idx = score[i], i
    for i in range(L + n, R + 1):                   # selection phase
        if score[i] > mx:
            picked.append(i)
            return
    picked.append(mx_idx)                           # fallback: observed max


def mcsa_topk(score: np.ndarray, k: int,
              rng: Optional[np.random.Generator] = None) -> List[int]:
    """Select (approximately top-)k indices from a streamed score array."""
    rng = rng or np.random.default_rng(0)
    score = np.asarray(score, dtype=float)
    picked: List[int] = []

    def rec(k: int, L: int, R: int) -> None:
        if R < L or k <= 0:
            return
        if k == 1:
            _one_choice(score, L, R, picked)
            return
        m = int(rng.binomial(R - L + 1, 0.5))       # line 4
        m = min(max(m, 1), R - L)                   # keep both halves nonempty
        rec(k // 2, L, L + m - 1)                   # line 5
        rec(k - k // 2, L + m, R)                   # line 6

    rec(k, 0, len(score) - 1)
    # dedupe while preserving order (recursion ranges are disjoint, but the
    # fallback may duplicate when ranges degenerate)
    seen, out = set(), []
    for i in picked:
        if i not in seen:
            seen.add(i)
            out.append(i)
    return out[:k]


def secretary_1e_stream(scores: jnp.ndarray) -> jnp.ndarray:
    """jit-able single-choice secretary over a score stream (1/e rule).
    Returns the selected index."""
    n = scores.shape[0]
    n_obs = max(int(n / math.e), 1)

    def body(carry, x):
        i, best_obs, best_obs_idx, chosen, chosen_idx = carry
        s = x
        in_obs = i < n_obs
        better = s > best_obs
        best_obs = jnp.where(in_obs & better, s, best_obs)
        best_obs_idx = jnp.where(in_obs & better, i, best_obs_idx)
        take = (~in_obs) & (s > best_obs) & (~chosen)
        chosen_idx = jnp.where(take, i, chosen_idx)
        chosen = chosen | take
        return (i + 1, best_obs, best_obs_idx, chosen, chosen_idx), None

    init = (jnp.int32(0), jnp.float32(-jnp.inf), jnp.int32(0),
            jnp.bool_(False), jnp.int32(-1))
    (_, _, best_obs_idx, chosen, chosen_idx), _ = jax.lax.scan(
        body, init, scores.astype(jnp.float32))
    return jnp.where(chosen, chosen_idx, best_obs_idx)


def topk_oracle(score: np.ndarray, k: int) -> List[int]:
    """Offline optimum (for competitive-ratio tests)."""
    return list(np.argsort(score)[::-1][:k])
