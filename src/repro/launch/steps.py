"""Step builders: train_step / prefill_step / decode_step for any arch,
plus abstract state/spec construction shared by train.py, serve.py and the
dry-run.  Nothing here allocates device memory for full-size configs —
everything also works on ShapeDtypeStructs via jax.eval_shape/lower.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import lm
from repro.models.common import ParamSpec, abstract_tree
from repro.optim import adamw
from repro.sharding import axes as axes_mod

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def resolve_rules(cfg: ModelConfig, profile: str) -> Dict[str, Any]:
    rules = dict(axes_mod.PROFILES[profile])
    rules.update(dict(cfg.sharding_overrides))
    return rules


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, runcfg: RunConfig):
    return lm.build_param_specs(cfg, DTYPES[runcfg.param_dtype])


def train_state_specs(cfg: ModelConfig, runcfg: RunConfig):
    ps = param_specs(cfg, runcfg)
    opt = adamw.abstract_opt_state(ps, DTYPES[runcfg.opt_state_dtype])
    return {"params": ps, "opt": opt}


def state_shardings(spec_tree, rules, mesh, prune_log=None):
    return axes_mod.tree_shardings(spec_tree, rules, mesh,
                                   prune_log=prune_log)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                act_dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    """ParamSpec tree for one input batch of the given shape."""
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": ParamSpec((B, S), jnp.int32, ("batch", "seq")),
        "labels": ParamSpec((B, S), jnp.int32, ("batch", "seq")),
    }
    if cfg.family == "vlm":
        out["img_embeds"] = ParamSpec((B, cfg.num_image_tokens, cfg.d_model),
                                      act_dtype, ("batch", "img_seq", None))
    if cfg.family == "audio_encdec":
        out["frames"] = ParamSpec((B, S, cfg.d_model), act_dtype,
                                  ("batch", "seq", None))
    return out


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig, runcfg: RunConfig):
    """Serving state: KV/SSM caches + position counter."""
    B, T = shape.global_batch, shape.seq_len
    layers = lm.cache_specs(cfg, B, T, DTYPES[runcfg.activation_dtype])
    return {"pos": ParamSpec((B,), jnp.int32, ("batch",), "zeros"),
            "layers": layers}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, runcfg: RunConfig, mesh):
    rules = resolve_rules(cfg, runcfg.sharding_profile)

    def loss(params, batch):
        return lm.loss_fn(params, batch, cfg, runcfg, mesh, rules)

    def train_step(state, batch):
        params = state["params"]
        if runcfg.num_microbatches > 1:
            M = runcfg.num_microbatches

            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                    b)

            mb = micro(batch)

            def acc_body(carry, b):
                gsum, lsum = carry
                (tot, (l, aux)), g = jax.value_and_grad(
                    loss, has_aux=True)(params, b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            carry0 = (g0, jnp.zeros((), jnp.float32))
            if runcfg.scan_layers:
                (grads, lsum), _ = jax.lax.scan(acc_body, carry0, mb)
            else:  # roofline path: unrolled so cost_analysis counts all M
                carry = carry0
                for i in range(M):
                    carry, _ = acc_body(
                        carry, jax.tree.map(lambda x: x[i], mb))
                grads, lsum = carry
            grads = jax.tree.map(lambda g: g / M, grads)
            loss_val = lsum / M
            aux = jnp.zeros((), jnp.float32)
        else:
            (tot, (loss_val, aux)), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)

        new_params, new_opt, om = adamw.adamw_update(
            params, grads, state["opt"], lr=runcfg.learning_rate,
            weight_decay=runcfg.weight_decay, grad_clip=runcfg.grad_clip)
        metrics = {"loss": loss_val, "aux": aux, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step, rules


def make_prefill_step(cfg: ModelConfig, runcfg: RunConfig, mesh):
    profile = runcfg.sharding_profile
    rules = resolve_rules(cfg, profile)

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        logits, layer_caches, _ = lm.forward(
            params, tokens, cfg, runcfg, mesh, rules, mode="prefill",
            img_embeds=batch.get("img_embeds"), frames=batch.get("frames"))
        B, S = tokens.shape
        caches = {"pos": jnp.full((B,), S, jnp.int32), "layers": layer_caches}
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step, rules


def make_decode_step(cfg: ModelConfig, runcfg: RunConfig, mesh):
    rules = resolve_rules(cfg, runcfg.sharding_profile)

    def decode_step(params, caches, tokens):
        """tokens: (B,1) int32. Returns (next_token, new_caches)."""
        pos = caches["pos"]
        logits, new_layers, _ = lm.forward(
            params, tokens, cfg, runcfg, mesh, rules, mode="decode",
            caches=caches["layers"], cache_len=pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, {"pos": pos + 1, "layers": new_layers}

    return decode_step, rules


def make_step(cfg, runcfg, mesh, kind: str):
    if kind == "train":
        return make_train_step(cfg, runcfg, mesh)
    if kind == "prefill":
        return make_prefill_step(cfg, runcfg, mesh)
    if kind == "decode":
        return make_decode_step(cfg, runcfg, mesh)
    raise ValueError(kind)


def default_runcfg(cfg: ModelConfig, shape: ShapeConfig, **overrides):
    """Shape-appropriate RunConfig (profile, remat) for an arch."""
    kw: Dict[str, Any] = {}
    if shape.kind == "train":
        # grad accumulation so per-device activations fit 16GB HBM
        mb = 8 if cfg.d_model >= 8192 else 4
        kw.update(sharding_profile="train", num_microbatches=mb)
    elif shape.kind == "prefill":
        kw.update(sharding_profile="train", remat=False)
    else:
        prof = "long" if shape.global_batch == 1 else "decode"
        kw.update(sharding_profile=prof, remat=False)
    # precedence: shape defaults < per-arch run_overrides < explicit caller
    kw.update(dict(cfg.run_overrides))
    kw.update(overrides)
    return RunConfig(**kw)
