"""Parse collective ops + shapes from optimized HLO text (per-device).

Used by the dry-run report and the roofline harness.  The optimized HLO
inlines only *result* shapes (operands are bare ``%name`` refs), so we
account collective traffic from the result shape plus the participant
count n (parsed from ``replica_groups=[G,n]``), using standard ring
algorithm wire-byte models *per device*:

  all-gather          result x (n-1)/n        (operand = result/n)
  reduce-scatter      result x (n-1)          (operand = result x n)
  all-reduce          2 x result x (n-1)/n    (RS + AG phases)
  all-to-all          result x (n-1)/n
  collective-permute  result                  (one hop)

``-done`` ops are skipped; bytes are counted once at ``-start``/plain ops.
Tuple results (tuple all-to-all/all-gather) sum their element shapes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_INSTR_RE = re.compile(
    r"%\S+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "all-reduce":
        return 2 * (n - 1) / n
    if kind == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """{kind: {count, result_bytes, wire_bytes}} per device."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        nbytes = sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result))
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            lm = _GROUPS_LIST_RE.search(line)
            n = len(lm.group(1).split(",")) if lm else 2
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += nbytes
        out[kind]["wire_bytes"] += nbytes * _wire_factor(kind, n)
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    """Total wire bytes per device."""
    return int(sum(v["wire_bytes"]
                   for v in collective_stats(hlo_text).values()))


def render_stats(stats: Dict[str, Dict[str, float]]) -> str:
    if not stats:
        return "  (no collectives)"
    lines = []
    for k in sorted(stats):
        v = stats[k]
        lines.append(f"  {k:20s} count={int(v['count']):4d} "
                     f"result={v['result_bytes'] / 1e6:10.2f} MB "
                     f"wire={v['wire_bytes'] / 1e6:10.2f} MB")
    return "\n".join(lines)
