import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For each cell this proves the distribution config is
coherent on the production mesh — sharding mismatches, compile-time OOM or
unsupported collectives fail here — and records memory_analysis(),
cost_analysis() and the collective-op inventory for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES_BY_NAME, shape_applicable
from repro.launch import steps as S
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh, HW
from repro.models.common import abstract_tree, param_count
from repro.sharding import axes as axes_mod


def input_specs(arch: str, shape_name: str, *, mesh=None, runcfg=None):
    """ShapeDtypeStruct stand-ins (+ NamedShardings) for every model input
    of the given cell: (step_kind, args, in_shardings, donate)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    runcfg = runcfg or S.default_runcfg(cfg, shape)
    mesh = mesh if mesh is not None else make_production_mesh()
    rules = S.resolve_rules(cfg, runcfg.sharding_profile)
    log = axes_mod.PruneLog()

    def shardings(spec_tree):
        return axes_mod.tree_shardings(spec_tree, rules, mesh, prune_log=log)

    bspecs = S.batch_specs(cfg, shape)
    if shape.kind != "train":
        bspecs.pop("labels", None)
    batch = abstract_tree(bspecs)
    batch_sh = shardings(bspecs)

    if shape.kind == "train":
        st_specs = S.train_state_specs(cfg, runcfg)
        args = (abstract_tree(st_specs), batch)
        shs = (shardings(st_specs), batch_sh)
        donate = (0,)
    elif shape.kind == "prefill":
        p_specs = S.param_specs(cfg, runcfg)
        args = (abstract_tree(p_specs), batch)
        shs = (shardings(p_specs), batch_sh)
        donate = ()
    else:  # decode
        p_specs = S.param_specs(cfg, runcfg)
        d_specs = S.decode_state_specs(cfg, shape, runcfg)
        tok_spec = {"tokens": S.batch_specs(cfg, shape)["tokens"]}
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), np.int32)
        args = (abstract_tree(p_specs), abstract_tree(d_specs), tok)
        tok_sh = axes_mod.tree_shardings(
            {"t": S.batch_specs(cfg, shape)["tokens"]._replace(
                shape=(shape.global_batch, 1))}, rules, mesh,
            prune_log=log)["t"]
        shs = (shardings(p_specs), shardings(d_specs), tok_sh)
        donate = (1,)
    return shape.kind, args, shs, donate, runcfg, rules, log


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             runcfg_overrides=None, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    runcfg = S.default_runcfg(cfg, shape, **(runcfg_overrides or {}))
    kind, args, shs, donate, runcfg, rules, log = input_specs(
        arch, shape_name, mesh=mesh, runcfg=runcfg)
    step, _ = S.make_step(cfg, runcfg, mesh, kind)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(step, in_shardings=shs, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    txt = compiled.as_text()
    colls = hlo_stats.collective_stats(txt)
    n_chips = int(np.prod(list(mesh.shape.values())))

    rec = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": dict(mesh.shape), "status": "OK",
        "params": param_count(S.param_specs(cfg, runcfg)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_dev": ca.get("flops", 0.0),
        "bytes_per_dev": ca.get("bytes accessed", 0.0),
        "collective_bytes_per_dev": int(
            sum(v["wire_bytes"] for v in colls.values())),
        "collectives": {k: {"count": int(v["count"]),
                            "result_mb": round(v["result_bytes"] / 1e6, 2),
                            "wire_mb": round(v["wire_bytes"] / 1e6, 2)}
                        for k, v in colls.items()},
        "memory": {
            "argument_mb": round(ma.argument_size_in_bytes / 2**20, 1),
            "output_mb": round(ma.output_size_in_bytes / 2**20, 1),
            "temp_mb": round(ma.temp_size_in_bytes / 2**20, 1),
            "alias_mb": round(ma.alias_size_in_bytes / 2**20, 1),
        },
        "hbm_total_mb": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**20, 1),
        "sharding_fallbacks": log.entries,
    }
    if verbose:
        fits = rec["hbm_total_mb"] * 2**20 <= HW["hbm_bytes"]
        print(f"[{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}]"
              f" OK compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['memory']['argument_mb']}MB "
              f"out={rec['memory']['output_mb']}MB "
              f"temp={rec['memory']['temp_mb']}MB "
              f"alias={rec['memory']['alias_mb']}MB "
              f"-> {rec['hbm_total_mb']}MB/dev "
              f"({'fits' if fits else 'OVER'} {HW['hbm_bytes']/2**30:.0f}GB)")
        print(f"  cost_analysis: flops/dev={rec['flops_per_dev']:.3e} "
              f"bytes/dev={rec['bytes_per_dev']:.3e}")
        print(hlo_stats.render_stats(colls))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = sorted(SHAPES_BY_NAME) if (args.all or not args.shape) \
        else (args.shape,)
    meshes = (False, True) if (args.both_meshes or args.all) \
        else (args.multi_pod,)
    records = []
    failed = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, multi_pod=mp)
                except Exception as e:  # a failure here is a sharding bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    failed += 1
                records.append(rec)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, default=str)
    print(f"\n{sum(r['status'] == 'OK' for r in records)} OK, "
          f"{sum(r['status'] == 'SKIP' for r in records)} SKIP, "
          f"{failed} FAIL / {len(records)} cells")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
