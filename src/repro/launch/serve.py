"""Serving driver: batched prefill+decode through the elastic observer
pool (inference replicas on spot capacity, scaled by Algorithm 1,
revocation-safe by Property 3.4).

Usage:
  python -m repro.launch.serve --arch smollm-360m --requests 64 --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.coord.elastic import ElasticObserverPool
from repro.data.pipeline import google_trace_like
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models.common import init_tree
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--revoke-p", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    runcfg = RunConfig(remat=False)
    mesh = make_host_mesh()
    prefill, _ = S.make_prefill_step(cfg, runcfg, mesh)
    decode, _ = S.make_decode_step(cfg, runcfg, mesh)
    prefill = jax.jit(prefill)
    decode = jax.jit(decode, donate_argnums=1)

    params = init_tree(jax.random.PRNGKey(args.seed),
                       S.param_specs(cfg, runcfg))

    from repro.configs.bwraft_kv import CONFIG as CLUSTER
    pool = ElasticObserverPool(CLUSTER, seed=args.seed)
    pool.set_committed(0)
    pool.add_replicas(2)

    trace = google_trace_like(args.requests, rate=8.0, seed=args.seed)
    B, P, G = args.batch, args.prompt_len, args.gen_len
    cap = P + G
    rng = np.random.default_rng(args.seed)

    t0 = time.time()
    total_tokens = 0
    done = 0
    batch_i = 0
    while done < args.requests:
        n = min(B, args.requests - done)
        # route this batch through the observer pool; revocations mid-flight
        # re-route to surviving replicas (paper fault path)
        routed = pool.route(n)
        killed = pool.revoke_random(args.revoke_p)
        if killed:
            pool.route(0)      # survivors pick up; queue counters keep score
        toks = rng.integers(0, cfg.vocab_size, (B, P)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["img_embeds"] = jnp.zeros(
                (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio_encdec":
            batch["frames"] = jnp.zeros((B, P, cfg.d_model), jnp.bfloat16)
        tok, caches = prefill(params, batch)
        # grow caches to capacity for decode
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == P:   # (G,B,P,KV,hd) kv caches
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, cap - P)
                return jnp.pad(x, pad)
            return x
        caches = {"pos": caches["pos"],
                  "layers": jax.tree.map(grow, caches["layers"])}
        for _ in range(G):
            tok, caches = decode(params, caches, tok[:, None])
        pool.serve_tick()
        total_tokens += n * G
        done += n
        batch_i += 1
        # autoscale each round on observed load
        pool.autoscale(reads_now=done * G, writes_now=0, budget=2.0,
                       spot_price=0.012, on_demand_price=0.042)
    dt = time.time() - t0
    print(f"[serve] {done} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s) "
          f"replicas={len(pool.alive)} served={pool.served} "
          f"rerouted={pool.rerouted}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
