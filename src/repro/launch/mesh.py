"""Production mesh construction.

A *function*, not a module-level constant, so importing this module never
touches jax device state.  The single-pod production mesh is 16x16 = 256
chips (TPU v5e pod slice); the multi-pod mesh adds a leading "pod" axis
(2 pods = 512 chips).  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import so these meshes can be built on the CPU container.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


HW = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,      # per chip
    "hbm_gbps": 819e9,              # bytes/s per chip
    "ici_link_gbps": 50e9,          # bytes/s per link (~100GB/s bidir / 2)
    "hbm_bytes": 16 * 2**30,
    "vmem_bytes": 128 * 2**20,
}
