"""Multi-host launch boundary (documented interface).

On a real fleet each host runs:

    python -m repro.launch.cluster --coordinator <addr> --pod-id <i>

which would call ``jax.distributed.initialize(coordinator, n, i)``, build
``make_production_mesh(multi_pod=True)`` over the global device set, run
one BW-Raft voter node (the per-host control agent speaking the record
schema in repro.coord.log_records), and enter launch/train.py's loop with
``shard=pod_id``.  This container has a single CPU device, so this module
only validates arguments and prints the would-be topology — the full code
path it delegates to (mesh building, steps, coordinator records,
checkpoint commit) is exactly what the in-process tests exercise.
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default="localhost:1234")
    ap.add_argument("--num-pods", type=int, default=2)
    ap.add_argument("--pod-id", type=int, default=0)
    ap.add_argument("--chips-per-pod", type=int, default=256)
    args = ap.parse_args(argv)
    print(f"[cluster] pod {args.pod_id}/{args.num_pods} @ "
          f"{args.coordinator}; {args.chips_per_pod} chips/pod")
    print("[cluster] would call jax.distributed.initialize(...), build "
          "make_production_mesh(multi_pod=True), start the BW-Raft voter "
          "agent, then exec repro.launch.train with shard=pod_id")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
