"""Fault-tolerant training driver.

End-to-end loop: deterministic data pipeline -> jitted train_step ->
async sharded checkpointing -> CKPT_COMMIT through the BW-Raft control
log -> straggler detection & elastic DP re-sharding -> restart from the
last *committed* checkpoint (never trusting local disk alone).

On this container it drives reduced configs on the host mesh; the same
driver lowers on the production mesh via --dryrun (see launch/dryrun.py
for the systematic sweep).

Usage:
  python -m repro.launch.train --arch llama3.2-1b --steps 100 --reduced \
      [--batch 8 --seq 64] [--kill-at 40] [--resume]
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore, tree_digest
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.coord.coordinator import ConsensusCoordinator
from repro.coord.stragglers import StragglerMitigator
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models.common import init_tree
from repro.optim import adamw


def build(arch: str, *, reduced: bool, batch: int, seq: int,
          runcfg: Optional[RunConfig] = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    runcfg = runcfg or RunConfig(remat=False, num_microbatches=1)
    mesh = make_host_mesh()
    train_step, rules = S.make_train_step(cfg, runcfg, mesh)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=seq, global_batch=batch))
    return cfg, runcfg, mesh, jax.jit(train_step, donate_argnums=0), pipe


def extras_for(cfg, batch, seq):
    ex = {}
    if cfg.family == "vlm":
        ex["img_embeds"] = np.zeros(
            (batch, cfg.num_image_tokens, cfg.d_model), np.float32)
    if cfg.family == "audio_encdec":
        ex["frames"] = np.zeros((batch, seq, cfg.d_model), np.float32)
    return ex


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--kill-at", type=int, default=-1,
                    help="simulate coordinator-pod failure at this step")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pods", type=int, default=4)
    args = ap.parse_args(argv)

    cfg, runcfg, mesh, train_step, pipe = build(
        args.arch, reduced=args.reduced, batch=args.batch, seq=args.seq)
    store = CheckpointStore(args.ckpt_dir)
    from repro.configs.bwraft_kv import CONFIG as CLUSTER
    coord = ConsensusCoordinator(CLUSTER, seed=args.seed)
    coord.wait_for_leader()
    straggler = StragglerMitigator(args.pods)

    params = init_tree(jax.random.PRNGKey(args.seed),
                       S.param_specs(cfg, runcfg))
    state = {"params": params, "opt": adamw.init_opt_state(params)}
    start_step = 0

    if args.resume:
        committed = coord.last_committed_checkpoint()
        if committed:
            step_c, tag = committed
            state, digest = store.restore(step_c, state)
            assert int(digest[:3], 16) == tag, \
                "restored checkpoint digest does not match committed record"
            start_step = step_c
            print(f"[restore] resumed from committed step {step_c} "
                  f"(digest tag {tag:03x})")

    ex = extras_for(cfg, args.batch, args.seq)
    t_last = time.time()
    for step in range(start_step, args.steps):
        # elastic DP: derive shard layout from the committed membership view
        shards = max(len(straggler.active_pods), 1)
        batch = pipe.batch_at(step, shard=0, num_shards=1, extras=ex)
        state, metrics = train_step(state, batch)

        dt = time.time() - t_last
        t_last = time.time()
        # per-pod heartbeats (pod 0 is us; others simulated at same speed)
        hb = {p: dt for p in straggler.active_pods}
        if args.kill_at >= 0 and step == args.kill_at:
            print(f"[failure] pod 1 dies at step {step}")
            straggler.mark_failed(1)
            coord.commit_membership(straggler.membership_bitmap())
        straggler.heartbeat(hb)

        if step % 10 == 0:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} pods={shards} "
                  f"({dt*1e3:.0f} ms)")
        if step > 0 and step % args.ckpt_every == 0:
            digest = store.save(step, state, blocking=False)
            store.wait()
            rec = coord.commit_checkpoint(step, digest)
            print(f"[ckpt] step {step} digest={digest} committed "
                  f"rev={rec.revision}")
    # final checkpoint
    digest = store.save(args.steps, state)
    coord.commit_checkpoint(args.steps, digest)
    print(f"[done] {args.steps} steps; final loss "
          f"{float(metrics['loss']):.4f}; checkpoint committed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
