"""BW-KV: the paper's key-value service API over the consensus core.

Mirrors Listing 1's client surface:
    revision_id <- put(key, value)
    (value, revision_id) <- get(key)

String keys hash into the bounded integer key space of the jitted state
machine (DESIGN.md §6).  `put` submits through the leader write path and
returns once the entry commits; `get` runs an explicit read-index round
(DESIGN.md §11): fence on the leader's commit index at request time,
pick a serving replica (observer preferred), wait until its apply index
reaches the fence, then read — so a read can never return uncommitted
data, and a read issued to a caught-up replica still reflects every
write acknowledged before it.  Per-request read latency is recorded on
the service (`read_latencies`) AND folded into the cluster's device-
resident read histogram (`state["read_lat_hist"]`), the same unit-bin
digest histogram the simulator's aggregate read path samples into.
This is the host-facing service layer used by the examples; throughput-
scale experiments drive the simulator's aggregate workload instead.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import state as SM
from repro.core.runtime import BWRaftSim


class NotLeader(Exception):
    pass


class Timeout(Exception):
    pass


@dataclasses.dataclass
class PutResult:
    revision: int
    latency_ticks: int


class BWKVService:
    """Synchronous client over an in-process BW-Raft cluster."""

    def __init__(self, sim: BWRaftSim, *, timeout_ticks: int = 400):
        self.sim = sim
        self.timeout = timeout_ticks
        self._tickfn = None
        # per-request read latencies (ticks), in completion order — the
        # host-side twin of the device read histogram (DESIGN.md §11)
        self.read_latencies: list = []
        # client-round annotations for the flight-recorder Perfetto
        # export (DESIGN.md §14): one span dict per completed put /
        # read-index round ({name, start_tick, end_tick, ...args}),
        # passed straight to `trace.export.to_perfetto(annotations=...)`
        # to land on the "client" track next to the device events
        self.annotations: list = []
        # session fence floor: the highest log length this client has
        # been acked (writes) or served (reads).  A read-index round
        # fences at max(leader commit index, floor), so a read can never
        # return a value older than the last write acknowledged to this
        # session — even across a leader change whose fresh leader has
        # not re-established the old commit index yet (DESIGN.md §11).
        self.session_floor: int = 0

    def _key_id(self, key: str) -> int:
        K = self.sim.cfg.key_space
        return int(hashlib.sha1(key.encode()).hexdigest(), 16) % K

    def _step(self, n: int = 1) -> None:
        import repro.core.step as step_mod
        if self._tickfn is None:
            static, cfg_c = self.sim.static, self.sim.cfg_c
            self._tickfn = jax.jit(
                lambda s, r: step_mod.tick(s, static, cfg_c, r))
        for _ in range(n):
            self.sim.rng, sub = jax.random.split(self.sim.rng)
            self.sim.state, _ = self._tickfn(self.sim.state, sub)

    def put(self, key: str, value: int) -> PutResult:
        """Submit a write through the leader; block until committed."""
        kid = self._key_id(key)
        st = self.sim.state
        lid = int(SM.leader_id(st, self.sim.static))
        waited = 0
        while lid < 0:
            self._step(5)
            waited += 5
            if waited > self.timeout:
                raise Timeout("no leader elected")
            lid = int(SM.leader_id(self.sim.state, self.sim.static))
        st = self.sim.state
        # append directly at the leader (bypasses the random workload gen —
        # this is the explicit-client path)
        pos = int(st["log_len"][lid])
        if pos >= self.sim.cfg.max_log:
            raise Timeout("log window full; run an epoch to compact")
        term = st["term"][lid]
        self.sim.state = dict(
            st,
            log_term=st["log_term"].at[lid, pos].set(term),
            log_key=st["log_key"].at[lid, pos].set(kid),
            log_val=st["log_val"].at[lid, pos].set(value),
            log_len=st["log_len"].at[lid].set(pos + 1),
            entry_submit_t=st["entry_submit_t"].at[pos].set(st["tick"]),
        )
        t0 = int(self.sim.state["tick"])
        while True:
            self._step(1)
            st = self.sim.state
            lid_now = int(SM.leader_id(st, self.sim.static))
            if lid_now >= 0 and int(st["commit_len"][lid_now]) > pos:
                self.session_floor = max(self.session_floor, pos + 1)
                self.annotations.append({
                    "name": f"put {key}", "start_tick": t0,
                    "end_tick": int(st["tick"]), "revision": pos,
                    "leader": lid_now})
                return PutResult(revision=pos,
                                 latency_ticks=int(st["tick"]) - t0)
            if int(st["tick"]) - t0 > self.timeout:
                raise Timeout(f"put({key}) not committed "
                              f"after {self.timeout} ticks")

    def _record_read(self, latency_ticks: int) -> None:
        """Fold one completed read into the service's latency record and
        the cluster's device-resident read histogram — the same unit-bin
        digest histogram the aggregate read path samples into, so client
        reads and simulated reads share one percentile machinery
        (DESIGN.md §11)."""
        self.read_latencies.append(int(latency_ticks))
        st = self.sim.state
        H = st["read_lat_hist"].shape[0]
        b = min(max(int(latency_ticks), 0), H - 1)
        self.sim.state = dict(
            st,
            reads_served=st["reads_served"] + 1,
            read_lat_sum=st["read_lat_sum"] + float(latency_ticks),
            read_lat_max=jnp.maximum(st["read_lat_max"],
                                     float(latency_ticks)),
            read_lat_hist=st["read_lat_hist"].at[b].add(1),
        )

    def get(self, key: str, *, allow_observer: bool = True,
            wait_for_leader: bool = False) -> Tuple[int, int]:
        """One explicit read-index round (paper §3.1 step 6 / §4.3,
        DESIGN.md §11):

        1. *leader fence* — find the leader and capture its commit index
           (`readindex`, floored at `session_floor` so the fence always
           covers every write already acked to this session, leader
           changes included) at request time; with no leader, raise
           `NotLeader`, or — `wait_for_leader=True` — step until one is
           elected (Timeout bounds the wait), so a read during an
           election waits or times out, never serves stale state;
        2. *replica pick* — serve from a caught-up observer when
           allowed, else a caught-up follower/leader, else fall back to
           the leader itself;
        3. *apply wait* — step until the serving replica's apply index
           reaches the fence, so the value returned reflects every
           entry committed before the read began.

        Returns ``(value, revision)`` with ``revision = readindex``; the
        round's latency (ticks from request to serve) is recorded via
        `_record_read`."""
        kid = self._key_id(key)
        t0 = int(self.sim.state["tick"])
        lid = int(SM.leader_id(self.sim.state, self.sim.static))
        if lid < 0 and not wait_for_leader:
            raise NotLeader("no leader for readindex")
        waited = 0
        while lid < 0:
            self._step(5)
            waited += 5
            if waited > self.timeout:
                raise Timeout("read: no leader elected")
            lid = int(SM.leader_id(self.sim.state, self.sim.static))
        st = self.sim.state
        role = np.asarray(st["role"])
        alive = np.asarray(st["alive"])
        readindex = max(int(st["commit_len"][lid]), self.session_floor)
        applied = np.asarray(st["applied_len"])
        node = None
        if allow_observer:
            obs = np.where((role == SM.OBSERVER) & alive &
                           (applied >= readindex))[0]
            if obs.size:
                node = int(obs[0])
        if node is None:
            fol = np.where(((role == SM.FOLLOWER) | (role == SM.LEADER)) &
                           alive & (applied >= readindex))[0]
            node = int(fol[0]) if fol.size else lid
        # apply-index wait: the serving replica must reach the fence
        waited = 0
        while int(self.sim.state["applied_len"][node]) < readindex:
            self._step(1)
            waited += 1
            if waited > self.timeout:
                raise Timeout("read: node never reached readindex")
        value = int(self.sim.state["kv"][node, kid])
        self.session_floor = max(self.session_floor, readindex)
        self.annotations.append({
            "name": f"read {key}", "start_tick": t0,
            "end_tick": int(self.sim.state["tick"]),
            "fence": readindex, "node": node})
        self._record_read(int(self.sim.state["tick"]) - t0)
        return value, readindex

    def get_stale(self, key: str) -> Tuple[int, int]:
        """Bounded-staleness read through the digest tier (DESIGN.md §13).

        No read-index fence: pick a live digest observer that is (a)
        within the configured staleness bound (``tick - dobs_synced_t <=
        staleness_bound``) and (b) not behind this session's floor
        (``dobs_applied >= session_floor``, the session-monotonicity
        contract — a session never reads a prefix shorter than one it
        already observed or wrote).  The observer holds no dense log, so
        the value is reconstructed host-side by last-wins replay of its
        follower's applied prefix ``log[:dobs_applied]`` — exactly the
        state the digest certifies (Property 3.2 prefix mirror).  Returns
        ``(value, revision)`` with ``revision = dobs_applied`` and raises
        the session floor to it.  When no digest observer qualifies
        (tier off, all stale, or all behind the floor) the read reroutes
        to the fenced `get` path, mirroring `read_step`'s in-graph
        reroute rule."""
        st = self.sim.state
        O = int(self.sim.static.get("O", 0))
        if O == 0:
            return self.get(key)
        kid = self._key_id(key)
        t0 = int(st["tick"])
        alive = np.asarray(st["dobs_alive"])
        applied = np.asarray(st["dobs_applied"])
        synced = np.asarray(st["dobs_synced_t"])
        bound = int(self.sim.cfg_c["staleness_bound"])
        ok = alive & (t0 - synced <= bound) & (applied >= self.session_floor)
        cand = np.where(ok)[0]
        if not cand.size:
            return self.get(key)                  # reroute: behind/stale
        # freshest qualifying observer serves
        o = int(cand[np.argmax(applied[cand])])
        revision = int(applied[o])
        fol = int(st["dobs_fol"][o])
        keys = np.asarray(st["log_key"][fol][:revision])
        vals = np.asarray(st["log_val"][fol][:revision])
        hits = np.where(keys == kid)[0]
        value = int(vals[hits[-1]]) if hits.size else -1
        self.session_floor = max(self.session_floor, revision)
        self.annotations.append({
            "name": f"read.stale {key}", "start_tick": t0,
            "end_tick": int(self.sim.state["tick"]),
            "revision": revision, "observer": o})
        self._record_read(int(self.sim.state["tick"]) - t0)
        return value, revision
