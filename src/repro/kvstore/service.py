"""BW-KV: the paper's key-value service API over the consensus core.

Mirrors Listing 1's client surface:
    revision_id <- put(key, value)
    (value, revision_id) <- get(key)

String keys hash into the bounded integer key space of the jitted state
machine (DESIGN.md §6).  `put` submits through the leader write path and
returns once the entry commits; `get` follows the observer/readindex path.
This is the host-facing service layer used by the examples; throughput-
scale experiments drive the simulator's aggregate workload instead.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import state as SM
from repro.core.runtime import BWRaftSim


class NotLeader(Exception):
    pass


class Timeout(Exception):
    pass


@dataclasses.dataclass
class PutResult:
    revision: int
    latency_ticks: int


class BWKVService:
    """Synchronous client over an in-process BW-Raft cluster."""

    def __init__(self, sim: BWRaftSim, *, timeout_ticks: int = 400):
        self.sim = sim
        self.timeout = timeout_ticks
        self._tickfn = None

    def _key_id(self, key: str) -> int:
        K = self.sim.cfg.key_space
        return int(hashlib.sha1(key.encode()).hexdigest(), 16) % K

    def _step(self, n: int = 1) -> None:
        import repro.core.step as step_mod
        if self._tickfn is None:
            static, cfg_c = self.sim.static, self.sim.cfg_c
            self._tickfn = jax.jit(
                lambda s, r: step_mod.tick(s, static, cfg_c, r))
        for _ in range(n):
            self.sim.rng, sub = jax.random.split(self.sim.rng)
            self.sim.state, _ = self._tickfn(self.sim.state, sub)

    def put(self, key: str, value: int) -> PutResult:
        """Submit a write through the leader; block until committed."""
        kid = self._key_id(key)
        st = self.sim.state
        lid = int(SM.leader_id(st, self.sim.static))
        waited = 0
        while lid < 0:
            self._step(5)
            waited += 5
            if waited > self.timeout:
                raise Timeout("no leader elected")
            lid = int(SM.leader_id(self.sim.state, self.sim.static))
        st = self.sim.state
        # append directly at the leader (bypasses the random workload gen —
        # this is the explicit-client path)
        pos = int(st["log_len"][lid])
        if pos >= self.sim.cfg.max_log:
            raise Timeout("log window full; run an epoch to compact")
        term = st["term"][lid]
        self.sim.state = dict(
            st,
            log_term=st["log_term"].at[lid, pos].set(term),
            log_key=st["log_key"].at[lid, pos].set(kid),
            log_val=st["log_val"].at[lid, pos].set(value),
            log_len=st["log_len"].at[lid].set(pos + 1),
            entry_submit_t=st["entry_submit_t"].at[pos].set(st["tick"]),
        )
        t0 = int(self.sim.state["tick"])
        while True:
            self._step(1)
            st = self.sim.state
            lid_now = int(SM.leader_id(st, self.sim.static))
            if lid_now >= 0 and int(st["commit_len"][lid_now]) > pos:
                return PutResult(revision=pos,
                                 latency_ticks=int(st["tick"]) - t0)
            if int(st["tick"]) - t0 > self.timeout:
                raise Timeout(f"put({key}) not committed "
                              f"after {self.timeout} ticks")

    def get(self, key: str, *, allow_observer: bool = True
            ) -> Tuple[int, int]:
        """Read via an observer when one has caught up to readindex,
        else via a follower (paper §3.1 step 6 / §4.3)."""
        kid = self._key_id(key)
        st = self.sim.state
        role = np.asarray(st["role"])
        alive = np.asarray(st["alive"])
        lid = int(SM.leader_id(st, self.sim.static))
        if lid < 0:
            raise NotLeader("no leader for readindex")
        readindex = int(st["commit_len"][lid])
        applied = np.asarray(st["applied_len"])
        if allow_observer:
            obs = np.where((role == SM.OBSERVER) & alive &
                           (applied >= readindex))[0]
            if obs.size:
                node = int(obs[0])
                return int(st["kv"][node, kid]), readindex
        fol = np.where(((role == SM.FOLLOWER) | (role == SM.LEADER)) &
                       alive & (applied >= readindex))[0]
        node = int(fol[0]) if fol.size else lid
        # wait for the serving node to apply up to readindex
        waited = 0
        while int(self.sim.state["applied_len"][node]) < readindex:
            self._step(1)
            waited += 1
            if waited > self.timeout:
                raise Timeout("read: node never reached readindex")
        return int(self.sim.state["kv"][node, kid]), readindex
