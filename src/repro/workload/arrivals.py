"""Open-loop arrival processes: rate curves that ride in `cfg_c`.

The closed-loop knob (`cfg_c["write_rate"]` / `["read_rate"]`, one scalar
per epoch) models a fixed-intensity client population; the paper's SLO-
goodput claim is about *open-loop* traffic — arrivals that keep coming at
the schedule's rate whether or not the service keeps up, so queues (and
tails) grow when capacity is exceeded.  Every provider here materializes
to a per-tick rate curve, a plain ``(Ta,)`` float32 array that enters the
compiled program as a jit *argument* — exactly the way market traces do
(DESIGN.md §10) — so swapping arrival schedules at one shape never
recompiles (DESIGN.md §11).

Providers (`materialize(ticks) -> (ticks,) np.float32`):

  `ConstantRate`   the open-loop twin of the closed-loop scalar knob
  `DiurnalRate`    sinusoidal day/night load curve around a base rate
  `FlashCrowd`     a base curve plus multiplicative burst windows — the
                   flash-crowd spikes that stress the p95 deadline

`OpenLoop` bundles a write curve + read curve into the arrival plan that
`runtime.make_cfg_arrays(arrivals=...)` compiles into cfg_c; `fit_to`
wraps a plan to a fleet-shared width the way `MarketTrace.fit_to` wraps
trace columns (the in-step lookup wraps at the plan's OWN length, a jit
argument, so widening is replay-neutral — DESIGN.md §11).

`ZipfianKeys` is the key-popularity side of the open-loop contract: a
``(K,)`` CDF riding in cfg_c; the leader samples write keys from it by
inverse transform, matching `scipy.stats.zipfian(a=s, n=K)` in
distribution (`tests/test_workload.py` pins the frequency ranks).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

Curve = Union["RateProcess", np.ndarray]


class RateProcess:
    """Base marker: providers expose `materialize(ticks) -> (ticks,)`."""

    def materialize(self, ticks: int) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantRate(RateProcess):
    """Flat open-loop rate — `rate` expected arrivals per tick."""
    rate: float

    def materialize(self, ticks: int) -> np.ndarray:
        assert ticks >= 1, ticks
        return np.full((ticks,), max(self.rate, 0.0), np.float32)


@dataclasses.dataclass(frozen=True)
class DiurnalRate(RateProcess):
    """Sinusoidal day/night curve: ``base * (1 + amplitude*sin(2πt/P))``,
    floored at zero.  `period_ticks` is the diurnal period (defaults to
    the materialized length, one full day per plan)."""
    base: float
    amplitude: float = 0.5
    period_ticks: Optional[int] = None
    phase: float = 0.0

    def materialize(self, ticks: int) -> np.ndarray:
        assert ticks >= 1, ticks
        period = self.period_ticks or ticks
        t = np.arange(ticks, dtype=np.float64)
        curve = self.base * (1.0 + self.amplitude *
                             np.sin(2.0 * np.pi * t / period + self.phase))
        return np.maximum(curve, 0.0).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class FlashCrowd(RateProcess):
    """A base curve with multiplicative burst windows: every
    `every_ticks` ticks the rate jumps to ``mult`` x base for
    `burst_ticks` ticks — the flash-crowd arrival spikes whose queueing
    tail the p95 deadline exists to measure."""
    base: Curve
    mult: float = 8.0
    every_ticks: int = 50
    burst_ticks: int = 5
    offset: int = 0

    def materialize(self, ticks: int) -> np.ndarray:
        assert self.every_ticks >= 1 and self.burst_ticks >= 0
        base = materialize_curve(self.base, ticks)
        t = (np.arange(ticks) - self.offset) % self.every_ticks
        burst = t < self.burst_ticks
        return np.where(burst, base * self.mult, base).astype(np.float32)


def materialize_curve(curve: Curve, ticks: int) -> np.ndarray:
    """A provider or a raw array -> validated (ticks,) float32 curve."""
    if isinstance(curve, RateProcess):
        out = curve.materialize(ticks)
    else:
        out = np.asarray(curve, np.float32)
    assert out.ndim == 1 and out.shape[0] == ticks, \
        f"curve shape {out.shape} != ({ticks},)"
    assert np.all(out >= 0.0), "arrival rates must be non-negative"
    return out.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class OpenLoop:
    """One arrival plan: a write curve + a read curve over `ticks` ticks.

    This is the object `runtime.make_cfg_arrays(arrivals=...)` compiles
    into the `cfg_c` arrival arrays (DESIGN.md §11).  The in-step lookup
    wraps at `ticks` (the plan's own period, a jit argument), so a short
    plan repeats across epochs and `fit_to`-widened copies replay the
    same schedule bit-for-bit.
    """
    write: Curve
    read: Curve
    ticks: int

    def materialize(self) -> Tuple[np.ndarray, np.ndarray]:
        return (materialize_curve(self.write, self.ticks),
                materialize_curve(self.read, self.ticks))

    def scaled(self, write_factor: float = 1.0, read_factor: float = 1.0
               ) -> "OpenLoop":
        """The same schedule at scaled intensity — how one system-wide
        plan divides over Multi-Raft shards (`multiraft.shard_workload`
        factors) while keeping the diurnal/burst *shape* intact."""
        w, r = self.materialize()
        return OpenLoop(write=(w * write_factor).astype(np.float32),
                        read=(r * read_factor).astype(np.float32),
                        ticks=self.ticks)

    def fit_to(self, width: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """(write_curve, read_curve, arrival_len) at a fleet-shared
        `width` >= 1: curves tile cyclically (`np.resize`) and
        `arrival_len = min(self.ticks, width)` keeps the in-step modulo
        lookup on this plan's own columns — the same replay-neutral
        widening rule as `MarketTrace.fit_to` (DESIGN.md §10/§11)."""
        assert width >= 1, width
        w, r = self.materialize()
        return (np.resize(w, width).astype(np.float32),
                np.resize(r, width).astype(np.float32),
                min(self.ticks, width))


@dataclasses.dataclass(frozen=True)
class ZipfianKeys:
    """Zipfian key popularity: P(key=k) ∝ 1/(k+1)^s over the real key
    space, key 0 hottest.  Materializes to the (K,) inclusive CDF the
    leader samples write keys from by inverse transform
    (`step.leader_step`, DESIGN.md §11); matches
    `scipy.stats.zipfian(a=s, n=n_keys)` in distribution."""
    s: float = 1.1

    def materialize(self, n_keys: int, pad_keys: int = 0) -> np.ndarray:
        assert n_keys >= 1, n_keys
        ranks = np.arange(1, n_keys + 1, dtype=np.float64)
        p = ranks ** (-self.s)
        cdf = np.cumsum(p / p.sum())
        cdf[-1] = 1.0
        # padded key-space tail: CDF saturated at 1.0 -> never sampled
        return np.concatenate(
            [cdf, np.ones((pad_keys,))]).astype(np.float32)


def uniform_key_cdf(n_keys: int, pad_keys: int = 0) -> np.ndarray:
    """The inert (K,) CDF closed-loop members carry: uniform over the
    real key space, saturated over the padded tail.  Never *sampled*
    when `cfg_c["key_zipf"]` is off — it exists so the cfg_c pytree has
    one stackable shape per fleet (DESIGN.md §11)."""
    assert n_keys >= 1, n_keys
    cdf = (np.arange(1, n_keys + 1, dtype=np.float64) / n_keys)
    return np.concatenate([cdf, np.ones((pad_keys,))]).astype(np.float32)


def host_poisson_totals(curve: np.ndarray, arrival_len: int, ticks: int,
                        ) -> float:
    """Host-side generator twin for the conservation property test: the
    expected arrival total of an open-loop run of `ticks` ticks is the
    sum of the wrapped curve — `tests/test_workload.py` checks the
    device path's Poisson totals against this within sampling error."""
    curve = np.asarray(curve, np.float64)
    idx = np.arange(ticks) % arrival_len
    return float(curve[idx].sum())
