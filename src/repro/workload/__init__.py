"""Open-loop workload surface (DESIGN.md §11): arrival-rate processes
and key-popularity models that compile to cfg_c jit-argument arrays —
the serving-side twin of the market-trace contract (DESIGN.md §10)."""
from repro.workload.arrivals import (ConstantRate, DiurnalRate, FlashCrowd,
                                     OpenLoop, RateProcess, ZipfianKeys,
                                     host_poisson_totals, materialize_curve,
                                     uniform_key_cdf)

__all__ = [
    "ConstantRate", "DiurnalRate", "FlashCrowd", "OpenLoop", "RateProcess",
    "ZipfianKeys", "host_poisson_totals", "materialize_curve",
    "uniform_key_cdf",
]
