"""Parameter descriptors, initialization, and shared layer math.

The model zoo is deliberately framework-free: a model is (1) a pytree of
`ParamSpec` descriptors built from its config and (2) pure apply
functions.  Descriptors materialize to real arrays (`init_tree`), abstract
ShapeDtypeStructs (`abstract_tree`, used by the dry-run so nothing is ever
allocated), or NamedShardings (`sharding.axes.tree_shardings`).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[Optional[str], ...]      # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float = 1.0


def _is_spec(x):
    return isinstance(x, ParamSpec)


def abstract_tree(tree):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree, is_leaf=_is_spec)


def init_tree(rng, tree, *, mesh=None, shardings=None):
    """Materialize parameters. fan-in scaled normal by default."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for r, p in zip(rngs, leaves):
        if p.init == "zeros":
            a = jnp.zeros(p.shape, p.dtype)
        elif p.init == "ones":
            a = jnp.ones(p.shape, p.dtype)
        else:
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            std = p.scale / math.sqrt(max(fan_in, 1))
            a = (jax.random.normal(r, p.shape, jnp.float32) * std).astype(p.dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def param_count(tree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(tree, is_leaf=_is_spec))


def param_bytes(tree) -> int:
    return sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
               for p in jax.tree.leaves(tree, is_leaf=_is_spec))


# ---------------------------------------------------------------------------
# Shared layer math
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, wg, wu, wd, *, bg=None, bu=None, bd=None):
    g = jnp.einsum("...d,df->...f", x, wg)
    u = jnp.einsum("...d,df->...f", x, wu)
    if bg is not None:
        g = g + bg
        u = u + bu
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("...f,fd->...d", h, wd)
    if bd is not None:
        out = out + bd
    return out


def cross_entropy(logits, labels, vocab_size: int):
    """Mean xent; logits may carry padded vocab entries (masked to -inf)."""
    padded = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if padded != vocab_size:
        mask = jnp.arange(padded) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple
