"""Mixture-of-Experts with explicit expert parallelism under shard_map.

Two production strategies (chosen automatically):

* ``a2a``  — tokens are sequence-sharded across the "model" (EP) axis; each
  device routes its local tokens, packs per-destination capacity buffers and
  exchanges them with ``lax.all_to_all`` (forward + return trip), computes its
  local experts as one batched matmul, and combines locally.  This is the
  DeepSeek/Switch dispatch mapped onto ICI all-to-all; every shape is static,
  all scatters are device-local (no GSPMD scatter fallback).
* ``psum`` — when the token axis cannot shard over the EP axis (decode steps,
  batch=1 long-context), tokens are replicated over "model"; each device
  computes only its local experts' contribution and a single small
  ``psum(T,D)`` combines.  Collective volume is O(T·D), ideal for decode.

A dense reference path (`moe_apply_dense`) computes every expert for every
token and is used as the correctness oracle in tests and for tiny smoke
configs.  Over-capacity tokens drop (standard capacity-factor semantics);
the auxiliary load-balance loss is the Switch formulation.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec


def padded_experts(e: int, multiple: int = 16) -> int:
    return ((e + multiple - 1) // multiple) * multiple


def moe_params(cfg, dtype=jnp.bfloat16):
    D, F = cfg.d_model, cfg.moe_d_ff
    E = padded_experts(cfg.moe_num_experts)
    p = {
        "pre_norm": ParamSpec((D,), jnp.float32, ("unsharded",), "ones"),
        "router": ParamSpec((D, E), jnp.float32, ("embed", "experts")),
        "wg": ParamSpec((E, D, F), dtype, ("experts", "embed", "expert_mlp")),
        "wu": ParamSpec((E, D, F), dtype, ("experts", "embed", "expert_mlp")),
        "wd": ParamSpec((E, F, D), dtype, ("experts", "expert_mlp", "embed")),
    }
    if cfg.moe_shared_d_ff:
        Fs = cfg.moe_shared_d_ff
        p["shared_wg"] = ParamSpec((D, Fs), dtype, ("embed", "shared_mlp"))
        p["shared_wu"] = ParamSpec((D, Fs), dtype, ("embed", "shared_mlp"))
        p["shared_wd"] = ParamSpec((Fs, D), dtype, ("shared_mlp", "embed"))
    return p


def _route(x_flat, router, cfg):
    """x_flat:(T,D) -> top-k (weights (T,k) f32, ids (T,k) i32, aux loss)."""
    E = cfg.moe_num_experts
    logits = (x_flat.astype(jnp.float32) @ router)          # (T, E_pad)
    pad_mask = jnp.arange(logits.shape[-1]) < E
    logits = jnp.where(pad_mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.moe_top_k)            # (T,k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e
    k_onehot = jax.nn.one_hot(ids, logits.shape[-1], dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(k_onehot, axis=1), axis=0)       # tokens per expert
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p) / cfg.moe_top_k
    return w, ids, aux


def _expert_ffn(wg, wu, wd, xb):
    """Batched per-expert SwiGLU. xb:(E_loc, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", xb, wg)
    u = jnp.einsum("ecd,edf->ecf", xb, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _positions_in_bins(bins_onehot):
    """bins_onehot:(N, M) 0/1 -> position of each row within its bin (N,)."""
    cum = jnp.cumsum(bins_onehot, axis=0) * bins_onehot
    return jnp.sum(cum, axis=-1).astype(jnp.int32) - 1


def _moe_local_a2a(x_loc, router, wg, wu, wd, cfg, ep: int, axis: str):
    """shard_map body, tokens sharded over `axis` (size ep)."""
    B, S, D = x_loc.shape
    T = B * S
    k = cfg.moe_top_k
    E_pad = wg.shape[0] * ep
    E_loc = wg.shape[0]
    xf = x_loc.reshape(T, D)
    w, ids, aux = _route(xf, router, cfg)

    # --- pack per-destination send buffers -------------------------------
    cap = int(-(-T * k // ep) * cfg.moe_capacity_factor)
    cap = max(cap, 1)
    flat_ids = ids.reshape(T * k)
    dest = flat_ids // E_loc                                  # (T*k,)
    dest_onehot = jax.nn.one_hot(dest, ep, dtype=jnp.int32)
    pos = _positions_in_bins(dest_onehot)                     # rank within dest
    valid = pos < cap
    # invalid entries park at (ep, cap): out of bounds, dropped by scatter
    d_idx = jnp.where(valid, dest, ep)
    p_idx = jnp.where(valid, pos, cap)
    src_token = jnp.repeat(jnp.arange(T), k)
    send_x = jnp.zeros((ep, cap, D), x_loc.dtype)
    send_x = send_x.at[d_idx, p_idx].set(xf[src_token], mode="drop")
    send_eid = jnp.full((ep, cap), E_loc, jnp.int32)          # E_loc = invalid
    send_eid = send_eid.at[d_idx, p_idx].set(flat_ids % E_loc, mode="drop")

    # --- exchange, local expert compute, exchange back --------------------
    recv_x = jax.lax.all_to_all(send_x, axis, 0, 0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid, axis, 0, 0, tiled=True)

    R = ep * cap
    rx = recv_x.reshape(R, D)
    reid = recv_eid.reshape(R)                                # E_loc marks empty
    eo = jax.nn.one_hot(reid, E_loc, dtype=jnp.int32)         # zero row if empty
    cap2 = int(-(-R // E_loc))
    pos2 = _positions_in_bins(eo)
    ok2 = (pos2 < cap2) & (reid < E_loc)
    e_idx = jnp.where(ok2, reid, E_loc)
    q_idx = jnp.where(ok2, pos2, cap2)
    buf = jnp.zeros((E_loc, cap2, D), x_loc.dtype)
    buf = buf.at[e_idx, q_idx].set(rx, mode="drop")
    buf = _expert_ffn(wg, wu, wd, buf)
    y = jnp.where(ok2[:, None],
                  buf[jnp.where(ok2, reid, 0), jnp.where(ok2, pos2, 0)], 0)
    y_send = jax.lax.all_to_all(y.reshape(ep, cap, D), axis, 0, 0, tiled=True)

    # --- combine ----------------------------------------------------------
    gathered = y_send[jnp.where(valid, dest, 0), jnp.where(valid, pos, 0)]
    gathered = jnp.where(valid[:, None], gathered, 0).reshape(T, k, D)
    out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                     w).astype(x_loc.dtype)
    aux = jax.lax.pmean(aux, axis)
    return out.reshape(B, S, D), aux


def _moe_local_psum(x_rep, router, wg, wu, wd, cfg, ep: int, axis: str):
    """shard_map body, tokens replicated over `axis`; local experts only."""
    B, S, D = x_rep.shape
    T = B * S
    E_loc = wg.shape[0]
    my = jax.lax.axis_index(axis)
    xf = x_rep.reshape(T, D)
    w, ids, aux = _route(xf, router, cfg)
    local = ids // E_loc == my                               # (T,k) mine?
    lids = jnp.where(local, ids % E_loc, E_loc)
    eo = jax.nn.one_hot(lids.reshape(-1), E_loc, dtype=jnp.int32)
    cap = max(int(-(-T * cfg.moe_top_k // max(E_loc, 1)) *
                  cfg.moe_capacity_factor), 1)
    pos = _positions_in_bins(eo)
    ok = (pos < cap) & local.reshape(-1)
    src = jnp.repeat(jnp.arange(T), cfg.moe_top_k)
    eidx = jnp.where(ok, lids.reshape(-1), E_loc)            # park invalid OOB
    pidx = jnp.where(ok, pos, cap)
    buf = jnp.zeros((E_loc, cap, D), x_rep.dtype)
    buf = buf.at[eidx, pidx].set(xf[src], mode="drop")
    buf = _expert_ffn(wg, wu, wd, buf)
    y = jnp.where(ok[:, None], buf[eidx, pidx], 0).reshape(T, cfg.moe_top_k, D)
    out = jnp.einsum("tkd,tk->td", y.astype(jnp.float32),
                     jnp.where(local, w, 0)).astype(x_rep.dtype)
    out = jax.lax.psum(out, axis)
    aux = jax.lax.pmean(aux, axis)
    return out.reshape(B, S, D), aux


def moe_apply(p, x, cfg, mesh, *, ep_axis: str = "model",
              dp_axes: Tuple[str, ...] = ("pod", "data")):
    """Production MoE layer. x:(B,S,D) -> (y, aux_loss)."""
    from jax import shard_map

    if mesh is None or ep_axis not in mesh.shape:
        return moe_apply_dense(p, x, cfg)
    ep = mesh.shape[ep_axis]
    dp = tuple(a for a in dp_axes if a in mesh.shape)
    B, S, D = x.shape
    batch_div = B % max(1, _extent(mesh, dp)) == 0
    bspec = dp if batch_div and dp else None
    if ep == 1:
        y, aux = moe_apply_dense(p, x, cfg)
        return y, aux

    wspecs = (P(), P(ep_axis), P(ep_axis), P(ep_axis))
    if S % ep == 0:
        body = functools.partial(_moe_local_a2a, cfg=cfg, ep=ep, axis=ep_axis)
        xspec = P(bspec, ep_axis, None)
    else:
        body = functools.partial(_moe_local_psum, cfg=cfg, ep=ep, axis=ep_axis)
        xspec = P(bspec, None, None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(xspec,) + wspecs,
                   out_specs=(xspec, P()),
                   check_vma=False)
    y, aux = fn(x, p["router"], p["wg"], p["wu"], p["wd"])
    if "shared_wg" in p:
        from repro.models.common import swiglu
        y = y + swiglu(x, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y, aux


def _extent(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def moe_apply_dense(p, x, cfg):
    """Oracle: every expert on every token, masked combine. O(E·T·D·F)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    w, ids, aux = _route(xf, p["router"], cfg)
    E_pad = p["wg"].shape[0]
    comb = jnp.zeros((xf.shape[0], E_pad), jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], ids].add(w)
    g = jnp.einsum("td,edf->tef", xf, p["wg"])
    u = jnp.einsum("td,edf->tef", xf, p["wu"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, p["wd"])
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), comb)
    y = y.astype(x.dtype).reshape(B, S, D)
    if "shared_wg" in p:
        from repro.models.common import swiglu
        y = y + swiglu(x, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y, aux
