"""Attention: GQA self/cross attention with full, chunked (online-softmax,
flash-style) and decode (sequence-sharded KV cache) paths — pure JAX.

The chunked path is the XLA analogue of the Pallas `flash_attention`
kernel in `repro.kernels`: it never materializes the S×S score matrix.
With ``unroll=True`` the chunk loops become Python loops and causally
masked-out (q,k) chunk pairs are skipped entirely — that variant is what
the roofline harness lowers (exact FLOPs, no scan undercount); the
``lax.scan`` variant is what the dry-run compiles (compile-time friendly).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm, apply_rope

NEG_INF = -1e30


def attention_params(cfg, *, cross: bool = False, dtype=jnp.bfloat16):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": ParamSpec((D, H, hd), dtype, ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, KV, hd), dtype, ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, KV, hd), dtype, ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), dtype, ("heads", "head_dim", "embed")),
        "pre_norm": ParamSpec((D,), jnp.float32, ("unsharded",), "ones"),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((H, hd), dtype, ("heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((KV, hd), dtype, ("kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((KV, hd), dtype, ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), jnp.float32, ("unsharded",), "ones")
        p["k_norm"] = ParamSpec((hd,), jnp.float32, ("unsharded",), "ones")
    return p


def _project_qkv(p, x, ctx, cfg, positions, ctx_positions, *, rope: bool):
    """x:(B,S,D) -> q:(B,S,H,hd); ctx:(B,T,D) -> k,v:(B,T,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", ctx, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", ctx, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, ctx_positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, num_heads: int):
    """(B,T,KV,hd) -> (B,T,H,hd). XLA lowers to a broadcast-gather; with H
    sharded on "model" each device materializes only its head slice."""
    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


# ---------------------------------------------------------------------------
# Core softmax-attention paths (all take q:(B,S,H,hd), k/v:(B,T,H,hd))
# ---------------------------------------------------------------------------

def full_attention(q, k, v, *, q_pos=None, k_pos=None, causal=True):
    """Materializes (B,H,S,T) scores — short-sequence / decode path."""
    hd = q.shape[-1]
    s = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) / (hd ** 0.5)
    if q_pos is not None:
        mask = k_pos[:, None, :] <= q_pos[:, :, None] if causal else \
            jnp.ones((1, q.shape[1], k.shape[1]), bool)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    elif causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((S, T), bool), T - S)
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthk->bshk", p.astype(q.dtype), v)


def _chunk_update(q, kc, vc, m, l, acc, smask, acc_dtype=jnp.float32):
    """One online-softmax update. q:(B,S,H,hd), kc/vc:(B,ck,H,hd),
    smask:(B,S,ck) bool or None. m/l/acc carries stay fp32; with
    acc_dtype=bf16 the (B,H,S,ck) score/exp intermediates are bf16
    (halves the dominant memory-roofline bytes; ~1e-2 logit noise)."""
    hd = q.shape[-1]
    s = jnp.einsum("bshk,bthk->bhst", q, kc).astype(jnp.float32) / (hd ** 0.5)
    if smask is not None:
        s = jnp.where(smask[:, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    corr = jnp.exp(m - m_new)
    e = jnp.exp((s - m_new[..., None]).astype(acc_dtype)
                .astype(jnp.float32)).astype(acc_dtype)
    l_new = l * corr + jnp.sum(e, axis=-1, dtype=jnp.float32)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhst,bthk->bhsk", e, vc.astype(acc_dtype)).astype(jnp.float32)
    return m_new, l_new, acc_new


def chunked_attention(q, k, v, *, q_pos, k_pos, causal=True,
                      chunk_k=2048, unroll=False, acc_dtype=jnp.float32):
    """Flash-style attention, scanning KV chunks with a running softmax.

    unroll=False: lax.scan over all KV chunks with masks (dry-run path).
    unroll=True : Python loop; fully-masked chunks are skipped statically
                  when positions are statically known (roofline path).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    ck = min(chunk_k, T)
    nk = (T + ck - 1) // ck
    Tp = nk * ck
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, Tp - T)), constant_values=2**30)

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd), jnp.float32)

    def smask_for(kp):
        if causal:
            return kp[:, None, :] <= q_pos[:, :, None]
        # non-causal: only exclude padded key slots
        return jnp.broadcast_to((kp < 2**30)[:, None, :],
                                (B, S, kp.shape[1]))

    if unroll:
        m, l, acc = m0, l0, a0
        import numpy as np
        qp = np.asarray(q_pos) if isinstance(q_pos, (np.ndarray,)) else None
        for i in range(nk):
            kc = jax.lax.slice_in_dim(k, i * ck, (i + 1) * ck, axis=1)
            vc = jax.lax.slice_in_dim(v, i * ck, (i + 1) * ck, axis=1)
            kp = jax.lax.slice_in_dim(k_pos, i * ck, (i + 1) * ck, axis=1)
            m, l, acc = _chunk_update(q, kc, vc, m, l, acc, smask_for(kp),
                                      acc_dtype)
    else:
        ks = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
        vs = v.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
        kps = k_pos.reshape(B, nk, ck).transpose(1, 0, 2)

        def body(carry, xs):
            m, l, acc = carry
            kc, vc, kp = xs
            m, l, acc = _chunk_update(q, kc, vc, m, l, acc, smask_for(kp),
                                      acc_dtype)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))

    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,S,H,hd)


def causal_blocked_attention(q, k, v, *, chunk_q=2048, chunk_k=2048,
                             unroll=False, acc_dtype=jnp.float32):
    """Self-attention over aligned q/k (prefill, training): q chunked too so
    the unrolled path skips future (fully masked) KV blocks — ~2× FLOPs saved
    vs. the rectangle. Used when q and k cover the same [0,S) positions."""
    B, S, H, hd = q.shape
    if not unroll:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return chunked_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                                 chunk_k=chunk_k, unroll=False,
                                 acc_dtype=acc_dtype)
    cq = min(chunk_q, S)
    nq = (S + cq - 1) // cq
    outs = []
    for i in range(nq):
        qi = jax.lax.slice_in_dim(q, i * cq, (i + 1) * cq, axis=1)
        hi = (i + 1) * cq                      # causal horizon for this block
        ki = jax.lax.slice_in_dim(k, 0, hi, axis=1)
        vi = jax.lax.slice_in_dim(v, 0, hi, axis=1)
        qp = jnp.broadcast_to(jnp.arange(i * cq, i * cq + qi.shape[1])[None],
                              (B, qi.shape[1]))
        kp = jnp.broadcast_to(jnp.arange(hi)[None], (B, hi))
        outs.append(chunked_attention(qi, ki, vi, q_pos=qp, k_pos=kp,
                                      causal=True, chunk_k=chunk_k,
                                      unroll=True, acc_dtype=acc_dtype))
    return jnp.concatenate(outs, axis=1)


def decode_attention(q, k_cache, v_cache, cache_len, cn=None):
    """q:(B,1,H,hd); caches:(B,T,KV,hd) (seq-shardable). Partial-softmax over
    the sharded T axis — GSPMD inserts small all-reduces (flash-decode).
    cn pins the repeated K/V to the cache's sequence sharding — without it
    the einsum partitioner reshards the whole cache to head-sharded every
    layer (measured 328 ms collective term vs 62 ms memory, EXPERIMENTS §Perf
    cell 3)."""
    B, _, H, hd = q.shape
    T = k_cache.shape[1]
    k = repeat_kv(k_cache, H)
    v = repeat_kv(v_cache, H)
    if cn is not None:
        k = cn(k, "batch", "kv_seq", None, "head_dim")
        v = cn(v, "batch", "kv_seq", None, "head_dim")
    s = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) / (hd ** 0.5)
    valid = (jnp.arange(T)[None] < cache_len[:, None])[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthk->bshk", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
