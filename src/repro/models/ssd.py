"""Mamba2-style SSD (state-space duality) block — chunked scan, pure JAX.

Faithful to the SSD formulation of arXiv:2405.21060: per-head scalar decay
``a_t = exp(-softplus(dt) * exp(A_log))``, rank-1 state updates
``h_t = a_t h_{t-1} + dt_t (B_t ⊗ x_t)`` with shared (G=1) B/C projections,
computed chunk-parallel: quadratic attention-like term inside chunks of Q
tokens plus a sequential inter-chunk state recurrence.  ``unroll=True``
turns the chunk recurrence into a Python loop (roofline path).

Jamba note (DESIGN.md §3): Jamba-1.5 uses Mamba-1 internals; we adapt both
assigned SSM archs to the SSD formulation, which is the TPU-native choice
(MXU-friendly chunk matmuls instead of elementwise scans).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm


def ssd_params(cfg, dtype=jnp.bfloat16):
    D = cfg.d_model
    DI = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    W = cfg.ssm_conv
    return {
        "wz": ParamSpec((D, DI), dtype, ("embed", "ssm_inner")),
        "wx": ParamSpec((D, DI), dtype, ("embed", "ssm_inner")),
        "wB": ParamSpec((D, N), dtype, ("embed", "ssm_state")),
        "wC": ParamSpec((D, N), dtype, ("embed", "ssm_state")),
        "wdt": ParamSpec((D, H), dtype, ("embed", "ssm_heads")),
        "conv_x": ParamSpec((W, DI), dtype, ("conv", "ssm_inner"), "normal", 0.5),
        "conv_B": ParamSpec((W, N), dtype, ("conv", "ssm_state"), "normal", 0.5),
        "conv_C": ParamSpec((W, N), dtype, ("conv", "ssm_state"), "normal", 0.5),
        "A_log": ParamSpec((H,), jnp.float32, ("ssm_heads",), "zeros"),
        "D_skip": ParamSpec((H,), jnp.float32, ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((H,), jnp.float32, ("ssm_heads",), "zeros"),
        "gate_norm": ParamSpec((DI,), jnp.float32, ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((DI, D), dtype, ("ssm_inner", "embed")),
        "pre_norm": ParamSpec((D,), jnp.float32, ("unsharded",), "ones"),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x:(B,S,C), w:(W,C). state:(B,W-1,C) or None.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, xp.shape[1] - (W - 1):, :]
    return y, new_state


def _project(p, x, cfg):
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    return z, xs, Bm, Cm, dt


def ssd_apply(p, x, cfg, *, unroll: bool = False, cn=None):
    """Training/prefill path. x:(B,S,D) -> (y:(B,S,D), final_state).

    cn: optional logical-axis constrainer — shards the SSD head dim so the
    (B,nc,Q,Q,H) intra-chunk decay tensor tiles over the "model" axis."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    if cn is None:
        cn = lambda t, *a: t
    S_pad = -(-S // Q) * Q

    z, xs, Bm, Cm, dt = _project(p, x, cfg)
    xs, conv_x_st = _causal_conv(xs, p["conv_x"])
    Bm, conv_B_st = _causal_conv(Bm, p["conv_B"])
    Cm, conv_C_st = _causal_conv(Cm, p["conv_C"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    Bm = jax.nn.silu(Bm.astype(jnp.float32)).astype(x.dtype)
    Cm = jax.nn.silu(Cm.astype(jnp.float32)).astype(x.dtype)
    S_orig = S
    if S_pad != S:
        # pad the tail AFTER projection with dt=0: padded steps are exact
        # no-ops in the recurrence (a=exp(0)=1, update dt·Bx=0)
        pad = ((0, 0), (0, S_pad - S), (0, 0))
        xs, Bm, Cm = (jnp.pad(t, pad) for t in (xs, Bm, Cm))
        dt = jnp.pad(dt, pad)
        S = S_pad
    nc = S // Q

    xh = xs.reshape(B, nc, Q, H, P)
    xh = cn(xh, "batch", None, None, "ssm_heads", None)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)
    dtc = dt.reshape(B, nc, Q, H)
    dtc = cn(dtc, "batch", None, None, "ssm_heads")
    loga = (-jnp.exp(p["A_log"]) * dtc)                      # (B,nc,Q,H) f32
    cs = jnp.cumsum(loga, axis=2)                             # within-chunk
    cs = cn(cs, "batch", None, None, "ssm_heads")

    # intra-chunk (diagonal) term: decay L[i,j] = exp(cs_i - cs_j + loga_j?)
    # h contribution of step j to output i (i>=j): exp(cs_i - cs_j) * dt_j
    Lij = cs[:, :, :, None, :] - cs[:, :, None, :, :]         # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Ldec = jnp.where(tri[None, None, :, :, None], jnp.exp(Lij), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (B,nc,Q,Q)
    w_ij = scores[..., None] * Ldec * dtc[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w_ij.astype(x.dtype), xh)

    # chunk summary states: s_c = sum_j exp(cs_Q - cs_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)             # (B,nc,Q,H)
    wB = (Bc[..., None, :] * (decay_to_end * dtc)[..., :, None])  # (B,nc,Q,H,N)
    s_chunk = jnp.einsum("bcqhn,bcqhp->bchpn", wB.astype(x.dtype), xh)

    # inter-chunk recurrence over running state
    chunk_decay = jnp.exp(cs[:, :, -1, :])                    # (B,nc,H)
    h0 = jnp.zeros((B, H, P, N), jnp.float32)

    if unroll:
        hs = []
        h = h0
        for c in range(nc):
            hs.append(h)
            h = (h * chunk_decay[:, c, :, None, None]
                 + s_chunk[:, c].astype(jnp.float32))
        h_prev = jnp.stack(hs, axis=1)                        # (B,nc,H,P,N)
        h_last = h
    else:
        def body(h, inp):
            dec, sc = inp
            h_new = h * dec[:, :, None, None] + sc.astype(jnp.float32)
            return h_new, h
        (h_last, h_prev) = jax.lax.scan(
            body, h0, (chunk_decay.transpose(1, 0, 2),
                       s_chunk.transpose(1, 0, 2, 3, 4)))
        h_prev = h_prev.transpose(1, 0, 2, 3, 4)

    # off-diagonal term: y_off_i = exp(cs_i) * C_i . h_prev
    decay_in = jnp.exp(cs)                                    # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn->bcqhp",
                       Cc.astype(jnp.float32), h_prev)
    y_off = y_off * decay_in[..., None]

    y = y_diag.astype(jnp.float32) + y_off
    y = y + xh.astype(jnp.float32) * p["D_skip"][None, None, None, :, None]
    y = y.reshape(B, S, H * P)[:, :S_orig]
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    state = {"ssm": h_last, "conv_x": conv_x_st.astype(x.dtype),
             "conv_B": conv_B_st.astype(x.dtype),
             "conv_C": conv_C_st.astype(x.dtype)}
    return out, state


def ssd_init_cache(cfg, batch: int, dtype=jnp.bfloat16):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W, DI = cfg.ssm_conv, cfg.d_inner
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, W - 1, DI), dtype),
        "conv_B": jnp.zeros((batch, W - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, W - 1, cfg.ssm_state), dtype),
    }


def ssd_decode(p, x, cache, cfg):
    """Single-token step. x:(B,1,D), cache from ssd_init_cache."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, Bm, Cm, dt = _project(p, x, cfg)
    xs, cx = _causal_conv(xs, p["conv_x"], cache["conv_x"])
    Bm, cb = _causal_conv(Bm, p["conv_B"], cache["conv_B"])
    Cm, cc = _causal_conv(Cm, p["conv_C"], cache["conv_C"])
    xs = jax.nn.silu(xs.astype(jnp.float32))[:, 0]            # (B,DI)
    Bm = jax.nn.silu(Bm.astype(jnp.float32))[:, 0]            # (B,N)
    Cm = jax.nn.silu(Cm.astype(jnp.float32))[:, 0]
    dt = dt[:, 0]                                             # (B,H)
    xh = xs.reshape(B, H, P)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                    # (B,H)
    upd = (dt[..., None] * xh)[..., None] * Bm[:, None, None, :]  # (B,H,P,N)
    h = cache["ssm"] * a[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cm)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(B, 1, H * P)
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"ssm": h, "conv_x": cx, "conv_B": cb, "conv_C": cc}


def ssd_reference(p, x, cfg):
    """Sequential per-token oracle (O(S) scan) for tests."""
    B, S, D = x.shape
    cache = ssd_init_cache(cfg, B, x.dtype)
    ys = []
    for t in range(S):
        y, cache = ssd_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
