"""Model assembly: heterogeneous layer stacks (dense / MoE / SSD / hybrid /
VLM cross-attention / encoder-decoder) with scan-over-layers.

Layers are grouped by the config's repeating *period* P: position r in
[0,P) determines the layer kind (mixer = attn|ssd, ffn = mlp|moe, optional
cross-attention), and all L/P layers sharing a position are stacked on a
leading "groups" axis so the whole stack runs under one `lax.scan`
(compile-time O(P), not O(L)).  With ``runcfg.scan_layers=False`` the stack
unrolls (the roofline path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssd as ssd_mod
from repro.models.common import (ParamSpec, cross_entropy, rms_norm, swiglu)


class LayerKind(NamedTuple):
    mixer: str          # "attn" | "ssd"
    ffn: str            # "mlp" | "moe" | "none"
    cross: bool = False


def layer_kinds(cfg) -> Tuple[LayerKind, ...]:
    P = cfg.layer_period
    kinds = []
    for r in range(P):
        mixer = "attn" if cfg.is_attn_layer(r) else "ssd"
        ffn = "moe" if cfg.is_moe_layer(r) else ("mlp" if cfg.d_ff else "none")
        kinds.append(LayerKind(mixer, ffn, cfg.is_cross_attn_layer(r)))
    return tuple(kinds)


# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------

def mlp_params(cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wg": ParamSpec((D, F), dtype, ("embed", "mlp")),
        "wu": ParamSpec((D, F), dtype, ("embed", "mlp")),
        "wd": ParamSpec((F, D), dtype, ("mlp", "embed")),
        "pre_norm": ParamSpec((D,), jnp.float32, ("unsharded",), "ones"),
    }


def block_params(cfg, kind: LayerKind, dtype):
    p: Dict[str, Any] = {}
    if kind.mixer == "attn":
        p["attn"] = attn_mod.attention_params(cfg, dtype=dtype)
    else:
        p["ssd"] = ssd_mod.ssd_params(cfg, dtype)
    if kind.cross:
        p["xattn"] = attn_mod.attention_params(cfg, cross=True, dtype=dtype)
        p["xattn_gate"] = ParamSpec((1,), jnp.float32, ("unsharded",), "zeros")
    if kind.ffn == "mlp":
        p["mlp"] = mlp_params(cfg, dtype)
    elif kind.ffn == "moe":
        p["moe"] = moe_mod.moe_params(cfg, dtype)
    return p


def _stack(tree, n: int):
    return jax.tree.map(
        lambda ps: ParamSpec((n,) + ps.shape, ps.dtype, ("layers",) + ps.axes,
                             ps.init, ps.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def build_param_specs(cfg, dtype=jnp.bfloat16):
    D, Vp = cfg.d_model, cfg.padded_vocab
    kinds = layer_kinds(cfg)
    P = len(kinds)
    assert cfg.num_layers % P == 0, (cfg.name, cfg.num_layers, P)
    G = cfg.num_layers // P
    params: Dict[str, Any] = {
        "embed": ParamSpec((Vp, D), dtype, ("vocab", "embed"), "normal"),
        "final_norm": ParamSpec((D,), jnp.float32, ("unsharded",), "ones"),
        "blocks": {f"r{r}": _stack(block_params(cfg, k, dtype), G)
                   for r, k in enumerate(kinds)},
    }
    if not cfg.tie_embeddings:
        params["head"] = ParamSpec((D, Vp), dtype, ("embed", "vocab"))
    if cfg.encoder_layers:
        enc_kind = LayerKind("attn", "mlp", False)
        params["encoder"] = {
            "blocks": {"r0": _stack(block_params(cfg, enc_kind, dtype),
                                    cfg.encoder_layers)},
            "final_norm": ParamSpec((D,), jnp.float32, ("unsharded",), "ones"),
        }
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _attn_mixer(p, h, cfg, cn, runcfg, *, mode, cache, positions, causal=True,
                ctx=None, ctx_positions=None, rope=True, cache_len=None):
    """Self- or cross-attention mixer. Returns (h, new_cache)."""
    x = rms_norm(h, p["pre_norm"], cfg.norm_eps)
    src = x if ctx is None else ctx
    q, k, v = attn_mod._project_qkv(p, x, src, cfg, positions,
                                    ctx_positions if ctx is not None
                                    else positions, rope=rope)
    q = cn(q, "batch", "seq", "heads", "head_dim")
    new_cache = cache
    if mode == "decode" and ctx is None:
        B = h.shape[0]
        ck, cv = cache["k"], cache["v"]
        # one-hot masked insert: elementwise over the (possibly sequence-
        # sharded) cache, so GSPMD never sees a scatter on a sharded dim
        hit = (jnp.arange(ck.shape[1])[None, :] ==
               cache_len[:, None])[..., None, None]
        ck = jnp.where(hit, k[:, :1], ck)
        cv = jnp.where(hit, v[:, :1], cv)
        ck = cn(ck, "batch", "kv_seq", "kv_heads", "head_dim")
        cv = cn(cv, "batch", "kv_seq", "kv_heads", "head_dim")
        o = attn_mod.decode_attention(q, ck, cv, cache_len + 1, cn=cn)
        new_cache = {"k": ck, "v": cv}
    elif mode == "decode":                                   # cross, cached
        o = attn_mod.decode_attention(q, cache["k"], cache["v"], cache["len"])
    else:
        kk = attn_mod.repeat_kv(k, cfg.num_heads)
        vv = attn_mod.repeat_kv(v, cfg.num_heads)
        kk = cn(kk, "batch", "seq", "heads", "head_dim")
        vv = cn(vv, "batch", "seq", "heads", "head_dim")
        if ctx is None and causal and runcfg.attention_impl == "pallas":
            from repro.kernels.flash_attention.ops import flash_attention
            o = flash_attention(q, kk, vv,
                                block_q=min(runcfg.attn_chunk_q, 128),
                                block_k=min(runcfg.attn_chunk_k, 128))
        elif ctx is None and causal:
            o = attn_mod.causal_blocked_attention(
                q, kk, vv, chunk_q=runcfg.attn_chunk_q,
                chunk_k=runcfg.attn_chunk_k, unroll=runcfg.unroll_attn,
                acc_dtype=jnp.dtype(runcfg.attn_acc_dtype))
        elif q.shape[1] * kk.shape[1] > 2 ** 22:
            # large non-causal (32k encoder self-attn / long cross-attn):
            # flash-style chunking, never materialize (S,T) scores
            B, Sq = q.shape[:2]
            T = kk.shape[1]
            qp = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
            kp = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
            o = attn_mod.chunked_attention(
                q, kk, vv, q_pos=qp, k_pos=kp, causal=False,
                chunk_k=runcfg.attn_chunk_k, unroll=runcfg.unroll_attn,
                acc_dtype=jnp.dtype(runcfg.attn_acc_dtype))
        else:
            o = attn_mod.full_attention(q, kk, vv, causal=False)
        if mode == "prefill":
            new_cache = {"k": cn(k, "batch", "kv_seq", "kv_heads", "head_dim"),
                         "v": cn(v, "batch", "kv_seq", "kv_heads", "head_dim")}
    o = cn(o, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "gate" in p:
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out, new_cache


def apply_block(kind: LayerKind, p, h, cfg, runcfg, mesh, cn, *,
                mode, cache, positions, img_ctx=None, cache_len=None):
    """One layer. cache is a dict (possibly with dummy leaves). Returns
    (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache else {}

    if kind.mixer == "attn":
        o, nc = _attn_mixer(p["attn"], h, cfg, cn, runcfg, mode=mode,
                            cache=cache.get("self") if cache else None,
                            positions=positions, cache_len=cache_len)
        h = cn(h + o, "batch", "seq", "embed_tp")
        if mode in ("prefill", "decode"):
            new_cache["self"] = nc
    else:
        x = rms_norm(h, p["ssd"]["pre_norm"], cfg.norm_eps)
        if mode == "decode":
            o, st = ssd_mod.ssd_decode(p["ssd"], x, cache["ssm"], cfg)
            new_cache["ssm"] = st
        else:
            o, st = ssd_mod.ssd_apply(p["ssd"], x, cfg,
                                      unroll=not runcfg.scan_layers, cn=cn)
            if mode == "prefill":
                new_cache["ssm"] = st
        h = cn(h + o, "batch", "seq", "embed_tp")

    if kind.cross:
        xp = dict(p["xattn"])
        xp["gate"] = p["xattn_gate"]
        if mode == "decode":
            xc = cache["cross"]
            x = rms_norm(h, xp["pre_norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", x, xp["wq"])
            if "bq" in xp:
                q = q + xp["bq"]
            o = attn_mod.decode_attention(q, xc["k"], xc["v"], xc["len"])
            o = jnp.einsum("bshk,hkd->bsd", o, xp["wo"])
            o = o * jnp.tanh(xp["gate"]).astype(o.dtype)
            new_cache["cross"] = xc
        else:
            octx = img_ctx
            o, _ = _attn_mixer(xp, h, cfg, cn, runcfg, mode="train",
                               cache=None, positions=positions, ctx=octx,
                               causal=False, rope=False)
            if mode == "prefill":
                k = jnp.einsum("btd,dhk->bthk", octx, xp["wk"])
                v = jnp.einsum("btd,dhk->bthk", octx, xp["wv"])
                new_cache["cross"] = {
                    "k": k, "v": v,
                    "len": jnp.full((h.shape[0],), octx.shape[1], jnp.int32)}
        h = cn(h + o, "batch", "seq", "embed_tp")

    if kind.ffn == "mlp":
        x = rms_norm(h, p["mlp"]["pre_norm"], cfg.norm_eps)
        x = swiglu(x, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
        h = cn(h + x, "batch", "seq", "embed_tp")
    elif kind.ffn == "moe":
        x = rms_norm(h, p["moe"]["pre_norm"], cfg.norm_eps)
        y, a = moe_mod.moe_apply(p["moe"], x, cfg, mesh)
        aux = aux + a
        h = cn(h + y, "batch", "seq", "embed_tp")
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Stack runner (scan / unrolled)
# ---------------------------------------------------------------------------

def run_stack(blocks, kinds, h, cfg, runcfg, mesh, cn, *, mode, caches,
              positions, img_ctx=None, cache_len=None, use_shardings=None):
    """Apply all num_layers layers. blocks[f"r{r}"] leaves have leading G.
    caches: same structure (leading G) for decode, None otherwise.
    Returns (h, new_caches_or_None, aux)."""
    P = len(kinds)
    G = cfg.num_layers // P

    def one_block(r, kind, h, bp_r, c_r):
        return apply_block(kind, bp_r, h, cfg, runcfg, mesh, cn, mode=mode,
                           cache=c_r, positions=positions, img_ctx=img_ctx,
                           cache_len=cache_len)

    def period_body(h, bp, cc):
        aux = jnp.zeros((), jnp.float32)
        new_cc = {}
        for r, kind in enumerate(kinds):
            c_r = cc.get(f"r{r}") if cc is not None else None
            bp_r = bp[f"r{r}"]
            if use_shardings is not None:
                # ZeRO-3 unshard-at-use: all-gather this layer's weights
                # (small) instead of letting GSPMD all-reduce activations
                bp_r = jax.tree.map(jax.lax.with_sharding_constraint,
                                    bp_r, use_shardings[f"r{r}"])
            bp = dict(bp, **{f"r{r}": bp_r})
            blk = functools.partial(one_block, r, kind)
            if runcfg.remat and mode == "train" and \
                    runcfg.remat_policy == "block":
                blk = jax.checkpoint(blk)
            h, nc, a = blk(h, bp[f"r{r}"], c_r)
            new_cc[f"r{r}"] = nc
            aux = aux + a
        return h, new_cc, aux

    # Default remat wraps the whole repeating period: measured 31.4GB vs
    # 50.9GB temp for per-block remat on vision-90b train (EXPERIMENTS §Perf)
    if runcfg.remat and mode == "train" and runcfg.remat_policy != "block":
        period_body = jax.checkpoint(period_body)

    if not runcfg.scan_layers or G == 1:
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        body = period_body
        for g in range(G):
            bp = jax.tree.map(lambda a: a[g], blocks)
            cc = (jax.tree.map(lambda a: a[g], caches)
                  if caches is not None else None)
            h, nc, a = body(h, bp, cc)
            new_caches.append(nc)
            aux = aux + a
        out_caches = None
        if mode in ("prefill", "decode"):
            out_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        return h, out_caches, aux

    if mode == "decode":
        def body(carry, xs):
            h, aux = carry
            bp, cc = xs
            h, nc, a = period_body(h, bp, cc)
            return (h, aux + a), nc
        (h, aux), new_caches = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), (blocks, caches))
        return h, new_caches, aux

    def body(carry, bp):
        h, aux = carry
        h, nc, a = period_body(h, bp, None if mode == "train" else {})
        y = nc if mode == "prefill" else 0.0
        return (h, aux + a), y

    (h, aux), ys = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), blocks)
    new_caches = ys if mode == "prefill" else None
    return h, new_caches, aux


# ---------------------------------------------------------------------------
# Whole-model forward
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, cn):
    h = jnp.take(params["embed"], tokens, axis=0)
    return cn(h, "batch", "seq", "embed_tp")


def _unembed(params, h, cfg, cn):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return cn(logits, "batch", "seq", "vocab")


def encode(params, frames, cfg, runcfg, mesh, cn):
    """Encoder stack over stub frontend embeddings (B,S,D)."""
    kinds = (LayerKind("attn", "mlp", False),)
    h = cn(frames, "batch", "seq", "embed_tp")
    enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                                  attn_layer_period=0, moe_num_experts=0,
                                  cross_attn_period=0)

    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def noncausal_block(h, bp):
        o, _ = _attn_mixer(bp["attn"], h, enc_cfg, cn, runcfg, mode="train",
                           cache=None, positions=pos, causal=False)
        h = cn(h + o, "batch", "seq", "embed_tp")
        x = rms_norm(h, bp["mlp"]["pre_norm"], enc_cfg.norm_eps)
        x = swiglu(x, bp["mlp"]["wg"], bp["mlp"]["wu"], bp["mlp"]["wd"])
        return cn(h + x, "batch", "seq", "embed_tp")

    blocks = params["encoder"]["blocks"]["r0"]
    if runcfg.scan_layers and cfg.encoder_layers > 1:
        def body(h, bp):
            f = noncausal_block
            if runcfg.remat:
                f = jax.checkpoint(noncausal_block)
            return f(h, bp), 0.0
        h, _ = jax.lax.scan(body, h, blocks)
    else:
        for g in range(cfg.encoder_layers):
            h = noncausal_block(h, jax.tree.map(lambda a: a[g], blocks))
    return rms_norm(h, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params, tokens, cfg, runcfg, mesh, rules, *, mode,
            caches=None, img_embeds=None, frames=None, cache_len=None):
    """tokens: (B,S) int32.  Returns (logits, new_caches, aux)."""
    from repro.sharding.axes import make_constrainer
    cn = make_constrainer(rules, mesh)
    kinds = layer_kinds(cfg)

    ctx = None
    if cfg.encoder_layers and frames is not None:
        ctx = encode(params, frames, cfg, runcfg, mesh, cn)
    elif img_embeds is not None:
        ctx = cn(img_embeds, "batch", "img_seq", "embed_tp")

    use_shardings = None
    if runcfg.zero3_at_use and mesh is not None and "data" in mesh.shape:
        from repro.sharding.axes import tree_shardings
        use_rules = dict(rules)
        use_rules["embed"] = None            # weights gather over "data"
        use_shardings = {
            f"r{r}": jax.tree.map(
                lambda ns: ns,
                tree_shardings(block_params(cfg, k,
                                            params["embed"].dtype),
                               use_rules, mesh))
            for r, k in enumerate(layer_kinds(cfg))}

    B, S = tokens.shape
    if mode == "decode":
        positions = cache_len[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    h = _embed(params, tokens, cfg, cn)
    h, new_caches, aux = run_stack(params["blocks"], kinds, h, cfg, runcfg,
                                   mesh, cn, mode=mode, caches=caches,
                                   positions=positions, img_ctx=ctx,
                                   cache_len=cache_len,
                                   use_shardings=use_shardings)
    logits = _unembed(params, h, cfg, cn)
    return logits, new_caches, aux


def loss_fn(params, batch, cfg, runcfg, mesh, rules):
    """Next-token xent (+ MoE aux). batch: tokens, labels[, img/frames]."""
    logits, _, aux = forward(
        params, batch["tokens"], cfg, runcfg, mesh, rules, mode="train",
        img_embeds=batch.get("img_embeds"), frames=batch.get("frames"))
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return loss + 0.01 * aux, (loss, aux)


# ---------------------------------------------------------------------------
# Cache construction (abstract or concrete via like=)
# ---------------------------------------------------------------------------

def cache_specs(cfg, batch: int, cache_cap: int, dtype=jnp.bfloat16):
    """ParamSpec tree for decode caches (leading G per position)."""
    kinds = layer_kinds(cfg)
    G = cfg.num_layers // len(kinds)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def stack(spec_tree):
        return jax.tree.map(
            lambda ps: ParamSpec((G,) + ps.shape, ps.dtype,
                                 ("layers",) + ps.axes, "zeros"),
            spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))

    out = {}
    for r, kind in enumerate(kinds):
        c = {}
        if kind.mixer == "attn":
            c["self"] = {
                "k": ParamSpec((batch, cache_cap, KV, hd), dtype,
                               ("batch", "kv_seq", "kv_heads", "head_dim"),
                               "zeros"),
                "v": ParamSpec((batch, cache_cap, KV, hd), dtype,
                               ("batch", "kv_seq", "kv_heads", "head_dim"),
                               "zeros"),
            }
        else:
            H, P_, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            W, DI = cfg.ssm_conv, cfg.d_inner
            c["ssm"] = {
                "ssm": ParamSpec((batch, H, P_, N), jnp.float32,
                                 ("batch", "ssm_heads", None, "ssm_state"),
                                 "zeros"),
                "conv_x": ParamSpec((batch, W - 1, DI), dtype,
                                    ("batch", None, "ssm_inner"), "zeros"),
                "conv_B": ParamSpec((batch, W - 1, N), dtype,
                                    ("batch", None, "ssm_state"), "zeros"),
                "conv_C": ParamSpec((batch, W - 1, N), dtype,
                                    ("batch", None, "ssm_state"), "zeros"),
            }
        if kind.cross:
            T = cfg.num_image_tokens or cache_cap
            c["cross"] = {
                "k": ParamSpec((batch, T, KV, hd), dtype,
                               ("batch", None, "kv_heads", "head_dim"),
                               "zeros"),
                "v": ParamSpec((batch, T, KV, hd), dtype,
                               ("batch", None, "kv_heads", "head_dim"),
                               "zeros"),
                "len": ParamSpec((batch,), jnp.int32, ("batch",), "zeros"),
            }
        out[f"r{r}"] = stack(c)
    return out
