"""Checkpoint store: roundtrip, digests, async, commit integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore, tree_digest


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32)}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), shards=2)
    t = _tree(0)
    digest = store.save(3, t)
    t2, d2 = store.restore(3, jax.tree.map(jnp.zeros_like, t))
    assert d2 == digest == tree_digest(t2)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_digest_detects_corruption(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree(1)
    d = store.save(1, t)
    other = _tree(2)
    assert tree_digest(other) != d


def test_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree(3)
    d = store.save(7, t, blocking=False)
    store.wait()
    assert 7 in store.available_steps()
    _, d2 = store.restore(7, t)
    assert d2 == d


def test_commit_then_restore_via_consensus(tmp_path):
    """The full recovery path: save -> CKPT_COMMIT -> read committed step
    from the replicated state machine -> restore + digest check."""
    from repro.configs.bwraft_kv import CONFIG as CC
    from repro.coord.coordinator import ConsensusCoordinator
    store = CheckpointStore(str(tmp_path))
    coord = ConsensusCoordinator(CC, seed=2)
    coord.wait_for_leader()
    t = _tree(4)
    digest = store.save(20, t)
    coord.commit_checkpoint(20, digest)
    got = coord.last_committed_checkpoint()
    assert got is not None
    step, tag = got
    assert step == 20 and tag == int(digest[:3], 16)
    t2, d2 = store.restore(step, t)
    assert int(d2[:3], 16) == tag
