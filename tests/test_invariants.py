"""Safety properties 3.1-3.4 under failures (hypothesis over seeds/phi)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property-based tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import invariants as inv


@pytest.mark.parametrize("phi", [0.0, 0.02, 0.2])
def test_safety_under_spot_failure(sim_trace_factory, phi):
    trace, _ = sim_trace_factory(seed=11, ticks=260, every=4, phi=phi)
    inv.check_all(trace)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_safety_random_seeds(sim_trace_factory, seed):
    trace, _ = sim_trace_factory(seed=seed, ticks=150, every=6, phi=0.05)
    inv.check_election_safety(trace)
    inv.check_commit_durability(trace)


def test_state_irrelevancy(sim_trace_factory):
    """Property 3.4: killing every secretary/observer mid-run leaves the
    voters' committed prefix untouched."""
    trace_a, state = sim_trace_factory(seed=21, ticks=200, every=4, phi=0.0)
    inv.check_all(trace_a)
    commit_before = int(np.asarray(state["commit_len"]).max())
    # continue with all spot nodes dead
    trace_b, state2 = sim_trace_factory(seed=21, ticks=200, every=4, phi=1.0)
    inv.check_all(trace_b)
    assert int(np.asarray(state2["commit_len"]).max()) > 0
