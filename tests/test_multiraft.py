"""The grouped Multi-Raft contract (DESIGN.md §9): a sharded system run
as ONE fleet dispatch — shard-group axis, in-graph 2PC coupling, grouped
digest reduction — matches the frozen sequential `MultiRaftSim`
reference exactly on committed/arrived counts and to within one
histogram bin on latency means; chi = 0 collapses to independent Rafts
bit-identically; an S-shard x B-system sweep compiles once."""
import numpy as np
import pytest

from repro.core import multiraft
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.multiraft import (MultiRaftSim, aggregate_shards,
                                  shard_specs, shard_workload,
                                  two_pc_penalty)
from repro.core.runtime import HIST_TAIL, BWRaftSim, EpochReport


def _small_cluster(name="mr", followers=(2, 2, 1), max_log=1024,
                   period_ticks=60):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=256, max_secretaries=4,
                         max_observers=8, period_ticks=period_ticks)


def _report(writes_committed=10, write_lat_mean=20.0, write_lat_p95=30.0,
            write_lat_p99=35.0, read_lat_mean=8.0, **kw) -> EpochReport:
    base = dict(epoch=0, reads_arrived=100, writes_arrived=12,
                reads_served=90, writes_committed=writes_committed,
                read_lat_mean=read_lat_mean, read_lat_max=12.0,
                write_lat_mean=write_lat_mean, write_lat_p95=write_lat_p95,
                write_lat_p99=write_lat_p99, cost=1.0, n_secretaries=0,
                n_observers=0, leader_changes=0, no_leader_ticks=0,
                killed=0)
    base.update(kw)
    return EpochReport(**base)


# --------------------------------------------------------------------- #
# satellite: shard_workload algebra + annotation
# --------------------------------------------------------------------- #
def test_shard_workload_cross_shard_inflation_algebra():
    """Cross-shard writes execute in both shards: summed over shards, the
    effective write rate is inflated by exactly (1 + chi) — the capacity
    the partner shards hold for duplicated prepares (DESIGN.md §9)."""
    for write_rate in (4.0, 8.0, 96.0):
        for shards in (1, 2, 4, 7):
            for chi in (0.0, 0.1, 0.5, 1.0):
                w_eff, r_eff = shard_workload(write_rate, 32.0, shards, chi)
                assert np.isclose(w_eff * shards, write_rate * (1 + chi)), \
                    (write_rate, shards, chi)
                assert np.isclose(r_eff * shards, 32.0)


def test_shard_workload_return_annotation():
    assert shard_workload.__annotations__["return"] == "tuple[float, float]"


# --------------------------------------------------------------------- #
# satellite: aggregate_shards NaN policy (reference-only path)
# --------------------------------------------------------------------- #
def test_aggregate_shards_zero_commit_shard_does_not_poison():
    """A shard that committed zero writes reports NaN latencies; the
    blend must exclude it — uniformly, for means and percentiles."""
    cfg = _small_cluster("nanpol")
    nan = float("nan")
    reps = [_report(),
            _report(writes_committed=0, write_lat_mean=nan,
                    write_lat_p95=nan, write_lat_p99=nan)]
    with np.errstate(all="raise"):
        out = aggregate_shards(0, reps, cfg, cross_shard_frac=0.1)
    tax = two_pc_penalty(cfg)
    assert np.isclose(out.write_lat_mean, 20.0 + 0.1 * tax)
    assert np.isclose(out.write_lat_p95, 30.0 + tax)
    assert np.isclose(out.write_lat_p99, 35.0 + tax)
    assert np.isclose(out.read_lat_mean, 8.0)
    assert out.writes_committed == int(10 / 1.1)
    # chi = 0: no cross-shard traffic, so no synthetic tail shift either
    zero = aggregate_shards(0, reps, cfg, cross_shard_frac=0.0)
    assert np.isclose(zero.write_lat_mean, 20.0)
    assert np.isclose(zero.write_lat_p95, 30.0)
    assert np.isclose(zero.write_lat_p99, 35.0)


def test_aggregate_shards_all_nan_blends_to_nan_quietly():
    cfg = _small_cluster("nanpol2")
    nan = float("nan")
    reps = [_report(writes_committed=0, write_lat_mean=nan,
                    write_lat_p95=nan, write_lat_p99=nan)] * 2
    with np.errstate(all="raise"):
        out = aggregate_shards(0, reps, cfg)
    assert np.isnan(out.write_lat_mean)
    assert np.isnan(out.write_lat_p95) and np.isnan(out.write_lat_p99)
    assert np.isfinite(out.read_lat_mean)


# --------------------------------------------------------------------- #
# tentpole: grouped fleet == sequential reference
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("chi", [0.0, 0.1, 0.5])
def test_grouped_equals_sequential(shards, chi):
    """DESIGN.md §9 acceptance invariant: exact on counts and cost,
    within one histogram bin on latency means.  (p95/p99 deliberately
    differ: the grouped engine *measures* the 2PC tail, the reference
    synthesizes it as a flat + tax shift.)"""
    cfg = _small_cluster()
    kw = dict(shards=shards, write_rate=9.0, read_rate=27.0,
              cross_shard_frac=chi, seed=11)
    grouped = MultiRaftSim(cfg, **kw).run(3)
    seq = MultiRaftSim(cfg, **kw, engine="sequential").run(3)
    for e, (a, b) in enumerate(zip(grouped, seq)):
        ctx = f"shards={shards} chi={chi} epoch={e}"
        for f in ("writes_committed", "writes_arrived", "reads_served",
                  "reads_arrived"):
            assert getattr(a, f) == getattr(b, f), \
                f"{ctx}: {f}: {getattr(a, f)} != {getattr(b, f)}"
        assert np.isclose(a.cost, b.cost, rtol=1e-4), ctx
        for f in ("write_lat_mean", "read_lat_mean"):
            x, y = getattr(a, f), getattr(b, f)
            if np.isnan(x) and np.isnan(y):
                continue
            assert abs(x - y) <= 1.0, f"{ctx}: {f}: {x} vs {y}"


def test_chi_zero_collapses_to_independent_rafts_bit_identically():
    """chi = 0 degenerate case: grouping must be dynamics-inert — the
    shard members' trajectories equal plain ungrouped raft members (and
    a solo BWRaftSim) bit for bit, not just statistically."""
    cfg = _small_cluster("chi0")
    grouped = FleetSim(shard_specs(cfg, shards=3, write_rate=9.0,
                                   read_rate=18.0, cross_shard_frac=0.0,
                                   seed=5, group_id=0))
    plain = FleetSim(shard_specs(cfg, shards=3, write_rate=9.0,
                                 read_rate=18.0, cross_shard_frac=0.0,
                                 seed=5, group_id=-1))
    ga, gb = grouped.run(2), plain.run(2)
    for i in range(3):
        for e, (a, b) in enumerate(zip(ga[i], gb[i])):
            for f in ("writes_committed", "writes_arrived", "reads_served",
                      "reads_arrived", "leader_changes", "no_leader_ticks",
                      "killed"):
                assert getattr(a, f) == getattr(b, f), (i, e, f)
            for f in ("write_lat_mean", "write_lat_p95", "write_lat_p99",
                      "read_lat_mean", "cost"):
                x, y = getattr(a, f), getattr(b, f)
                assert (np.isnan(x) and np.isnan(y)) or x == y, (i, e, f)
    # solo twin at the same shapes/seed: shard 0 exactly
    w_eff, r_eff = shard_workload(9.0, 18.0, 3, 0.0)
    solo = BWRaftSim(cfg, mode="raft", write_rate=w_eff, read_rate=r_eff,
                     seed=5, manage_resources=False).run(2)
    for e, (a, b) in enumerate(zip(ga[0], solo)):
        assert a.writes_committed == b.writes_committed, e
        assert a.reads_served == b.reads_served, e
    # and the group report is the plain sum at chi = 0
    grp = grouped.group_reports[0]
    for e in range(2):
        assert grp[e].writes_committed == \
            sum(ga[i][e].writes_committed for i in range(3)), e
        assert grp[e].two_pc_prepares == 0 and grp[e].two_pc_aborts == 0
        assert grp[e].cross_arrived == 0


def test_grouped_shard_matches_solo_with_cross_knobs():
    """A grouped shard member (chi > 0) is trajectory-equal to a solo
    BWRaftSim run with the same cross_shard_frac/two_pc_ticks knobs —
    the 2PC charge is part of the member program, not a fleet side
    effect."""
    cfg = _small_cluster("knobs")
    chi, tax = 0.5, two_pc_penalty(cfg)
    fleet = FleetSim(shard_specs(cfg, shards=2, write_rate=8.0,
                                 read_rate=16.0, cross_shard_frac=chi,
                                 seed=2, group_id=0))
    freps = fleet.run(2)
    w_eff, r_eff = shard_workload(8.0, 16.0, 2, chi)
    solo = BWRaftSim(cfg, mode="raft", write_rate=w_eff, read_rate=r_eff,
                     seed=2, manage_resources=False, cross_shard_frac=chi,
                     two_pc_ticks=tax).run(2)
    for e, (a, b) in enumerate(zip(freps[0], solo)):
        for f in ("writes_committed", "writes_arrived", "reads_served"):
            assert getattr(a, f) == getattr(b, f), (e, f)
        for f in ("write_lat_mean", "write_lat_p95", "write_lat_p99"):
            x, y = getattr(a, f), getattr(b, f)
            assert (np.isnan(x) and np.isnan(y)) or x == y, (e, f)


# --------------------------------------------------------------------- #
# tentpole: one compiled dispatch for the whole S x B sweep
# --------------------------------------------------------------------- #
def test_shard4_b8_sweep_single_compile():
    """Acceptance: a shards=4, B=8 Multi-Raft sweep (32 members, 8
    groups) advances one epoch per call of ONE compiled program — the
    in-graph group reduction rides the same dispatch (CountingJit)."""
    cfg = _small_cluster("accept", followers=(1, 1), max_log=512,
                        period_ticks=40)
    specs = []
    for g in range(8):
        specs += shard_specs(cfg, shards=4, write_rate=4.0 + g,
                             read_rate=16.0, cross_shard_frac=0.1,
                             seed=g, group_id=g)
    fleet = FleetSim(specs)
    assert fleet.shapes.B == 32 and fleet.n_groups == 8
    for _ in range(3):
        fleet.run_epoch()
    assert fleet.compile_count == 1, \
        "S x B sweep must stay one compiled dispatch per epoch"
    for g in range(8):
        reps = fleet.group_reports[g]
        assert len(reps) == 3
        assert reps[-1].writes_committed > 0
        assert reps[-1].two_pc_prepares > 0
        assert reps[-1].cross_arrived > 0
    # measured 2PC rounds land in the histogram tail past the synthetic
    # clip: the digest histogram is (T + 1 + HIST_TAIL) bins wide
    dg_hist_bins = cfg.period_ticks + 1 + HIST_TAIL
    assert np.isfinite(reps[-1].write_lat_p99)
    assert reps[-1].write_lat_p99 < dg_hist_bins


def test_group_scan_equals_epoch_by_epoch():
    """The multi-epoch single-dispatch scan produces the same group
    reports as the epoch-by-epoch loop (DESIGN.md §7.1 extended to the
    §9 group digest)."""
    cfg = _small_cluster("scan")
    specs = shard_specs(cfg, shards=2, write_rate=8.0, read_rate=16.0,
                        cross_shard_frac=0.1, seed=7, group_id=0)
    fast, slow = FleetSim(specs), FleetSim(specs)
    assert fast.single_dispatch_eligible
    fast.run(3)                                  # ONE dispatch
    slow.run(3, single_dispatch=False)
    for a, b in zip(fast.group_reports[0], slow.group_reports[0]):
        assert a.writes_committed == b.writes_committed
        assert a.two_pc_prepares == b.two_pc_prepares
        assert a.two_pc_aborts == b.two_pc_aborts
        x, y = a.write_lat_mean, b.write_lat_mean
        assert (np.isnan(x) and np.isnan(y)) or x == y
        assert a.write_lat_p99 == b.write_lat_p99 or \
            (np.isnan(a.write_lat_p99) and np.isnan(b.write_lat_p99))


def test_ragged_groups_and_mixed_members():
    """Ragged shard counts (groups of different sizes) and ungrouped
    members coexist in one fleet; ungrouped digests never leak into a
    group (the dropped-segment masking rule, DESIGN.md §9)."""
    cfg = _small_cluster("ragged")
    specs = ([MemberSpec(cfg=cfg, mode="raft", write_rate=8.0,
                         read_rate=16.0, seed=99,
                         manage_resources=False)]
             + shard_specs(cfg, shards=2, write_rate=8.0, read_rate=16.0,
                           cross_shard_frac=0.1, seed=1, group_id=4)
             + shard_specs(cfg, shards=3, write_rate=6.0, read_rate=12.0,
                           cross_shard_frac=0.5, seed=2, group_id=2))
    fleet = FleetSim(specs)
    assert fleet.shapes.B == 6 and fleet.n_groups == 2
    reps = fleet.run(2)
    for g, idxs, chi in ((2, [3, 4, 5], 0.5), (4, [1, 2], 0.1)):
        grp = fleet.group_reports[g][-1]
        member_sum = sum(reps[i][-1].writes_committed for i in idxs)
        assert grp.writes_committed == int(member_sum / (1 + chi)), g
        assert grp.reads_served == \
            sum(reps[i][-1].reads_served for i in idxs), g


def test_group_validation_guards():
    cfg = _small_cluster("guard")
    ok = shard_specs(cfg, shards=2, seed=0, group_id=0)
    # declared size must match the actual member count (ragged guard)
    with pytest.raises(AssertionError):
        FleetSim(ok[:1])
    # shard groups need the digest pipeline
    with pytest.raises(AssertionError):
        FleetSim(ok, pipeline="host")
    # shards must not manage (mode="raft" members never do)
    import dataclasses
    bad = [dataclasses.replace(s, mode="bwraft") for s in ok]
    with pytest.raises(AssertionError):
        FleetSim(bad)


def test_cross_shard_mark_floor_property():
    """The deterministic marking pattern (DESIGN.md §9): exactly
    floor(n * chi) of the first n entries are marked — no RNG consumed,
    chi = 0 marks nothing, chi = 1 marks everything."""
    import jax.numpy as jnp
    from repro.core import step as step_mod
    for chi in (0.0, 0.1, 0.3, 0.5, 1.0):
        marks = np.asarray(step_mod.cross_shard_mark(
            jnp.arange(1000), jnp.float32(chi)))
        cum = np.cumsum(marks)
        for n in (1, 7, 100, 1000):
            want = int(np.floor(np.float32(n) * np.float32(chi)))
            assert cum[n - 1] == want, (chi, n)
    assert not np.asarray(step_mod.cross_shard_mark(
        jnp.arange(64), jnp.float32(0.0))).any()
    assert np.asarray(step_mod.cross_shard_mark(
        jnp.arange(64), jnp.float32(1.0))).all()
