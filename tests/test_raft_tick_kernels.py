"""The raft_tick kernel contract (DESIGN.md §8): every Pallas kernel is
**bit-identical** to its `ref.py` twin (the PR-1 formulations lifted from
`core/step.py`) under interpret mode — across padded fleets, dead-node
masks, and degenerate windows (empty log, single voter, all-observers) —
and a `backend="pallas"` simulation reproduces the `backend="xla"`
trajectory exactly, solo and batched.

The randomized sweeps run through hypothesis when it is installed
(requirements-dev.txt) and fall back to fixed-seed sweeps otherwise, so
the bit-identity invariant is enforced either way."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import state as SM
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim, make_cfg_arrays
from repro.kernels.raft_tick import ops
from repro.kernels.raft_tick import ref as R

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------- #
# shared case builders / checkers
# --------------------------------------------------------------------- #
def _log_match_case(N, L, W, seed, *, due_frac=0.5, empty_log=False):
    rng = np.random.default_rng(seed)
    mk = lambda hi, sh: jnp.asarray(rng.integers(0, hi, sh), jnp.int32)
    hi_len = 1 if empty_log else L + 1
    args = dict(
        log_term=mk(4, (N, L)), log_key=mk(8, (N, L)),
        log_val=mk(64, (N, L)),
        ldr_term=mk(4, (L,)), ldr_key=mk(8, (L,)), ldr_val=mk(64, (L,)),
        log_len=mk(hi_len, (N,)), app_from_len=mk(hi_len, (N,)),
        app_upto=mk(hi_len, (N,)),
        due=jnp.asarray(rng.random(N) < due_frac),
    )
    return args


def _check_log_match(N, L, W, seed, **kw):
    args = _log_match_case(N, L, W, seed, **kw)
    got = ops.log_match_append(*args.values(), w=W)
    want = R.log_match_append_ref(*args.values(), w=W)
    names = ("log_term", "log_key", "log_val", "new_len", "accept")
    for name, g, w_ in zip(names, got, want):
        w_ = (w_ != 0) if name == "accept" else w_
        assert np.array_equal(np.asarray(g), np.asarray(w_)), \
            (name, N, L, W, seed)


def _check_commit(N, L, majority, curterm, seed, dead_frac):
    rng = np.random.default_rng(seed)
    match_len = jnp.asarray(rng.integers(0, L + 1, N), jnp.int32)
    voter_alive = jnp.asarray(rng.random(N) >= dead_frac)
    ldr_term = jnp.asarray(rng.integers(0, 4, L), jnp.int32)
    got = ops.commit_majority(match_len, voter_alive, ldr_term, curterm,
                              majority)
    want = R.commit_majority_ref(match_len, voter_alive, ldr_term, curterm,
                                 majority)
    assert int(got) == int(want), (N, L, majority, seed)
    if majority <= N:        # the sort form indexes position majority-1
        vmatch = jnp.where(voter_alive, match_len, -1)
        kth = jnp.sort(vmatch)[::-1][max(majority - 1, 0)]
        lens = jnp.arange(L) + 1
        sort_form = jnp.max(jnp.where((lens <= kth) & (ldr_term == curterm),
                                      lens, 0))
        assert int(got) == int(sort_form), (N, L, majority, seed)


def _check_apply(N, K, A, seed):
    rng = np.random.default_rng(seed)
    kv = jnp.asarray(rng.integers(-4, 4, (N, K)), jnp.int32)
    keys = jnp.asarray(rng.integers(-2, K + 2, (N, A)), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 64, (N, A)), jnp.int32)
    valid = jnp.asarray(rng.random((N, A)) < 0.7)
    got = ops.apply_last_wins(kv, keys, vals, valid)
    want = R.apply_last_wins_ref(kv, keys, vals, valid)
    assert np.array_equal(np.asarray(got), np.asarray(want)), (N, K, A,
                                                               seed)


# --------------------------------------------------------------------- #
# property tests: hypothesis when available, fixed-seed sweep otherwise
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(N=st.integers(1, 24), L=st.integers(1, 200),
           W=st.integers(1, 64), seed=st.integers(0, 2**31))
    def test_log_match_append_matches_ref(N, L, W, seed):
        """Fused kernel == (N, W) gather/scatter twin, any window."""
        _check_log_match(N, L, W, seed)

    @settings(max_examples=25, deadline=None)
    @given(N=st.integers(1, 24), L=st.integers(1, 200),
           majority=st.integers(1, 24), curterm=st.integers(0, 4),
           seed=st.integers(0, 2**31), dead_frac=st.floats(0.0, 1.0))
    def test_commit_majority_matches_ref(N, L, majority, curterm, seed,
                                         dead_frac):
        """Blockwise order statistic == count matrix == sort form,
        under arbitrary voter/alive masks (incl. all-dead)."""
        _check_commit(N, L, majority, curterm, seed, dead_frac)

    @settings(max_examples=25, deadline=None)
    @given(N=st.integers(1, 24), K=st.integers(1, 200),
           A=st.integers(1, 8), seed=st.integers(0, 2**31))
    def test_apply_last_wins_matches_ref(N, K, A, seed):
        """In-register select == A sequential scatters, incl. duplicate
        keys (last wins) and out-of-range keys (drop semantics)."""
        _check_apply(N, K, A, seed)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_log_match_append_matches_ref(seed):
        rng = np.random.default_rng(100 + seed)
        _check_log_match(int(rng.integers(1, 24)),
                         int(rng.integers(1, 200)),
                         int(rng.integers(1, 64)), seed)

    @pytest.mark.parametrize("seed", range(8))
    def test_commit_majority_matches_ref(seed):
        rng = np.random.default_rng(200 + seed)
        _check_commit(int(rng.integers(1, 24)), int(rng.integers(1, 200)),
                      int(rng.integers(1, 24)), int(rng.integers(0, 4)),
                      seed, float(rng.random()))

    @pytest.mark.parametrize("seed", range(8))
    def test_apply_last_wins_matches_ref(seed):
        rng = np.random.default_rng(300 + seed)
        _check_apply(int(rng.integers(1, 24)), int(rng.integers(1, 200)),
                     int(rng.integers(1, 8)), seed)


# --------------------------------------------------------------------- #
# directed degenerate cases
# --------------------------------------------------------------------- #
def test_log_match_append_degenerate_windows():
    """Empty logs (from/upto/len all 0), W wider than L, single node,
    everyone-due and nobody-due."""
    for N, L, W, kw in [(1, 1, 1, {}), (3, 7, 64, {"empty_log": True}),
                        (5, 33, 256, {"due_frac": 1.0}),
                        (4, 16, 8, {"due_frac": 0.0})]:
        _check_log_match(N, L, W, 7, **kw)


def test_commit_majority_single_voter_and_no_voter():
    """majority=1 with one live voter commits its match; zero live
    voters (all observers / all dead — Property 3.4) commit nothing."""
    ldr_term = jnp.zeros(16, jnp.int32)
    one = ops.commit_majority(jnp.asarray([5], jnp.int32),
                              jnp.asarray([True]), ldr_term, 0, 1)
    none = ops.commit_majority(jnp.asarray([5, 9], jnp.int32),
                               jnp.asarray([False, False]), ldr_term, 0, 1)
    assert int(one) == 5 and int(none) == 0


def test_ops_batch_under_vmap():
    """vmapped ops over a padded 'fleet' axis == per-member ref calls —
    the form `FleetSim(backend="pallas")` exercises."""
    B, N, L, K, A, W = 3, 9, 70, 50, 4, 16
    cases = [_log_match_case(N, L, W, s) for s in range(B)]
    batched = {k: jnp.stack([c[k] for c in cases]) for k in cases[0]}
    # (vmap rebuilds dict pytrees in sorted-key order — pass by name)
    got = jax.vmap(lambda c: ops.log_match_append(
        c["log_term"], c["log_key"], c["log_val"], c["ldr_term"],
        c["ldr_key"], c["ldr_val"], c["log_len"], c["app_from_len"],
        c["app_upto"], c["due"], w=W))(batched)
    for b in range(B):
        want = R.log_match_append_ref(*cases[b].values(), w=W)
        for g, w_ in zip(got[:4], want[:4]):
            assert np.array_equal(np.asarray(g[b]), np.asarray(w_))

    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.integers(0, 4, (B, N, K)), jnp.int32)
    keys = jnp.asarray(rng.integers(0, K, (B, N, A)), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 64, (B, N, A)), jnp.int32)
    valid = jnp.asarray(rng.random((B, N, A)) < 0.7)
    got = jax.vmap(ops.apply_last_wins)(kv, keys, vals, valid)
    for b in range(B):
        want = R.apply_last_wins_ref(kv[b], keys[b], vals[b], valid[b])
        assert np.array_equal(np.asarray(got[b]), np.asarray(want))


# --------------------------------------------------------------------- #
# end-to-end: the pallas backend reproduces the xla trajectory
# --------------------------------------------------------------------- #
def _small_cluster(name="ktiny", followers=(2, 1), max_log=384):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=128, max_secretaries=2,
                         max_observers=4, period_ticks=40)


def test_pallas_tick_trajectory_equals_xla():
    """A 60-tick jitted scan on the pallas backend is bit-identical to
    the xla backend — elections, commits, applies, the lot."""
    cfg = _small_cluster()
    static = SM.build_static(cfg)
    cfg_c = make_cfg_arrays(cfg, write_rate=6.0, read_rate=12.0, phi=0.05)
    state0 = SM.init_state(cfg, static)
    rngs = jax.random.split(jax.random.PRNGKey(3), 60)

    def run(backend):
        def body(c, r):
            s, _ = step_mod.tick(c, static, cfg_c, r, backend=backend)
            return s, None
        out, _ = jax.jit(lambda s: jax.lax.scan(body, s, rngs))(state0)
        return jax.tree.map(np.asarray, out)

    x, p = run("xla"), run("pallas")
    for k in x:
        assert np.array_equal(x[k], p[k]), f"state[{k}] diverged"


def _assert_reports_equal(a, b, ctx=""):
    """Dataclass equality, NaN-tolerant on the latency floats (NaN means
    'no committed writes this epoch' on both sides)."""
    import dataclasses
    for f in dataclasses.fields(a):
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, float) and np.isnan(x) and np.isnan(y):
            continue
        assert x == y, f"{ctx}: {f.name}: pallas={y} xla={x}"


def test_pallas_backend_sim_and_fleet_match_xla():
    """BWRaftSim/FleetSim grow a `backend` knob: reports (and the padded
    heterogeneous-fleet dead-slot masking) are identical across
    backends."""
    small = _small_cluster("kpad", followers=(1, 1), max_log=256)
    big = _small_cluster("kbig", followers=(2, 2), max_log=384)
    solo_kw = dict(write_rate=6.0, read_rate=12.0, phi=0.05, seed=2,
                   manage_resources=False, prelease=(1, 2))
    rx = BWRaftSim(big, **solo_kw, backend="xla").run(2)
    rp = BWRaftSim(big, **solo_kw, backend="pallas").run(2)
    for e, (a, b) in enumerate(zip(rx, rp)):
        _assert_reports_equal(a, b, ctx=f"solo epoch {e}")

    specs = [MemberSpec(cfg=small, write_rate=6.0, read_rate=12.0, seed=0,
                        manage_resources=False),
             MemberSpec(cfg=big, mode="raft", write_rate=8.0,
                        read_rate=8.0, seed=1, manage_resources=False)]
    fx = FleetSim(specs, backend="xla")
    fp = FleetSim(specs, backend="pallas")
    reps_x, reps_p = fx.run(2), fp.run(2)
    for i in range(len(specs)):
        for e, (a, b) in enumerate(zip(reps_x[i], reps_p[i])):
            _assert_reports_equal(a, b, ctx=f"member {i} epoch {e}")
    # padding stays inert through the kernels too
    st_np = {k: np.asarray(v) for k, v in fp.state.items()}
    assert (st_np["role"][0, small.max_nodes:] == SM.DEAD).all()
    assert not st_np["alive"][0, small.max_nodes:].any()

    with pytest.raises(AssertionError):
        FleetSim(specs, pipeline="host", backend="pallas")
