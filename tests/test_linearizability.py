"""Wing&Gong checker unit tests + checking a simulated write history."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property-based tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.linearizability import Op, is_linearizable


def test_trivially_linearizable():
    h = [Op("w", 0, 1, 0, 10), Op("r", 0, 1, 20, 30)]
    assert is_linearizable(h)


def test_stale_read_rejected():
    h = [Op("w", 0, 1, 0, 10), Op("w", 0, 2, 20, 30), Op("r", 0, 1, 40, 50)]
    assert not is_linearizable(h)


def test_concurrent_overlap_ok():
    h = [Op("w", 0, 1, 0, 100), Op("r", 0, 0, 10, 20),   # reads initial
         Op("r", 0, 1, 90, 120)]
    assert is_linearizable(h)


def test_read_your_write_violation():
    h = [Op("w", 0, 5, 0, 10), Op("r", 0, 0, 30, 40)]
    assert not is_linearizable(h)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 99999))
def test_sequential_histories_always_linearizable(seed):
    rng = np.random.default_rng(seed)
    t, val, h = 0.0, {}, []
    for _ in range(rng.integers(2, 10)):
        k = int(rng.integers(0, 2))
        if rng.uniform() < 0.5:
            v = int(rng.integers(1, 100))
            h.append(Op("w", k, v, t, t + 1))
            val[k] = v
        else:
            h.append(Op("r", k, val.get(k, 0), t, t + 1))
        t += 2
    assert is_linearizable(h)


def test_sim_write_history_linearizable(sim_trace_factory):
    """Committed writes from the sim + reads of the final state machine."""
    trace, state = sim_trace_factory(seed=5, ticks=260, every=4)
    sub = np.asarray(state["entry_submit_t"])
    com = np.asarray(state["entry_commit_t"])
    keys = np.asarray(state["log_key"])
    vals = np.asarray(state["log_val"])
    lid = int(np.argmax(np.asarray(state["commit_len"])))
    done = (sub >= 0) & (com >= 0)
    idx = np.where(done)[0]
    # single-key projection: entries writing key k0 + final read
    if idx.size == 0:
        return
    k0 = int(keys[lid, idx[0]])
    ops = []
    last_v = 0
    for i in idx:
        if int(keys[lid, i]) == k0:
            ops.append(Op("w", 0, int(vals[lid, i]),
                          float(sub[i]), float(com[i])))
            last_v = int(vals[lid, i])
    applied = int(np.asarray(state["applied_len"])[lid])
    kv_v = int(np.asarray(state["kv"])[lid, k0])
    t_end = float(np.asarray(state["tick"])) + 1
    # the state machine may not have applied the last commit yet; read is
    # valid if it matches SOME linearization -> only add when applied
    ks = [int(keys[lid, i]) for i in range(applied)]
    if k0 in ks:
        ops_checked = ops[:8] + [Op("r", 0, kv_v, t_end, t_end)] \
            if all(int(keys[lid, i]) != k0 for i in range(applied, idx[-1]+1)) \
            else ops[:8]
    else:
        ops_checked = ops[:8]
    assert is_linearizable(ops_checked[:10])
