"""Wing&Gong checker unit tests, checking a simulated write history, and
checking the service's observer read-index round (DESIGN.md §11) against
the same checker."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.configs.bwraft_kv import CONFIG as CC
from repro.core.linearizability import Op, is_linearizable
from repro.core.runtime import BWRaftSim
from repro.kvstore.service import BWKVService


def test_trivially_linearizable():
    h = [Op("w", 0, 1, 0, 10), Op("r", 0, 1, 20, 30)]
    assert is_linearizable(h)


def test_stale_read_rejected():
    h = [Op("w", 0, 1, 0, 10), Op("w", 0, 2, 20, 30), Op("r", 0, 1, 40, 50)]
    assert not is_linearizable(h)


def test_concurrent_overlap_ok():
    h = [Op("w", 0, 1, 0, 100), Op("r", 0, 0, 10, 20),   # reads initial
         Op("r", 0, 1, 90, 120)]
    assert is_linearizable(h)


def test_read_your_write_violation():
    h = [Op("w", 0, 5, 0, 10), Op("r", 0, 0, 30, 40)]
    assert not is_linearizable(h)


def _check_sequential_history(seed):
    rng = np.random.default_rng(seed)
    t, val, h = 0.0, {}, []
    for _ in range(rng.integers(2, 10)):
        k = int(rng.integers(0, 2))
        if rng.uniform() < 0.5:
            v = int(rng.integers(1, 100))
            h.append(Op("w", k, v, t, t + 1))
            val[k] = v
        else:
            h.append(Op("r", k, val.get(k, 0), t, t + 1))
        t += 2
    assert is_linearizable(h)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 99999))
    def test_sequential_histories_always_linearizable(seed):
        _check_sequential_history(seed)
else:                                                 # fixed-seed fallback
    @pytest.mark.parametrize("seed", [0, 17, 4242, 99998])
    def test_sequential_histories_always_linearizable(seed):
        _check_sequential_history(seed)


def test_sim_write_history_linearizable(sim_trace_factory):
    """Committed writes from the sim + reads of the final state machine."""
    trace, state = sim_trace_factory(seed=5, ticks=260, every=4)
    sub = np.asarray(state["entry_submit_t"])
    com = np.asarray(state["entry_commit_t"])
    keys = np.asarray(state["log_key"])
    vals = np.asarray(state["log_val"])
    lid = int(np.argmax(np.asarray(state["commit_len"])))
    done = (sub >= 0) & (com >= 0)
    idx = np.where(done)[0]
    # single-key projection: entries writing key k0 + final read
    if idx.size == 0:
        return
    k0 = int(keys[lid, idx[0]])
    ops = []
    last_v = 0
    for i in idx:
        if int(keys[lid, i]) == k0:
            ops.append(Op("w", 0, int(vals[lid, i]),
                          float(sub[i]), float(com[i])))
            last_v = int(vals[lid, i])
    applied = int(np.asarray(state["applied_len"])[lid])
    kv_v = int(np.asarray(state["kv"])[lid, k0])
    t_end = float(np.asarray(state["tick"])) + 1
    # the state machine may not have applied the last commit yet; read is
    # valid if it matches SOME linearization -> only add when applied
    ks = [int(keys[lid, i]) for i in range(applied)]
    if k0 in ks:
        ops_checked = ops[:8] + [Op("r", 0, kv_v, t_end, t_end)] \
            if all(int(keys[lid, i]) != k0 for i in range(applied, idx[-1]+1)) \
            else ops[:8]
    else:
        ops_checked = ops[:8]
    assert is_linearizable(ops_checked[:10])


# ------------------------------------------------------------------ #
# the service's observer read-index round vs the checker
# ------------------------------------------------------------------ #
def _service(*, seed, observers=0, timeout_ticks=400):
    sim = BWRaftSim(CC, write_rate=0.0, read_rate=0.0, seed=seed,
                    manage_resources=False)
    if observers:
        sim._lease(0, observers)
    s = BWKVService(sim, timeout_ticks=timeout_ticks)
    s._step(120)                       # elect a leader
    return s


def _timed(svc, fn, *args, **kw):
    """Invocation interval in cluster ticks: (result, t_invoke, t_return)."""
    t0 = float(svc.sim.state["tick"])
    out = fn(*args, **kw)
    return out, t0, float(svc.sim.state["tick"])


def test_observer_read_history_linearizable():
    """A put/get interleaving over one key, reads served through the
    observer read-index round, timed in cluster ticks — the history must
    pass the same Wing&Gong checker the aggregate traces do."""
    s = _service(seed=21, observers=4)
    h = []
    rng = np.random.default_rng(3)
    for i in range(1, 7):
        _, t0, t1 = _timed(s, s.put, "lin", i)
        h.append(Op("w", 0, i, t0, t1))
        if rng.uniform() < 0.7:
            (v, _), t0, t1 = _timed(s, s.get, "lin")
            h.append(Op("r", 0, v, t0, t1))
    (v, _), t0, t1 = _timed(s, s.get, "lin")
    h.append(Op("r", 0, v, t0, t1))
    assert is_linearizable(h)


def test_leader_only_read_history_linearizable():
    """The same contract holds with observers disallowed (fallback to a
    caught-up follower or the leader)."""
    s = _service(seed=23)
    h = []
    for i in (5, 9, 2):
        _, t0, t1 = _timed(s, s.put, "k", i)
        h.append(Op("w", 0, i, t0, t1))
        (v, _), t0, t1 = _timed(s, s.get, "k", allow_observer=False)
        h.append(Op("r", 0, v, t0, t1))
    assert is_linearizable(h)


def test_session_read_never_older_than_acked_write():
    """Session monotonicity (DESIGN.md §11): a read-index read returns a
    revision at or past the session floor, so a get never observes state
    older than the last write acked to the same client session — and
    successive reads never travel backwards."""
    s = _service(seed=25, observers=3)
    last_rev = -1
    for i in range(1, 6):
        res = s.put("mono", i * 11)
        assert s.session_floor > res.revision
        v, rev = s.get("mono")
        assert v == i * 11             # exactly the acked write, no older
        assert rev >= s.session_floor - 1 and rev >= res.revision + 1
        assert rev >= last_rev
        last_rev = rev
    # an interleaved read on another key still rides the same floor
    s.put("other", 1)
    v, rev = s.get("mono")
    assert v == 55 and rev >= last_rev


# ------------------------------------------------------------------ #
# bounded-staleness reads through the digest tier (DESIGN.md §13)
# ------------------------------------------------------------------ #
def _digest_service(*, seed, n_observers=6, staleness_bound=16,
                    ae_interval=4, timeout_ticks=400):
    sim = BWRaftSim(CC, write_rate=0.0, read_rate=0.0, seed=seed,
                    manage_resources=False, n_observers=n_observers,
                    staleness_bound=staleness_bound,
                    ae_interval=ae_interval)
    s = BWKVService(sim, timeout_ticks=timeout_ticks)
    s._step(120)                       # elect a leader
    return s


def test_leader_reads_linearizable_with_digest_tier():
    """Wing&Gong on leader/voter reads while a digest tier rides along:
    the fenced read-index round stays linearizable regardless of the
    tier — §13 only relaxes reads that explicitly opt into staleness."""
    s = _digest_service(seed=31)
    h = []
    for i in (4, 8, 1, 6):
        _, t0, t1 = _timed(s, s.put, "k", i)
        h.append(Op("w", 0, i, t0, t1))
        (v, _), t0, t1 = _timed(s, s.get, "k", allow_observer=False)
        h.append(Op("r", 0, v, t0, t1))
    assert is_linearizable(h)


def test_digest_observer_reads_session_monotonic():
    """Session monotonicity on digest-tier reads (`get_stale`, §13):
    revisions never regress the session floor, successive reads never
    travel backwards, and a read after an acked write reflects it — the
    floor reroutes to a fenced read when every observer is behind."""
    s = _digest_service(seed=33)
    last_rev = -1
    for i in range(1, 6):
        res = s.put("mono", i * 7)
        v, rev = s.get_stale("mono")
        assert v == i * 7              # read-your-writes via the floor
        assert rev >= res.revision + 1
        assert rev >= last_rev
        last_rev = rev
    # stale reads between writes: still never backwards
    for _ in range(4):
        s._step(3)
        v, rev = s.get_stale("mono")
        assert v == 35 and rev >= last_rev
        last_rev = rev
    # the tier did sync (the eligibility set was not permanently empty)
    assert int(np.asarray(s.sim.state["dobs_applied"]).max()) > 0


def test_digest_observer_history_linearizable_single_session():
    """A single-session put/`get_stale` interleaving over one key passes
    Wing&Gong: the session floor forces every bounded-staleness read to
    cover the last acked write, which for one client makes the relaxed
    history as strong as the fenced one."""
    s = _digest_service(seed=35)
    h = []
    rng = np.random.default_rng(7)
    for i in range(1, 7):
        _, t0, t1 = _timed(s, s.put, "dk", i)
        h.append(Op("w", 0, i, t0, t1))
        if rng.uniform() < 0.7:
            (v, _), t0, t1 = _timed(s, s.get_stale, "dk")
            h.append(Op("r", 0, v, t0, t1))
    (v, _), t0, t1 = _timed(s, s.get_stale, "dk")
    h.append(Op("r", 0, v, t0, t1))
    assert is_linearizable(h)
