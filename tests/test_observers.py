"""Digest-tier observer suite (DESIGN.md §13).

Covers the §13 contract end to end:

  * golden gate — with the digest tier OFF (O = 0) the dense voter core
    and the legacy full-log observers are bit-identical to the frozen
    pre-tier fixture (`tests/data/observer_golden.json`), managed and
    fixed-role runs both;
  * equivalence — attaching a tier (O > 0) leaves every dense core leaf
    bit-identical at the same seed (the tier only adds digest-shaped
    state and redistributes reads);
  * Property 3.2 prefix mirrors — legacy observers' mirrored state
    equals a prefix of their follower's applied log at every tick, the
    rolling `applied_digest` equals the recompute-from-scratch
    `prefix_digest` on every alive node, and every digest observer's
    `dobs_digest` certifies a committed voter prefix;
  * anti-entropy convergence — under random gossip schedules,
    revocation kills, and warned drains every live digest observer
    converges within `ae_interval + max hop` of the fleet tick
    (hypothesis when installed, fixed-seed fallback otherwise);
  * staleness histogram pin — the device `obs_stale_hist` equals a
    numpy recomputation from the raw per-tick samples;
  * fleet equivalence — a solo digest-tier run and the same spec as a
    one-member fleet produce identical reports.
"""
import hashlib
import json
import os

import jax
import numpy as np
import pytest

from repro.configs.bwraft_kv import CONFIG
from repro.core import state as SM
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # fixed-seed fallback
    HAVE_HYPOTHESIS = False

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "observer_golden.json")

# report fields frozen in the fixture: ints exact, floats by repr
INT_FIELDS = ("killed", "leader_changes", "n_observers", "n_secretaries",
              "no_leader_ticks", "reads_arrived", "reads_served",
              "writes_arrived", "writes_committed")
FLOAT_FIELDS = ("cost", "read_lat_max", "read_lat_mean", "write_lat_mean",
                "write_lat_p95", "write_lat_p99")

# the two frozen scenarios (digest tier off): the managed headline run
# and a fixed-role run with legacy full-log observers serving reads
SCENARIOS = {
    "solo_managed": dict(write_rate=8.0, read_rate=32.0, phi=0.05, seed=7),
    "solo_fixed_obs": dict(write_rate=6.0, read_rate=48.0, phi=0.02,
                           seed=11, manage_resources=False,
                           prelease=(2, 8)),
}

# leaves the digest tier is ALLOWED to move: its own state, read
# serving, and cost (digest observers lease spot capacity); everything
# else is dense voter core and must stay bit-identical (DESIGN.md §13)
_NON_CORE = ("read_queue", "reads_served", "read_lat_hist",
             "read_lat_sum", "read_lat_max", "cost_accrued")


def _is_core_leaf(name: str) -> bool:
    return (not name.startswith("dobs_") and not name.startswith("obs_")
            and name not in _NON_CORE)


def _sha(arr) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(arr)).tobytes()).hexdigest()


def _small_cluster(name="obs-small", followers=(2, 2, 1), max_log=1024):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=256, max_secretaries=4,
                         max_observers=8, period_ticks=60)


# ------------------------------------------------------------------ golden


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_golden_bit_identity_digest_off(scenario):
    """With the digest tier off, the run is bit-identical to the frozen
    pre-tier fixture: every report field and every recorded state leaf."""
    with open(GOLDEN) as f:
        golden = json.load(f)[scenario]
    sim = BWRaftSim(CONFIG, **SCENARIOS[scenario])
    reports = sim.run(len(golden["reports"]))
    for i, (rep, want) in enumerate(zip(reports, golden["reports"])):
        for fld in INT_FIELDS:
            assert getattr(rep, fld) == want[fld], \
                f"{scenario} epoch {i}: {fld}"
        for fld in FLOAT_FIELDS:
            assert repr(float(getattr(rep, fld))) == want[fld], \
                f"{scenario} epoch {i}: {fld}"
    for leaf, meta in golden["state"].items():
        arr = np.asarray(sim.state[leaf])
        assert list(arr.shape) == meta["shape"], f"{scenario}: {leaf} shape"
        assert str(arr.dtype) == meta["dtype"], f"{scenario}: {leaf} dtype"
        assert _sha(arr) == meta["sha256"], f"{scenario}: {leaf} bytes"


# ------------------------------------------------------- core equivalence


def test_digest_tier_leaves_voter_core_bit_identical():
    """O = 0 vs O > 0 at the same seed: every dense core leaf equal, and
    the tier actually served reads (the comparison is not vacuous)."""
    cfg = _small_cluster()
    kw = dict(write_rate=6.0, read_rate=24.0, phi=0.05, seed=3,
              manage_resources=False, prelease=(2, 4))
    base = BWRaftSim(cfg, **kw)
    base.run(2)
    tier = BWRaftSim(cfg, **kw, n_observers=12, staleness_bound=10,
                     ae_interval=3)
    reports = tier.run(2)
    for leaf in base.state:
        if _is_core_leaf(leaf):
            assert np.array_equal(np.asarray(base.state[leaf]),
                                  np.asarray(tier.state[leaf])), leaf
    assert reports[-1].obs_reads_served > 0


# -------------------------------------------- Property 3.2 prefix mirror


def _tick_trace(cfg, *, ticks, seed, n_observers=0, prelease=(1, 4),
                phi=0.02, staleness_bound=12, ae_interval=3,
                snapshot_every=3, ae_phase=None, warning_ticks=0):
    """Host tick loop (no epoch machinery): snapshots of the raw state
    every few ticks, for the per-tick Property 3.2 pins."""
    sim = BWRaftSim(cfg, write_rate=6.0, read_rate=24.0, phi=phi,
                    seed=seed, manage_resources=False, prelease=prelease,
                    n_observers=n_observers,
                    staleness_bound=staleness_bound,
                    ae_interval=ae_interval, ae_phase=ae_phase,
                    warning_ticks=warning_ticks)
    static, cfg_c = sim.static, sim.cfg_c
    tickfn = jax.jit(lambda s, r: step_mod.tick(s, static, cfg_c, r))
    rng = sim.rng
    state, snaps, mets = sim.state, [], []
    for t in range(ticks):
        rng, sub = jax.random.split(rng)
        state, m = tickfn(state, sub)
        if t % snapshot_every == 0:
            snaps.append({k: np.asarray(v) for k, v in state.items()})
        mets.append({k: np.asarray(v) for k, v in m.items()
                     if k.startswith("obs_")})
    return sim, snaps, mets, {k: np.asarray(v) for k, v in state.items()}


def test_property_32_legacy_observer_prefix_mirror():
    """Property 3.2 pin on `observer_sync_step`: at every snapshot, an
    alive legacy observer with an alive follower holds a prefix of that
    follower's applied log — applied index behind or equal, identical
    keys/values over the observer's applied prefix, identical KV image
    over it, and the mirrored digest certifying exactly that prefix."""
    _, snaps, _, _ = _tick_trace(_small_cluster(), ticks=90, seed=5,
                                 prelease=(1, 6))
    checked = 0
    for s in snaps:
        is_obs = (s["role"] == SM.OBSERVER) & s["alive"]
        for o in np.where(is_obs)[0]:
            f = int(s["obs_of"][o])
            if f < 0 or not s["alive"][f]:
                continue
            a = int(s["applied_len"][o])
            assert a <= int(s["applied_len"][f])
            assert np.array_equal(s["log_key"][o][:a], s["log_key"][f][:a])
            assert np.array_equal(s["log_val"][o][:a], s["log_val"][f][:a])
            checked += 1
    assert checked > 0, "no live observer/follower pair ever checked"


def test_rolling_digest_equals_prefix_recompute():
    """The incremental `applied_digest` chain equals the
    recompute-from-scratch `prefix_digest` on every alive node at every
    snapshot — voters, secretaries, and legacy observers alike."""
    _, snaps, _, _ = _tick_trace(_small_cluster(), ticks=90, seed=9,
                                 prelease=(2, 4))
    for s in snaps:
        for n in np.where(s["alive"])[0]:
            want = SM.prefix_digest(s["log_key"][n], s["log_val"][n],
                                    int(s["applied_len"][n]), xp=np)
            assert s["applied_digest"][n] == want, f"node {n}"


def test_digest_observer_certifies_committed_prefix():
    """Every alive digest observer's (applied, digest) pair names a
    committed prefix: recomputing the digest over the most-applied live
    voter's log at `dobs_applied` reproduces `dobs_digest` exactly."""
    sim, snaps, _, _ = _tick_trace(_small_cluster(), ticks=90, seed=13,
                                   n_observers=10)
    is_voter = np.asarray(sim.static["is_voter"])
    checked = 0
    for s in snaps:
        live_v = np.where(is_voter & s["alive"])[0]
        v = live_v[np.argmax(s["applied_len"][live_v])]
        for o in np.where(s["dobs_alive"])[0]:
            a = int(s["dobs_applied"][o])
            if a == 0:
                continue                      # nothing adopted yet
            assert a <= int(s["applied_len"][v])
            want = SM.prefix_digest(s["log_key"][v], s["log_val"][v],
                                    a, xp=np)
            assert s["dobs_digest"][o] == want, f"slot {o}"
            checked += 1
    assert checked > 0, "no synced digest observer ever checked"


# --------------------------------------------- anti-entropy convergence


def _check_convergence(seed, phi, ae_interval, warning_ticks):
    """Under a random gossip phase schedule, revocation kills, and
    warned drains, every live digest observer's last sync is within
    `ae_interval + max hop` of the fleet tick at every snapshot, and its
    digest certifies a committed prefix (monotone adoption never
    regresses).  Checked on a raw tick trace: the epoch boundary
    deliberately revives slots stale (`compact_state`), so convergence
    is a steady-state property, not a post-`run()` one."""
    cfg = _small_cluster()
    O = 16
    rng = np.random.default_rng(seed)
    sim, snaps, _, _ = _tick_trace(
        cfg, ticks=90, seed=seed, n_observers=O, prelease=(1, 2),
        phi=phi, staleness_bound=24, ae_interval=ae_interval,
        ae_phase=rng.integers(0, max(ae_interval, 1), size=O),
        warning_ticks=warning_ticks)
    is_voter = np.asarray(sim.static["is_voter"])
    hop_max = int(np.asarray(sim.static["site_rtt"]).max())
    checked = 0
    for s in snaps:
        tick = int(s["tick"])
        live = np.where(s["dobs_alive"])[0]
        stale = tick - s["dobs_synced_t"][live]
        assert (stale <= ae_interval + hop_max).all(), \
            f"tick {tick}: stale={stale.max()} > interval " \
            f"{ae_interval} + hop {hop_max}"
        live_v = np.where(is_voter & s["alive"])[0]
        v = live_v[np.argmax(s["applied_len"][live_v])]
        for o in live:
            a = int(s["dobs_applied"][o])
            assert a <= int(s["applied_len"][v])
            if a:
                assert s["dobs_digest"][o] == SM.prefix_digest(
                    s["log_key"][v], s["log_val"][v], a, xp=np)
                checked += 1
    assert checked > 0, "no live synced digest observer ever checked"


_CONVERGENCE_CASES = [(0, 0.0, 1, 0), (3, 0.05, 4, 0), (11, 0.02, 7, 3),
                      (21, 0.02, 2, 2), (42, 0.05, 3, 0)]

if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           phi=st.sampled_from([0.0, 0.02, 0.05]),
           ae_interval=st.integers(1, 7),
           warning_ticks=st.sampled_from([0, 3]))
    def test_anti_entropy_convergence(seed, phi, ae_interval,
                                      warning_ticks):
        _check_convergence(seed, phi, ae_interval, warning_ticks)
else:
    @pytest.mark.parametrize("seed,phi,ae_interval,warning_ticks",
                             _CONVERGENCE_CASES)
    def test_anti_entropy_convergence(seed, phi, ae_interval,
                                      warning_ticks):
        _check_convergence(seed, phi, ae_interval, warning_ticks)


# ------------------------------------------------- staleness histogram


def test_staleness_histogram_numpy_pin():
    """The device `obs_stale_hist` equals a numpy recomputation from the
    raw per-tick (served, staleness) samples, and the serve counter
    equals the histogram mass — so the staleness percentiles the reports
    quote are exact, and every sample is <= the configured bound."""
    bound = 12
    _, _, mets, final = _tick_trace(_small_cluster(), ticks=90, seed=17,
                                    n_observers=10, staleness_bound=bound)
    H = final["obs_stale_hist"].shape[0]
    hist = np.zeros(H, np.int64)
    for m in mets:
        served, stale = m["obs_served_tick"], m["obs_stale_tick"]
        for o in np.where(served > 0)[0]:
            hist[min(int(stale[o]), H - 1)] += int(served[o])
    assert hist.sum() > 0, "digest tier never served"
    assert np.array_equal(hist, final["obs_stale_hist"])
    assert int(final["obs_reads_served"]) == hist.sum()
    assert hist[bound + 1:].sum() == 0, "served a read beyond the bound"


# ------------------------------------------------------ fleet equivalence


def test_fleet_member_matches_solo_with_observers():
    """The same digest-tier spec run solo and as a one-member fleet
    produces identical reports, observer columns included."""
    cfg = _small_cluster()
    kw = dict(write_rate=6.0, read_rate=24.0, phi=0.02, seed=19,
              manage_resources=False, prelease=(1, 3))
    tier = dict(n_observers=12, staleness_bound=10, ae_interval=3)
    solo = BWRaftSim(cfg, **kw, **tier).run(2)
    fleet = FleetSim([MemberSpec(cfg=cfg, mode="bwraft", **kw, **tier)])
    batched = fleet.run(2)[0]
    fields = INT_FIELDS + ("obs_reads_served", "obs_rerouted",
                           "n_obs_digest")
    for a, b in zip(solo, batched):
        for fld in fields:
            assert getattr(a, fld) == getattr(b, fld), fld
        for fld in FLOAT_FIELDS + ("obs_stale_p95", "obs_stale_p99"):
            fa, fb = getattr(a, fld), getattr(b, fld)
            assert (np.isnan(fa) and np.isnan(fb)) or fa == fb, fld
