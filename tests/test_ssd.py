"""SSD chunked scan == sequential recurrence oracle; decode chain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssd
from repro.models.common import init_tree, abstract_tree


def _params(cfg, rng):
    return init_tree(rng, ssd.ssd_params(cfg, jnp.float32))


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 16)])
def test_ssd_apply_matches_sequential(S, chunk):
    import dataclasses
    cfg = dataclasses.replace(get_config("mamba2-130m").reduced(),
                              ssm_chunk=chunk)
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model),
                          jnp.float32) * 0.3
    y_chunked, st = ssd.ssd_apply(p, x, cfg)
    y_seq = ssd.ssd_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_ssd_unrolled_matches_scan():
    cfg = get_config("mamba2-130m").reduced()
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.3
    y1, s1 = ssd.ssd_apply(p, x, cfg, unroll=False)
    y2, s2 = ssd.ssd_apply(p, x, cfg, unroll=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1["ssm"]), np.asarray(s2["ssm"]),
                               rtol=1e-5, atol=1e-5)


def test_prefill_state_continues_decode():
    """prefill state + decode steps == running the full sequence."""
    cfg = get_config("mamba2-130m").reduced()
    p = _params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 3, cfg.d_model),
                          jnp.float32) * 0.3
    y_full = ssd.ssd_reference(p, x, cfg)
    _, state = ssd.ssd_apply(p, x[:, :S], cfg)
    cache = {"ssm": state["ssm"], "conv_x": state["conv_x"],
             "conv_B": state["conv_B"], "conv_C": state["conv_C"]}
    outs = []
    for t in range(3):
        y, cache = ssd.ssd_decode(p, x[:, S + t:S + t + 1], cache, cfg)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full[:, S:], np.float32),
                               rtol=3e-3, atol=3e-3)
