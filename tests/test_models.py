"""Per-arch smoke tests (assignment f): reduced config, one forward/train
step on CPU, asserting output shapes + no NaNs; plus prefill/decode
consistency against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import RunConfig
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.common import init_tree, param_count
from repro.optim import adamw


def _batch(cfg, B, Ssz, rng):
    b = {"tokens": jax.random.randint(rng, (B, Ssz), 0, cfg.vocab_size),
         "labels": jax.random.randint(rng, (B, Ssz), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["img_embeds"] = jnp.ones((B, cfg.num_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "audio_encdec":
        b["frames"] = jnp.ones((B, Ssz, cfg.d_model), jnp.bfloat16) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    runcfg = RunConfig()
    mesh = make_host_mesh()
    params = init_tree(jax.random.PRNGKey(0), S.param_specs(cfg, runcfg))
    state = {"params": params, "opt": adamw.init_opt_state(params)}
    B, Ssz = 2, 32
    batch = _batch(cfg, B, Ssz, jax.random.PRNGKey(1))
    train_step, rules = S.make_train_step(cfg, runcfg, mesh)
    state2, m = jax.jit(train_step)(state, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert np.isfinite(float(m["grad_norm"]))
    # logits shape via forward
    logits, _, _ = lm.forward(params, batch["tokens"], cfg, runcfg, mesh,
                              S.resolve_rules(cfg, "train"), mode="train",
                              img_embeds=batch.get("img_embeds"),
                              frames=batch.get("frames"))
    assert logits.shape == (B, Ssz, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-130m",
                                  "qwen2-moe-a2.7b",
                                  "jamba-1.5-large-398b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy tokens from prefill+decode must equal argmax of the full
    causal forward at the same positions (KV-cache correctness)."""
    cfg = get_config(arch).reduced()
    # f32 end-to-end: bf16 rounding can flip argmax between the two paths
    runcfg = RunConfig(remat=False, param_dtype="float32",
                       activation_dtype="float32")
    mesh = make_host_mesh()
    params = init_tree(jax.random.PRNGKey(0), S.param_specs(cfg, runcfg))
    rules = S.resolve_rules(cfg, "train")
    B, P = 2, 16
    batch = _batch(cfg, B, P, jax.random.PRNGKey(2))
    batch.pop("labels")

    prefill, _ = S.make_prefill_step(cfg, runcfg, mesh)
    decode, _ = S.make_decode_step(cfg, runcfg, mesh)
    tok, caches = jax.jit(prefill)(params, batch)
    # grow cache capacity to P + 4
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == P:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 4)
            return jnp.pad(x, pad)
        return x
    caches = {"pos": caches["pos"],
              "layers": jax.tree.map(grow, caches["layers"])}

    toks = [tok]
    for _ in range(3):
        tok, caches = jax.jit(decode)(params, caches, tok[:, None])
        toks.append(tok)

    # oracle: run the full forward over prompt + generated tokens
    seq = jnp.concatenate(
        [batch["tokens"]] + [t[:, None] for t in toks[:-1]], axis=1)
    logits, _, _ = lm.forward(params, seq, cfg, runcfg, mesh, rules,
                              mode="train",
                              img_embeds=batch.get("img_embeds"),
                              frames=(jnp.ones((B, seq.shape[1],
                                                cfg.d_model), jnp.bfloat16)
                                      * 0.1 if cfg.family == "audio_encdec"
                                      else None))
    for i, t in enumerate(toks):
        ref = jnp.argmax(logits[:, P - 1 + i], axis=-1)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(ref)), \
            (arch, i)


def test_param_counts_match_spec():
    """Full (non-reduced) configs must be in the advertised ballpark."""
    expected = {"llama3.2-1b": (1.0e9, 1.6e9),
                "qwen3-8b": (6e9, 9e9),
                "llama-3.2-vision-90b": (80e9, 110e9),
                "jamba-1.5-large-398b": (330e9, 420e9),
                "mamba2-130m": (0.10e9, 0.19e9)}
    from repro.configs.base import RunConfig
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = param_count(S.param_specs(cfg, RunConfig()))
        assert lo <= n <= hi, (arch, n)
