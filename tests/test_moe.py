"""MoE: shard_map EP (a2a + psum strategies) vs the dense oracle."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import moe
from repro.models.common import init_tree


def _setup(cap=8.0):
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                              moe_capacity_factor=cap)
    p = init_tree(jax.random.PRNGKey(0), moe.moe_params(cfg, jnp.float32))
    return cfg, p


def test_ep_matches_dense_single_device():
    cfg, p = _setup(cap=8.0)   # high capacity: no drops -> exact match
    mesh = make_host_mesh(model=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y_ep, aux_ep = moe.moe_apply(p, x, cfg, mesh)
    y_dense, aux_d = moe.moe_apply_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_ep), float(aux_d), rtol=1e-3)


def test_psum_strategy_when_seq_indivisible():
    cfg, p = _setup(cap=8.0)
    mesh = make_host_mesh(model=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model),
                          jnp.float32) * 0.5   # S=1 -> psum path
    y, aux = moe.moe_apply(p, x, cfg, mesh)
    y_d, _ = moe.moe_apply_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_d),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_bounded():
    """With tiny capacity, outputs differ from dense but stay finite and
    the aux loss stays sane (dropping semantics)."""
    cfg, p = _setup(cap=0.5)
    mesh = make_host_mesh(model=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model),
                          jnp.float32)
    y, aux = moe.moe_apply(p, x, cfg, mesh)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0


def test_router_respects_padded_experts():
    cfg, p = _setup()
    xf = jax.random.normal(jax.random.PRNGKey(4), (64, cfg.d_model))
    w, ids, aux = moe._route(xf, p["router"], cfg)
    assert int(ids.max()) < cfg.moe_num_experts, \
        "padded experts must never be selected"
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
