"""Flight-recorder tests (DESIGN.md §14, ISSUE 10).

The §14 contract, pinned:

- **Golden bit-identity** — with `trace_on=0` the instrumented code
  must replay the committed pre-instrumentation fixture
  (`tests/data/trace_golden.json`) bit for bit: reports AND state-leaf
  hashes, solo managed and fixed-role fleet.  The gated scatter writes
  nothing when off; toggling never recompiles (CountingJit-asserted).
- **Host-replay equivalence** — events decoded from the ring must
  match what a host loop recomputes from the raw state transitions
  (alive drops, leader presence, commit advances, warn/reprieve).
- **Exact drop accounting** — a capacity sweep with forced overflow:
  decoded + dropped == emitted per class at every capacity, and the
  small-ring event stream is a per-drain suffix of the big-ring one.
- **First-tick leader_changes** — a leader elected on the FIRST tick
  of an epoch counts, in the in-scan digest AND the host `build_report`
  form, pinned against the trace-derived elect count (the pre-§14
  blindness this PR fixes).
"""
import json
import pathlib
from collections import Counter

import numpy as np
import jax
import pytest

from repro.configs.bwraft_kv import CONFIG
from repro.core import state as SM
from repro.core import step as step_mod
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import (BWRaftSim, build_report, device_epoch,
                                make_cfg_arrays)
from repro.trace import (CLASS_NAMES, EV_COMMIT, EV_ELECT, EV_KILL,
                         EV_REPRIEVE, EV_SEC_STOP, EV_WARN, NCLASS,
                         DrainCursor, default_mask, leader_timeline,
                         timeline, to_perfetto)
from repro.trace import metrics as trace_metrics

GOLDEN = pathlib.Path(__file__).parent / "data" / "trace_golden.json"


def _hash(arr) -> str:
    import hashlib
    return hashlib.sha256(np.ascontiguousarray(
        np.asarray(arr)).tobytes()).hexdigest()


def _reports_match(greports, reports):
    for grep, rep in zip(greports, reports):
        for k, v in grep.items():
            got = getattr(rep, k)
            ok = (repr(float(got)) == v if isinstance(v, str)
                  else int(got) == v)
            if not ok:
                return False, (k, v, got)
    return True, None


def _state_match(gstate, state):
    for k, leaf in gstate.items():
        arr = np.asarray(state[k])
        if list(arr.shape) != leaf["shape"] \
                or str(arr.dtype) != leaf["dtype"] \
                or _hash(arr) != leaf["sha256"]:
            return False, k
    return True, None


# --------------------------------------------------------------------- #
# satellite 1: golden bit-identity + zero-recompile toggles
# --------------------------------------------------------------------- #
def test_trace_off_is_bit_identical_solo():
    """The pre-instrumentation solo trajectory, replayed through the
    instrumented code with tracing off: reports and every state leaf
    hash must match exactly — emit's scatter is provably inert at
    trace_on=0."""
    g = json.loads(GOLDEN.read_text())["solo_managed"]
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=32.0, phi=0.02,
                    seed=0)
    reps = sim.run(len(g["reports"]))
    ok, why = _reports_match(g["reports"], reps)
    assert ok, f"report field diverged: {why}"
    ok, why = _state_match(g["state"], sim.state)
    assert ok, f"state leaf diverged: {why}"


def test_trace_off_is_bit_identical_fleet():
    """Same gate for the fixed-role fleet recipe — the vmapped rings
    and the grouped-reduction plumbing must be equally inert."""
    g = json.loads(GOLDEN.read_text())["fleet_fixed"]
    fleet = FleetSim([
        MemberSpec(cfg=CONFIG, write_rate=6.0, read_rate=24.0, seed=1,
                   manage_resources=False, prelease=(2, 6)),
        MemberSpec(cfg=CONFIG, mode="raft", write_rate=12.0,
                   read_rate=12.0, seed=2, manage_resources=False)])
    fleet.run(len(g["reports"][0]))
    for greports, member in zip(g["reports"], fleet.reports):
        ok, why = _reports_match(greports, member)
        assert ok, f"fleet report field diverged: {why}"
    ok, why = _state_match(g["state"], fleet.state)
    assert ok, f"fleet state leaf diverged: {why}"


def test_trace_toggle_never_recompiles_solo():
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, seed=4,
                    manage_resources=False)
    sim.run(1)
    n0 = sim._epoch_fn.cache_size()
    sim.set_trace(on=True)
    sim.run(1)
    sim.set_trace(mask=default_mask(commit=False, ae=False))
    sim.run(1)
    sim.set_trace(on=False)
    sim.run(1)
    assert sim._epoch_fn.cache_size() == n0, \
        "trace_on/trace_mask flips must be cfg_c data, not compile keys"


def test_trace_toggle_never_recompiles_fleet():
    fleet = FleetSim([MemberSpec(cfg=CONFIG, write_rate=8.0,
                                 read_rate=16.0, seed=i,
                                 manage_resources=False)
                      for i in range(2)])
    fleet.run_epoch()
    n0 = fleet._epoch_fn.cache_size()
    fleet.set_trace(on=True)
    fleet.run_epoch()
    fleet.set_trace(on=False, members=[1])
    fleet.run_epoch()
    assert fleet._epoch_fn.cache_size() == n0
    assert any(e.member == 0 for e in fleet.trace_events)


# --------------------------------------------------------------------- #
# satellite 2: host-replay equivalence + capacity sweep
# --------------------------------------------------------------------- #
def _host_loop(ticks, *, seed=11, phi=0.03, warning_ticks=0,
               capacity=2048, lease=(3, 5), spot_bid=None):
    """Drive step.tick directly, drain every tick, and snapshot the raw
    transitions the events claim to describe."""
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, phi=phi,
                    seed=seed, warning_ticks=warning_ticks,
                    spot_bid=spot_bid,
                    trace_on=True, trace_capacity=capacity)
    if lease is not None:
        sim._lease(*lease)
    static, cfg_c = sim.static, sim.cfg_c
    tickfn = jax.jit(lambda s, r, c: step_mod.tick(s, static, c, r))
    state = sim.state
    rng = jax.random.PRNGKey(seed)
    cur = DrainCursor()
    per_tick = []
    prev = {k: np.asarray(state[k]).copy()
            for k in ("alive", "role", "warn_timer", "commit_len")}
    for t in range(ticks):
        rng, sub = jax.random.split(rng)
        state, _ = tickfn(state, sub, cfg_c)
        now = {k: np.asarray(state[k]) for k in prev}
        per_tick.append({"events": cur.drain(state), "prev": prev,
                         "now": now})
        prev = {k: v.copy() for k, v in now.items()}
    return per_tick, cur


def test_host_replay_alive_drops_and_leader_presence():
    """Every alive->dead transition must be explained by exactly one
    EV_KILL or EV_SEC_STOP event on that node at that tick, and the
    replayed leader timeline must match the per-tick probe."""
    ticks = 3 * CONFIG.period_ticks // 2
    per_tick, cur = _host_loop(ticks)
    assert not any(cur.dropped), cur.dropped_by_class()
    all_events = []
    leader_probe = []
    for t, row in enumerate(per_tick):
        dropped_alive = set(
            np.where(row["prev"]["alive"] & ~row["now"]["alive"])[0])
        explained = {e.node for e in row["events"]
                     if e.code in (EV_KILL, EV_SEC_STOP)}
        assert explained == dropped_alive, \
            (t, sorted(explained), sorted(dropped_alive))
        for e in row["events"]:
            assert e.tick == t, (e, t)
        all_events.extend(row["events"])
        leader_probe.append(bool(((row["now"]["role"] == SM.LEADER) &
                                  row["now"]["alive"]).any()))
    assert len(all_events) > 0
    up = leader_timeline(all_events, ticks)
    assert (up == np.asarray(leader_probe, bool)).all()


def test_host_replay_commit_advances():
    """EV_COMMIT events must be exactly the leader's commit-index
    advances: one event per advancing tick, aux = the new index."""
    ticks = CONFIG.period_ticks
    per_tick, _ = _host_loop(ticks, phi=0.0, seed=2)
    prev_commit = -1
    for t, row in enumerate(per_tick):
        role, alive = row["now"]["role"], row["now"]["alive"]
        lids = np.where((role == SM.LEADER) & alive)[0]
        commits = [e for e in row["events"] if e.code == EV_COMMIT]
        if lids.size:
            c = int(row["now"]["commit_len"][int(lids.max())])
            if prev_commit >= 0 and c > prev_commit:
                assert len(commits) == 1, (t, commits)
                assert commits[0].aux == c, (t, commits[0], c)
            prev_commit = c
        else:
            assert not commits


def test_host_replay_warn_and_reprieve():
    """Under an advance-warning window, every warn_timer arming is an
    EV_WARN and every early signal drop an EV_REPRIEVE.  Warnings come
    from the MARKET signal only (a phi kill is unwarned by design,
    DESIGN.md §12), so the bid is pinned at the price mean to make the
    synthetic walk cross it."""
    ticks = 2 * CONFIG.period_ticks
    per_tick, _ = _host_loop(ticks, phi=0.0, warning_ticks=6, seed=9,
                             spot_bid=0.0125)
    warns = reprieves = 0
    for t, row in enumerate(per_tick):
        armed = set(np.where((row["prev"]["warn_timer"] < 0) &
                             (row["now"]["warn_timer"] >= 0))[0])
        ev_warn = {e.node for e in row["events"] if e.code == EV_WARN}
        assert ev_warn == armed, (t, sorted(ev_warn), sorted(armed))
        # reprieve: the timer was running and reset without a death
        calm = set(np.where((row["prev"]["warn_timer"] >= 0) &
                            (row["now"]["warn_timer"] < 0) &
                            row["now"]["alive"] &
                            row["prev"]["alive"])[0])
        ev_rep = {e.node for e in row["events"] if e.code == EV_REPRIEVE}
        assert ev_rep == calm, (t, sorted(ev_rep), sorted(calm))
        warns += len(ev_warn)
        reprieves += len(ev_rep)
    assert warns > 0, "drill never armed a warning — raise phi/ticks"


@pytest.mark.parametrize("cap", [4, 16, 64])
def test_capacity_sweep_exact_drop_accounting(cap):
    """Forced overflow: per class, decoded + dropped == emitted exactly,
    drops are positive at tiny rings, and every drain's decoded slice is
    a suffix of the full-ring stream (the ring keeps the newest)."""
    epochs = 2

    def run(capacity):
        sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, phi=0.02,
                        seed=6, manage_resources=False, prelease=(2, 4),
                        trace_on=True, trace_capacity=capacity)
        drains, seen = [], 0
        for _ in range(epochs):
            sim.run(1)
            drains.append(list(sim.trace_events[seen:]))
            seen = len(sim.trace_events)
        emitted = np.asarray(sim.state["trace_emit"]).astype(np.int64)
        return sim, drains, emitted

    big_sim, big_drains, big_emit = run(4096)
    sim, drains, emitted = run(cap)
    assert (big_emit == emitted).all(), "emission is capacity-independent"
    assert not any(big_sim._trace_cursor.dropped)

    decoded = np.zeros(NCLASS, np.int64)
    for d in drains:
        for e in d:
            decoded[e.cls] += 1
    dropped = sim._trace_cursor.dropped
    assert (decoded + dropped == emitted).all(), \
        (decoded.tolist(), dropped.tolist(), emitted.tolist())
    if int(emitted.sum()) > epochs * cap:
        assert int(dropped.sum()) > 0, "overflow must report drops"
    key = lambda e: (e.code, e.tick, e.node, e.term, e.aux)
    for small, big in zip(drains, big_drains):
        if small:
            assert [key(e) for e in small] == \
                [key(e) for e in big][-len(small):], \
                "small ring must keep the newest events"


# --------------------------------------------------------------------- #
# satellite 3: first-tick-of-epoch leader_changes
# --------------------------------------------------------------------- #
def _staged_first_tick_state():
    """A cluster one tick away from electing node 0: pre-staged
    candidate with majority-1 banked votes, so the win lands on the
    FIRST tick of the next epoch."""
    static = SM.build_static(CONFIG)
    state = SM.init_state(CONFIG, static)
    maj = int(static["majority"])
    N = state["role"].shape[0]
    state = dict(
        state,
        role=state["role"].at[0].set(SM.CANDIDATE),
        term=state["term"].at[0].set(1),
        voted_for=state["voted_for"].at[0].set(0),
        votes_received=state["votes_received"].at[0].set(maj - 1),
        election_timer=jax.numpy.full((N,), 50, state["election_timer"].dtype),
    )
    return state, static


def test_first_tick_leader_change_counts_in_digest():
    state, static = _staged_first_tick_state()
    cfg_c = make_cfg_arrays(CONFIG, write_rate=0.0, read_rate=0.0,
                            phi=0.0, trace_on=True)
    out, digest = device_epoch(state, static, cfg_c,
                               jax.random.PRNGKey(0), 1)
    assert int(digest["no_leader_ticks"]) == 0, "the win must land tick 0"
    assert int(digest["leader_changes"]) == 1, \
        "a first-tick election is a leader change (pre-§14 blindness)"
    events = DrainCursor().drain(out)
    elects = [e for e in events if e.code == EV_ELECT]
    assert len(elects) == 1 and elects[0].node == 0 and elects[0].tick == 0
    assert int(digest["leader_changes"]) == len(elects), \
        "digest count must agree with the trace-derived count"


def test_first_tick_leader_change_counts_in_host_report():
    state, static = _staged_first_tick_state()
    cfg_c = make_cfg_arrays(CONFIG, write_rate=0.0, read_rate=0.0, phi=0.0)
    st, m = step_mod.tick(state, static, cfg_c, jax.random.PRNGKey(0),
                          reference=True)
    ms = jax.tree.map(lambda x: np.asarray(x)[None], m)
    rep = build_report(0, jax.tree.map(np.asarray, st), ms, 0.0,
                       leader_term0=-1)
    assert rep.leader_changes == 1, \
        "host np.diff form must count the first tick given leader_term0"


# --------------------------------------------------------------------- #
# metrics registry + export surfaces
# --------------------------------------------------------------------- #
def test_metrics_registry_always_on_and_per_epoch():
    """Named counters flow through the digest with tracing OFF, and
    compaction resets them so each report is per-epoch."""
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, seed=3,
                    manage_resources=False, prelease=(2, 4))
    r1, r2 = sim.run(2)
    for rep in (r1, r2):
        assert rep.metrics is not None
        assert set(rep.metrics) == set(trace_metrics.COUNTERS)
    assert r1.metrics["leader_elected"] >= 1
    assert r2.metrics["elections_started"] <= r1.metrics["elections_started"], \
        "counters must reset at compaction (steady state re-elects less)"
    assert r2.metrics["commit_advances"] > 0
    assert len(sim.trace_events) == 0, "no ring writes while off"


def test_metrics_match_trace_counts():
    """The in-digest counters and the decoded ring agree where a class
    is 1 event : 1 count (elections, kills, commits)."""
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, phi=0.02,
                    seed=5, manage_resources=False, prelease=(2, 4),
                    trace_on=True, trace_capacity=4096)
    reps = sim.run(2)
    assert not any(sim._trace_cursor.dropped)
    by = Counter(e.code for e in sim.trace_events)
    tot = {k: sum(r.metrics[k] for r in reps) for k in reps[0].metrics}
    assert by[EV_ELECT] == tot["leader_elected"]
    assert by[EV_KILL] == tot["kills"]
    assert by[EV_COMMIT] == tot["commit_advances"]


def test_perfetto_export_shape():
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, phi=0.02,
                    seed=5, manage_resources=False, prelease=(2, 4),
                    trace_on=True, trace_capacity=4096)
    sim.run(2)
    doc = to_perfetto(sim.trace_events,
                      ticks=2 * CONFIG.period_ticks,
                      annotations=[{"name": "read k", "start_tick": 3,
                                    "end_tick": 9, "fence": 2}])
    evs = doc["traceEvents"]
    assert evs and all({"ph", "pid", "name"} <= set(e) for e in evs)
    assert any(e["ph"] == "X" and e["tid"] == 9_999 for e in evs), \
        "leader tenure spans must be on the leader track"
    assert any(e.get("name") == "read k" for e in evs), \
        "client annotations must land in the export"
    assert json.loads(json.dumps(doc)) == doc
    art = timeline.render(sim.trace_events, ticks=2 * CONFIG.period_ticks)
    assert "leader" in art and "\n" in art


def test_trace_mask_filters_classes():
    """Masking a class suppresses its ring events AND its drop
    accounting, while the unmasked classes still record."""
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=16.0, phi=0.02,
                    seed=5, manage_resources=False, prelease=(2, 4),
                    trace_on=True, trace_capacity=4096,
                    trace_mask=default_mask(commit=False))
    reps = sim.run(2)
    codes = Counter(e.cls for e in sim.trace_events)
    assert codes[CLASS_NAMES.index("commit")] == 0
    assert sum(codes.values()) > 0
    assert sum(r.metrics["commit_advances"] for r in reps) > 0, \
        "metrics registry must stay on under a mask"


# --------------------------------------------------------------------- #
# satellite 6: BENCH schema over every committed artifact
# --------------------------------------------------------------------- #
def test_bench_schema_validates_all_committed_files():
    import sys
    repo = pathlib.Path(__file__).parent.parent
    sys.path.insert(0, str(repo))
    from benchmarks.common import validate_bench_file
    files = sorted(repo.glob("BENCH_*.json"))
    expected = {"BENCH_fleet.json", "BENCH_tick.json", "BENCH_market.json",
                "BENCH_serving.json", "BENCH_faults.json",
                "BENCH_observers.json", "BENCH_trace.json"}
    assert expected <= {f.name for f in files}, \
        f"missing committed BENCH files: {expected - {f.name for f in files}}"
    problems = [p for f in files for p in validate_bench_file(f)]
    assert not problems, problems
