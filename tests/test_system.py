"""End-to-end behaviour: the BW-Raft system does its job."""
import numpy as np
import pytest

from repro.configs.bwraft_kv import CONFIG as CC
from repro.core.runtime import BWRaftSim
from repro.core.multiraft import MultiRaftSim


def test_bwraft_reaches_steady_state():
    sim = BWRaftSim(CC, write_rate=8.0, read_rate=32.0, seed=3)
    reps = sim.run(5)
    last = reps[-1]
    assert last.no_leader_ticks == 0, "leadership must stabilize"
    assert last.writes_committed > 0
    assert last.reads_served > 0.5 * last.reads_arrived
    assert np.isfinite(last.write_lat_p95)
    assert last.n_secretaries > 0 and last.n_observers > 0, \
        "Algorithm 1 must lease spot roles"


def test_raft_mode_never_uses_spot():
    sim = BWRaftSim(CC, mode="raft", write_rate=8.0, read_rate=16.0, seed=1)
    reps = sim.run(3)
    assert all(r.n_secretaries == 0 and r.n_observers == 0 for r in reps)
    assert reps[-1].writes_committed > 0


def test_secretary_offload_scales_writes():
    """The paper's core claim: at large follower counts plain Raft's
    leader chokes on fan-out; BW-Raft holds throughput (Fig. 7)."""
    import dataclasses
    from repro.core.cluster_config import ClusterConfig, SiteConfig
    sites = tuple(SiteConfig(n, followers=8, rtt_intra=1, rtt_inter=r,
                             on_demand_price=0.0416, spot_price_mean=0.0125)
                  for n, r in [("eu", 8), ("asia", 10), ("us-e", 6),
                               ("us-w", 7)])
    cfg = ClusterConfig(name="scale", sites=sites)
    raft = BWRaftSim(cfg, mode="raft", write_rate=16.0, read_rate=8.0,
                     seed=5).run(5)[-1]
    bw = BWRaftSim(cfg, mode="bwraft", write_rate=16.0, read_rate=8.0,
                   seed=5).run(5)[-1]
    assert bw.writes_committed > 1.5 * raft.writes_committed


def test_all_spot_loss_reverts_to_raft():
    """Extreme case (paper §3.2): all spot instances fail -> plain Raft."""
    sim = BWRaftSim(CC, write_rate=8.0, read_rate=16.0, seed=7)
    sim.run(2)
    sim.set_rates(phi=1.0)       # kill every spot node each tick
    rep = sim.run_epoch()
    assert rep.n_secretaries == 0 and rep.n_observers == 0
    sim.set_rates(phi=0.0)
    sim.manage = True
    rep2 = sim.run_epoch()
    assert rep2.writes_committed > 0, "consensus survives total spot loss"


def test_multiraft_costs_more_per_goodput():
    bw = BWRaftSim(CC, write_rate=8.0, read_rate=32.0, seed=3)
    mr = MultiRaftSim(CC, shards=2, write_rate=8.0, read_rate=32.0, seed=3)
    bw_r = bw.run(4)[-1]
    mr_r = mr.run_epoch()
    for _ in range(3):
        mr_r = mr.run_epoch()
    bw_cpg = bw_r.cost / max(bw_r.goodput, 1)
    mr_cpg = mr_r.cost / max(mr_r.goodput, 1)
    assert bw_cpg < mr_cpg, (bw_cpg, mr_cpg)
