"""The widened kernel layer (DESIGN.md §8): the PR-9 Pallas families —
leader fan-out, grouped digest reduction, anti-entropy sync — are each
**bit-identical** to their frozen `ref.py` twins (the XLA formulations
lifted from `core/step.py` / `core/fleet.py`) under interpret mode,
across dead-slot masks, degenerate windows, ragged/empty groups, and
the warned-secretary handoff; `backend="auto"` resolves per platform
and threads through `tick` / `BWRaftSim` / `FleetSim.from_sweep`
without costing the one-compile / digest-only-D2H contract (§7/§7.1).

The randomized sweeps run through hypothesis when it is installed
(requirements-dev.txt) and fall back to fixed-seed sweeps otherwise, so
the bit-identity invariant is enforced either way."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fleet as fleet_mod
from repro.core import state as SM
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim
from repro.core.runtime import BWRaftSim, make_cfg_arrays
from repro.core.state import pytree_nbytes
from repro.kernels import BACKENDS, resolve_backend
from repro.kernels.ae_sync import ops as ae_ops
from repro.kernels.ae_sync import ref as ae_ref
from repro.kernels.group_digest import ops as gd_ops
from repro.kernels.group_digest import ref as gd_ref
from repro.kernels.leader_fanout import ops as lf_ops
from repro.kernels.leader_fanout import ref as lf_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

u2i = lambda v: jax.lax.bitcast_convert_type(
    jnp.asarray(v, jnp.uint32), jnp.int32)


# --------------------------------------------------------------------- #
# case builders / checkers
# --------------------------------------------------------------------- #
def _fanout_case(N, L, seed, *, has_leader=True, alive_frac=0.8,
                 pending_frac=0.6, warn_frac=0.3):
    rng = np.random.default_rng(seed)
    mk = lambda lo, hi, sh: jnp.asarray(rng.integers(lo, hi, sh),
                                        jnp.int32)
    lid = int(rng.integers(0, N))
    warn = np.where(rng.random(N) < warn_frac, rng.integers(0, 5, N), -1)
    arrive = np.where(rng.random(N) < pending_frac, -1,
                      rng.integers(0, 40, N))
    return dict(
        role=mk(0, 6, (N,)),
        alive=jnp.asarray(rng.random(N) < alive_frac),
        warn_timer=jnp.asarray(warn, jnp.int32),
        sec_of=mk(-1, N, (N,)), match_len=mk(0, L + 1, (N,)),
        app_arrive_t=jnp.asarray(arrive, jnp.int32),
        app_from_len=mk(0, L + 1, (N,)), app_upto=mk(0, L + 1, (N,)),
        app_term=mk(0, 4, (N,)), app_commit=mk(0, L + 1, (N,)),
        rtt=mk(1, 20, (N, N)), lid_c=jnp.int32(lid),
        has_leader=jnp.asarray(has_leader),
        tick=jnp.int32(int(rng.integers(0, 100))),
        ldr_len=jnp.int32(int(rng.integers(0, L + 1))),
        ldr_term=mk(0, 4, ()), ldr_commit=mk(0, L + 1, ()))


_FANOUT_OUT = ("app_arrive_t", "app_from_len", "app_upto", "app_term",
               "app_commit", "work")


def _check_fanout(case, msg_budget, max_ship, entries_per_msg):
    kw = dict(msg_budget=msg_budget, max_ship=max_ship,
              entries_per_msg=entries_per_msg)
    got = lf_ops.leader_fanout(*case.values(), **kw)
    want = lf_ref.leader_fanout_ref(*case.values(), **kw)
    for name, g, w in zip(_FANOUT_OUT, got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), \
            (name, msg_budget, max_ship, entries_per_msg)


def _group_case(B, G, Fi, Ff, seed, *, dropped_frac=0.2):
    rng = np.random.default_rng(seed)
    gids = np.where(rng.random(B) < dropped_frac, G,
                    rng.integers(0, max(G, 1), B))
    return (jnp.asarray(gids, jnp.int32),
            jnp.asarray(rng.integers(-50, 2**20, (B, Fi)), jnp.int32),
            jnp.asarray(rng.standard_normal((B, Ff)) * 100.0,
                        jnp.float32))


def _check_group(gids, int_mat, flt_mat, G):
    got = gd_ops.group_reduce(gids, int_mat, flt_mat, n_groups=G)
    want = gd_ref.group_reduce_ref(gids, int_mat, flt_mat, n_groups=G)
    for name, g, w in zip(("int_sum", "flt_sum", "flt_max"), got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (name, G)


def _ae_case(O, N, S, seed, *, voter_frac=0.6, alive_frac=0.8,
             interval=4):
    rng = np.random.default_rng(seed)
    mk = lambda lo, hi, sh: jnp.asarray(rng.integers(lo, hi, sh),
                                        jnp.int32)
    u32 = lambda sh: jnp.asarray(
        rng.integers(0, 2**32, sh, dtype=np.uint32))
    return dict(
        dobs_alive=mk(0, 2, (O,)), dobs_fol=mk(-1, N, (O,)),
        dobs_applied=mk(0, 64, (O,)), dobs_term=mk(0, 4, (O,)),
        dobs_digest=u32((O,)), dobs_synced_t=mk(-1, 40, (O,)),
        ae_phase=mk(0, max(interval, 1) + 1, (O,)),
        dobs_site=mk(0, S, (O,)),
        alive=jnp.asarray(rng.random(N) < alive_frac),
        is_voter=jnp.asarray(rng.random(N) < voter_frac),
        applied_len=mk(0, 65, (N,)), term=mk(0, 4, (N,)),
        applied_digest=u32((N,)), site=mk(0, S, (N,)),
        site_rtt=mk(1, 20, (S, S)),
        tick=jnp.int32(int(rng.integers(0, 100))),
        ae_interval=jnp.int32(interval))


_AE_OUT = ("dobs_applied", "dobs_term", "dobs_digest", "dobs_synced_t")


def _check_ae(case):
    got = ae_ops.ae_sync(*case.values())
    c = dict(case, dobs_digest=u2i(case["dobs_digest"]),
             applied_digest=u2i(case["applied_digest"]))
    want = ae_ref.ae_sync_ref(*c.values())
    want = (want[0], want[1],
            jax.lax.bitcast_convert_type(want[2], jnp.uint32), want[3])
    for name, g, w in zip(_AE_OUT, got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), name


# --------------------------------------------------------------------- #
# property tests: hypothesis when available, fixed-seed sweep otherwise
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(N=st.integers(1, 24), L=st.integers(1, 128),
           msg_budget=st.integers(0, 20), max_ship=st.integers(1, 64),
           entries_per_msg=st.integers(1, 64), seed=st.integers(0, 2**31),
           has_leader=st.booleans(), alive_frac=st.floats(0.0, 1.0))
    def test_leader_fanout_matches_ref(N, L, msg_budget, max_ship,
                                       entries_per_msg, seed, has_leader,
                                       alive_frac):
        """Fused fan-out == cumsum/gather twin under arbitrary roles,
        secretary wiring, warn timers, and dead-slot masks."""
        case = _fanout_case(N, L, seed, has_leader=has_leader,
                            alive_frac=alive_frac)
        _check_fanout(case, msg_budget, max_ship, entries_per_msg)

    @settings(max_examples=25, deadline=None)
    @given(B=st.integers(1, 48), G=st.integers(1, 10),
           Fi=st.integers(1, 150), Ff=st.integers(1, 4),
           seed=st.integers(0, 2**31), dropped=st.floats(0.0, 1.0))
    def test_group_reduce_matches_ref(B, G, Fi, Ff, seed, dropped):
        """Blockwise masked reduction == segment_sum/segment_max twins —
        bit-exact float sums (ascending member order) and the -inf
        empty-group max identity, any ragged/dropped mix."""
        _check_group(*_group_case(B, G, Fi, Ff, seed,
                                  dropped_frac=dropped), G)

    @settings(max_examples=25, deadline=None)
    @given(O=st.integers(1, 12), N=st.integers(1, 24),
           S=st.integers(1, 4), seed=st.integers(0, 2**31),
           voter_frac=st.floats(0.0, 1.0), interval=st.integers(0, 8))
    def test_ae_sync_matches_ref(O, N, S, seed, voter_frac, interval):
        """Fused anti-entropy round == argmax/gather twin under
        arbitrary wiring, dead sources, and traced cadence (including
        interval=0, which clamps to 1 on both sides)."""
        _check_ae(_ae_case(O, N, S, seed, voter_frac=voter_frac,
                           interval=interval))
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_leader_fanout_matches_ref(seed):
        rng = np.random.default_rng(400 + seed)
        case = _fanout_case(int(rng.integers(1, 24)),
                            int(rng.integers(1, 128)), seed,
                            has_leader=bool(rng.integers(0, 2)),
                            alive_frac=float(rng.random()))
        _check_fanout(case, int(rng.integers(0, 20)),
                      int(rng.integers(1, 64)), int(rng.integers(1, 64)))

    @pytest.mark.parametrize("seed", range(8))
    def test_group_reduce_matches_ref(seed):
        rng = np.random.default_rng(500 + seed)
        G = int(rng.integers(1, 10))
        _check_group(*_group_case(int(rng.integers(1, 48)), G,
                                  int(rng.integers(1, 150)),
                                  int(rng.integers(1, 4)), seed,
                                  dropped_frac=float(rng.random())), G)

    @pytest.mark.parametrize("seed", range(8))
    def test_ae_sync_matches_ref(seed):
        rng = np.random.default_rng(600 + seed)
        _check_ae(_ae_case(int(rng.integers(1, 12)),
                           int(rng.integers(1, 24)),
                           int(rng.integers(1, 4)), seed,
                           voter_frac=float(rng.random()),
                           interval=int(rng.integers(0, 8))))


# --------------------------------------------------------------------- #
# directed degenerate cases
# --------------------------------------------------------------------- #
def test_leader_fanout_warned_secretary_hands_off():
    """A warned secretary stops relaying NOW (DESIGN.md §12): followers
    wired to it fall back to direct leader fan-out, unwarned relays keep
    relaying — and the kernel agrees with the ref on both."""
    N = 6
    z = lambda v: jnp.asarray(v, jnp.int32)
    case = dict(
        role=z([2, 3, 3, 0, 0, 0]),            # leader, 2 secs, 3 fols
        alive=jnp.asarray([True] * 6),
        warn_timer=z([-1, 3, -1, -1, -1, -1]),  # sec 1 warned, sec 2 not
        sec_of=z([-1, -1, -1, 1, 2, -1]),
        match_len=z([0, 0, 0, 4, 8, 2]),
        app_arrive_t=z([-1] * 6), app_from_len=z([0] * 6),
        app_upto=z([0] * 6), app_term=z([0] * 6), app_commit=z([0] * 6),
        rtt=jnp.full((N, N), 3, jnp.int32), lid_c=jnp.int32(0),
        has_leader=jnp.asarray(True), tick=jnp.int32(10),
        ldr_len=jnp.int32(32), ldr_term=jnp.int32(2),
        ldr_commit=jnp.int32(16))
    kw = dict(msg_budget=16, max_ship=16, entries_per_msg=8)
    got = lf_ops.leader_fanout(*case.values(), **kw)
    _check_fanout(case, **kw)
    arrive = np.asarray(got[0])
    assert arrive[3] >= 0 and arrive[4] >= 0 and arrive[5] >= 0
    # follower 4 relays (two rtt hops), followers 3/5 go direct (one)
    assert arrive[4] == 10 + 6 and arrive[3] == arrive[5] == 10 + 3


def test_leader_fanout_no_leader_and_budget_zero():
    """has_leader=False passes every app_* row through untouched;
    budget 0 still ships relayed batches (secretaries carry them) but
    cuts every direct target."""
    case = _fanout_case(8, 32, 11, has_leader=False)
    got = lf_ops.leader_fanout(*case.values(), msg_budget=4, max_ship=8,
                               entries_per_msg=4)
    for name, g in zip(_FANOUT_OUT, got):
        if name != "work":
            assert np.array_equal(np.asarray(g),
                                  np.asarray(case[name])), name
    assert int(got[5]) == 0
    for seed in range(4):
        case = _fanout_case(10, 32, 20 + seed, warn_frac=0.0)
        _check_fanout(case, 0, 8, 4)


def test_group_reduce_empty_and_all_dropped():
    """All members dropped -> every group is empty: 0 sums, -inf max —
    the segment-op identities; a lone member lands alone."""
    gids, int_mat, flt_mat = _group_case(6, 3, 5, 2, 0)
    gids = jnp.full_like(gids, 3)                 # everyone dropped
    _check_group(gids, int_mat, flt_mat, 3)
    g_int, g_sum, g_max = gd_ops.group_reduce(gids, int_mat, flt_mat,
                                              n_groups=3)
    assert not np.asarray(g_int).any() and not np.asarray(g_sum).any()
    assert (np.asarray(g_max) == -np.inf).all()
    _check_group(jnp.asarray([0], jnp.int32),
                 jnp.ones((1, 1), jnp.int32),
                 jnp.full((1, 1), 2.5, jnp.float32), 1)


def test_group_reduce_float_order_is_scatter_add_order():
    """One big group: the kernel's ascending accumulation reproduces
    segment_sum's float result bit-for-bit (not just approximately)."""
    rng = np.random.default_rng(42)
    B = 37
    flt = jnp.asarray(rng.standard_normal((B, 3)) * 1e3, jnp.float32)
    gids = jnp.zeros((B,), jnp.int32)
    got = gd_ops.group_reduce(gids, jnp.zeros((B, 1), jnp.int32), flt,
                              n_groups=1)[1]
    want = jax.ops.segment_sum(flt, gids, num_segments=1)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_ae_sync_no_voter_and_dead_observers():
    """Zero live voters -> nothing is due, every dobs_* row passes
    through; dead observer slots never adopt even when due."""
    case = _ae_case(6, 8, 2, 5)
    case["is_voter"] = jnp.asarray([False] * 8)
    got = ae_ops.ae_sync(*case.values())
    _check_ae(case)
    for name, g in zip(_AE_OUT, got):
        assert np.array_equal(np.asarray(g), np.asarray(case[name])), name
    case = _ae_case(6, 8, 2, 6, interval=1)       # everyone due...
    case["dobs_alive"] = jnp.zeros((6,), jnp.int32)   # ...but dead slots
    got = ae_ops.ae_sync(*case.values())
    _check_ae(case)
    for name, g in zip(_AE_OUT, got):
        assert np.array_equal(np.asarray(g), np.asarray(case[name])), name


def test_ae_sync_monotone_adoption():
    """An observer ahead of its source keeps its applied index (and the
    digest/term that go with it) — adoption never regresses."""
    case = _ae_case(4, 6, 2, 7, interval=1)
    case["dobs_alive"] = jnp.ones((4,), jnp.int32)
    case["dobs_applied"] = jnp.full((4,), 1000, jnp.int32)
    case["applied_len"] = jnp.zeros((6,), jnp.int32)
    got = ae_ops.ae_sync(*case.values())
    _check_ae(case)
    assert np.array_equal(np.asarray(got[0]),
                          np.asarray(case["dobs_applied"]))
    assert np.array_equal(np.asarray(got[2]),
                          np.asarray(case["dobs_digest"]))


def test_wide_ops_batch_under_vmap():
    """vmapped wide ops over a fleet axis == per-member ref calls — the
    form the `FleetSim(backend="pallas")` epoch body exercises."""
    cases = [_fanout_case(9, 48, s) for s in range(3)]
    batched = {k: jnp.stack([c[k] for c in cases]) for k in cases[0]}
    kw = dict(msg_budget=6, max_ship=16, entries_per_msg=8)
    got = jax.vmap(lambda c: lf_ops.leader_fanout(
        c["role"], c["alive"], c["warn_timer"], c["sec_of"],
        c["match_len"], c["app_arrive_t"], c["app_from_len"],
        c["app_upto"], c["app_term"], c["app_commit"], c["rtt"],
        c["lid_c"], c["has_leader"], c["tick"], c["ldr_len"],
        c["ldr_term"], c["ldr_commit"], **kw))(batched)
    for b, case in enumerate(cases):
        want = lf_ref.leader_fanout_ref(*case.values(), **kw)
        for name, g, w in zip(_FANOUT_OUT, got, want):
            assert np.array_equal(np.asarray(g[b]), np.asarray(w)), \
                (b, name)

    groups = [_group_case(16, 4, 7, 3, s) for s in range(3)]
    bg = tuple(jnp.stack([c[i] for c in groups]) for i in range(3))
    got = jax.vmap(
        lambda g, i, f: gd_ops.group_reduce(g, i, f, n_groups=4))(*bg)
    for b, (gids, int_mat, flt_mat) in enumerate(groups):
        want = gd_ref.group_reduce_ref(gids, int_mat, flt_mat, n_groups=4)
        for g, w in zip(got, want):
            assert np.array_equal(np.asarray(g[b]), np.asarray(w)), b


def test_fleet_group_digest_pallas_equals_xla():
    """`fleet._group_digest` on the kernel == the segment-op path, on a
    synthetic digest with ragged groups, dropped members, and an empty
    group — every leaf, exact (the §9 Multi-Raft rollup)."""
    rng = np.random.default_rng(9)
    B, G, H = 11, 4, 32
    digest = {}
    for k in fleet_mod._GROUP_SUM_KEYS:
        if k.endswith("_hist"):
            digest[k] = jnp.asarray(rng.integers(0, 50, (B, H)), jnp.int32)
        elif k in fleet_mod._GROUP_FLOAT_KEYS:
            digest[k] = jnp.asarray(rng.standard_normal(B) * 40.0,
                                    jnp.float32)
        else:
            digest[k] = jnp.asarray(rng.integers(0, 100, B), jnp.int32)
    digest["read_lat_max"] = jnp.asarray(rng.standard_normal(B) * 9.0,
                                         jnp.float32)
    gids = jnp.asarray([0, 0, 1, 4, 1, 2, 2, 2, 4, 0, 1], jnp.int32)
    # group 3 is empty; id 4 == G marks the two dropped members
    x = fleet_mod._group_digest(digest, gids, G, backend="xla")
    p = fleet_mod._group_digest(digest, gids, G, backend="pallas")
    assert set(x) == set(p)
    for k in x:
        assert np.array_equal(np.asarray(x[k]), np.asarray(p[k])), k


# --------------------------------------------------------------------- #
# end-to-end: observers in the loop + backend="auto" plumbing
# --------------------------------------------------------------------- #
def _small_cluster(name="wtiny", followers=(2, 1), max_log=256):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=64, max_secretaries=2,
                         max_observers=4, period_ticks=40)


def test_observer_trajectory_pallas_equals_xla():
    """With digest-tier observers provisioned, a 60-tick pallas scan ==
    the xla scan on EVERY state leaf — the anti-entropy kernel rides
    the real tick, not just its ref twin."""
    cfg = _small_cluster()
    static = SM.build_static(cfg, n_obs_digest=3)
    cfg_c = make_cfg_arrays(cfg, write_rate=6.0, read_rate=12.0, phi=0.05,
                            n_observers=3, ae_interval=3)
    state0 = SM.init_state(cfg, static)
    rngs = jax.random.split(jax.random.PRNGKey(5), 60)

    def run(backend):
        def body(c, r):
            s, _ = step_mod.tick(c, static, cfg_c, r, backend=backend)
            return s, None
        out, _ = jax.jit(lambda s: jax.lax.scan(body, s, rngs))(state0)
        return jax.tree.map(np.asarray, out)

    x, p = run("xla"), run("pallas")
    assert any(k.startswith("dobs_") for k in x)   # observers really ran
    for k in x:
        assert np.array_equal(x[k], p[k]), f"state[{k}] diverged"


def test_backend_auto_resolution():
    """'auto' resolves per platform (pallas iff TPU), explicit choices
    pass through, junk is rejected — and the resolution lands on the
    sim/fleet objects."""
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert resolve_backend("auto") == expect
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("pallas") == "pallas"
    assert set(BACKENDS) == {"auto", "xla", "pallas"}
    with pytest.raises(AssertionError):
        resolve_backend("cuda")
    cfg = _small_cluster("wauto", followers=(1, 1))
    sim = BWRaftSim(cfg, write_rate=4.0, read_rate=8.0, seed=0,
                    manage_resources=False, backend="auto")
    assert sim.backend == expect
    fleet = FleetSim.from_sweep(cfg, {"phi": [0.0, 0.05]},
                                write_rate=4.0, read_rate=8.0, seed=0,
                                backend="auto")
    assert fleet.backend == expect


def test_auto_backend_sweep_b32_single_compile_digest_d2h():
    """The ISSUE-9 acceptance sweep: 32 clusters on backend="auto" cost
    ONE epoch compilation and one dispatch per epoch, and per-epoch D2H
    stays digest-sized (§7.1) — auto resolution shares the cache with
    its explicit resolution."""
    cfg = _small_cluster("wb32", followers=(1, 1))
    fleet = FleetSim.from_sweep(
        cfg, {"phi": [0.0, 0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2],
              "write_rate": [4.0, 8.0, 16.0, 32.0]},
        read_rate=16.0, seed=0, backend="auto")
    assert fleet.shapes.B == 32
    fleet.run(1)
    assert fleet.compile_count == 1, fleet.compile_count
    # digest-only D2H ceiling: a few KB per cluster per epoch, well
    # under the device-resident state (which never crosses)
    assert fleet.d2h_bytes < fleet.shapes.B * 4096, fleet.d2h_bytes
    assert fleet.d2h_bytes < pytree_nbytes(fleet.state) / 10, \
        (fleet.d2h_bytes, pytree_nbytes(fleet.state))
    # auto and its resolution hit the same compiled program
    resolved = FleetSim.from_sweep(
        cfg, {"phi": [0.0, 0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2],
              "write_rate": [4.0, 8.0, 16.0, 32.0]},
        read_rate=16.0, seed=0, backend=resolve_backend("auto"))
    assert resolved._epoch_fn is fleet._epoch_fn
