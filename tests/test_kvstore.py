"""BW-KV service semantics over the consensus core."""
import pytest

from repro.configs.bwraft_kv import CONFIG as CC
from repro.core.runtime import BWRaftSim
from repro.kvstore.service import BWKVService


@pytest.fixture(scope="module")
def svc():
    sim = BWRaftSim(CC, write_rate=0.0, read_rate=0.0, seed=9,
                    manage_resources=False)
    s = BWKVService(sim)
    s._step(120)    # elect
    return s


def test_put_get_roundtrip(svc):
    r = svc.put("hello", 42)
    assert r.revision >= 0
    v, rev = svc.get("hello")
    assert v == 42


def test_overwrite_returns_latest(svc):
    svc.put("key2", 1)
    svc.put("key2", 2)
    v, _ = svc.get("key2")
    assert v == 2


def test_reads_follow_commits(svc):
    res = svc.put("key3", 7)
    v, rev = svc.get("key3")
    assert v == 7 and rev > res.revision
