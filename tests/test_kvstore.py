"""BW-KV service semantics over the consensus core: the explicit
read-index round (leader commit fence + replica apply wait,
DESIGN.md §11), its NotLeader/Timeout raise paths, and key-hash
stability."""
import hashlib

import numpy as np
import pytest

from repro.configs.bwraft_kv import CONFIG as CC
from repro.core import state as SM
from repro.core.runtime import BWRaftSim
from repro.kvstore.service import BWKVService, NotLeader, Timeout


def fresh_service(*, seed=9, elect=True, timeout_ticks=400,
                  observers=0) -> BWKVService:
    sim = BWRaftSim(CC, write_rate=0.0, read_rate=0.0, seed=seed,
                    manage_resources=False)
    if observers:
        sim._lease(0, observers)
    s = BWKVService(sim, timeout_ticks=timeout_ticks)
    if elect:
        s._step(120)
    return s


@pytest.fixture(scope="module")
def svc():
    return fresh_service()


def test_put_get_roundtrip(svc):
    r = svc.put("hello", 42)
    assert r.revision >= 0
    v, rev = svc.get("hello")
    assert v == 42


def test_overwrite_returns_latest(svc):
    svc.put("key2", 1)
    svc.put("key2", 2)
    v, _ = svc.get("key2")
    assert v == 2


def test_reads_follow_commits(svc):
    res = svc.put("key3", 7)
    v, rev = svc.get("key3")
    assert v == 7 and rev > res.revision


# ------------------------------------------------------------------ #
# the explicit read-index round (DESIGN.md §11)
# ------------------------------------------------------------------ #
def test_put_then_get_returns_committed_revision(svc):
    """The read's revision is the leader commit fence at request time:
    at least past the put's log position, and the value is the
    committed one."""
    res = svc.put("fence", 11)
    v, rev = svc.get("fence")
    assert v == 11
    assert rev > res.revision          # fence covers the committed put
    lid = int(SM.leader_id(svc.sim.state, svc.sim.static))
    assert rev <= int(svc.sim.state["commit_len"][lid])


def test_read_index_round_records_latency(svc):
    """Every completed get records its round latency on the service AND
    in the cluster's device-resident read histogram (DESIGN.md §11)."""
    svc.put("lat", 5)
    n0 = len(svc.read_latencies)
    h0 = int(np.asarray(svc.sim.state["read_lat_hist"]).sum())
    s0 = int(svc.sim.state["reads_served"])
    v, _ = svc.get("lat")
    assert v == 5
    assert len(svc.read_latencies) == n0 + 1
    assert svc.read_latencies[-1] >= 0
    assert int(np.asarray(svc.sim.state["read_lat_hist"]).sum()) == h0 + 1
    assert int(svc.sim.state["reads_served"]) == s0 + 1


def test_observer_serves_caught_up_read():
    """With a caught-up observer wired, the round serves from it (the
    observer offload of paper §3.1 step 6)."""
    s = fresh_service(seed=11, observers=4)
    s.put("obs", 21)
    s._step(30)                        # let observers catch up
    st = s.sim.state
    role = np.asarray(st["role"])
    alive = np.asarray(st["alive"])
    lid = int(SM.leader_id(st, s.sim.static))
    readindex = int(st["commit_len"][lid])
    applied = np.asarray(st["applied_len"])
    caught = (role == SM.OBSERVER) & alive & (applied >= readindex)
    assert caught.any(), "no observer caught up — wiring broke"
    v, rev = s.get("obs")
    assert v == 21 and rev >= readindex


def test_uncommitted_log_entry_not_readable(svc):
    """A log entry that has not committed is invisible to the read-index
    round: the fence is the leader's COMMIT index, so a read served by a
    caught-up replica returns the last committed value, never log tail."""
    svc.put("dirty", 1)
    svc._step(30)                      # settle: applied reaches commit
    st = svc.sim.state
    lid = int(SM.leader_id(st, svc.sim.static))
    kid = svc._key_id("dirty")
    pos = int(st["log_len"][lid])
    # append an UNCOMMITTED overwrite directly to the leader's log
    svc.sim.state = dict(
        st,
        log_term=st["log_term"].at[lid, pos].set(st["term"][lid]),
        log_key=st["log_key"].at[lid, pos].set(kid),
        log_val=st["log_val"].at[lid, pos].set(999),
        log_len=st["log_len"].at[lid].set(pos + 1),
    )
    v, rev = svc.get("dirty")
    assert v == 1, "read returned uncommitted data"
    assert rev <= pos                  # fence stops at the commit index


# ------------------------------------------------------------------ #
# NotLeader / Timeout raise paths
# ------------------------------------------------------------------ #
def test_get_without_leader_raises_notleader():
    s = fresh_service(seed=13, elect=False)   # t=0: nobody elected yet
    assert int(SM.leader_id(s.sim.state, s.sim.static)) < 0
    with pytest.raises(NotLeader):
        s.get("anything")


def test_get_wait_for_leader_times_out():
    """`wait_for_leader=True` bounds the election wait by Timeout — a
    read during an election waits or times out, never serves."""
    s = fresh_service(seed=13, elect=False, timeout_ticks=5)
    n0 = len(s.read_latencies)
    with pytest.raises(Timeout):
        s.get("anything", wait_for_leader=True)
    assert len(s.read_latencies) == n0    # nothing served, nothing logged


def test_read_during_election_waits_or_times_out_never_stale():
    """Kill the leader mid-session.  A plain get raises NotLeader; a
    waiting get blocks through the election — and because the fresh
    leader cannot commit the old-term entry until a current-term entry
    commits (the Raft §5.4.2 rule), the session fence makes the read
    TIME OUT rather than return a value older than the acked write.
    Once a new write re-establishes the commit index, the read serves
    the acked value."""
    s = fresh_service(seed=15, timeout_ticks=120)
    s.put("ha", 77)
    floor = s.session_floor
    assert floor >= 1
    st = s.sim.state
    lid = int(SM.leader_id(st, s.sim.static))
    s.sim.state = dict(
        st,
        role=st["role"].at[lid].set(SM.DEAD),
        alive=st["alive"].at[lid].set(False),
    )
    with pytest.raises(NotLeader):
        s.get("ha")
    # waits through the election, then refuses to serve below the
    # session floor: Timeout, never the pre-write value
    with pytest.raises(Timeout):
        s.get("ha", wait_for_leader=True)
    # a current-term write re-establishes the commit fence ...
    s.timeout = 400
    s.put("nudge", 1)
    # ... and the read now serves the value acked before the failover
    v, rev = s.get("ha")
    assert v == 77
    assert rev >= floor


def test_put_without_leader_times_out():
    s = fresh_service(seed=13, elect=False, timeout_ticks=5)
    with pytest.raises(Timeout):
        s.put("k", 1)


# ------------------------------------------------------------------ #
# key-hash stability
# ------------------------------------------------------------------ #
def test_key_hash_stable_across_services_and_runs(svc):
    """The string->key-id map is a pure function of (key, key_space):
    identical across service instances, sessions, and platforms (sha1,
    not python hash()), so revisions and shard routing are replayable."""
    other = BWKVService(BWRaftSim(CC, write_rate=0.0, read_rate=0.0,
                                  seed=99, manage_resources=False))
    for key in ("hello", "key2", "a" * 100, "", "ünicode"):
        kid = svc._key_id(key)
        assert kid == other._key_id(key)
        assert 0 <= kid < CC.key_space
        want = int(hashlib.sha1(key.encode()).hexdigest(), 16) % CC.key_space
        assert kid == want


def test_key_hash_pinned_values(svc):
    """Two pinned probes guard the exact hash formula — a silent change
    would silently remap every stored key."""
    assert CC.key_space == 1024
    assert svc._key_id("hello") == int(hashlib.sha1(b"hello")
                                       .hexdigest(), 16) % 1024
    assert svc._key_id("bwraft") == int(hashlib.sha1(b"bwraft")
                                        .hexdigest(), 16) % 1024
