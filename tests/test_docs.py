"""Docstring section-reference audit: every `DESIGN.md §N` citation in
the source tree must resolve to a real section header in DESIGN.md —
docstrings are the map of this codebase, and a dangling §-reference is a
broken link (ISSUE 3 satellite; the §8 insertion is exactly the kind of
edit that can strand one)."""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCANNED = ("src", "benchmarks", "examples", "tests")
REF_RE = re.compile(r"DESIGN\.md §(\d+(?:\.\d+)?)")
HEADER_RE = re.compile(r"^#{2,3} §(\d+(?:\.\d+)?)\b", re.MULTILINE)


def _design_sections():
    return set(HEADER_RE.findall((REPO / "DESIGN.md").read_text()))


def _references():
    refs = {}
    for top in SCANNED:
        for path in sorted((REPO / top).rglob("*.py")):
            for m in REF_RE.finditer(path.read_text()):
                refs.setdefault(m.group(1), []).append(
                    str(path.relative_to(REPO)))
    return refs


def test_design_section_references_resolve():
    sections = _design_sections()
    refs = _references()
    assert refs, "no DESIGN.md §N references found — regex or tree moved?"
    dangling = {sec: files for sec, files in refs.items()
                if sec not in sections}
    assert not dangling, \
        f"dangling DESIGN.md references (existing: {sorted(sections)}): " \
        f"{dangling}"


def test_kernel_layer_is_cross_referenced():
    """The §8 kernel-layer contract must be cited from both sides of the
    boundary it documents: the tick/fleet code that dispatches on
    `backend` and every kernel family that implements it (the PR-9
    widening makes this four packages, not one)."""
    refs = _references()
    cited_from = set(refs.get("8", []))
    assert any("core/step.py" in f for f in cited_from), cited_from
    assert any("core/fleet.py" in f for f in cited_from), cited_from
    for family in ("kernels/raft_tick", "kernels/leader_fanout",
                   "kernels/group_digest", "kernels/ae_sync"):
        assert any(family in f for f in cited_from), (family, cited_from)


def test_market_contract_is_cross_referenced():
    """Same rule for the §10 market-provider contract: cited from the
    tick that replays traces (`spot_step`) and from the market package
    that produces them."""
    refs = _references()
    cited_from = set(refs.get("10", []))
    assert any("core/step.py" in f for f in cited_from), cited_from
    assert any("repro/market/" in f for f in cited_from), cited_from


def test_fault_contract_is_cross_referenced():
    """Same rule for the §12 revocation-hardening contract: cited from
    the tick that runs the warning timer (`spot_step`) and from the
    market package that builds schedules and bid policies."""
    refs = _references()
    cited_from = set(refs.get("12", []))
    assert any("core/step.py" in f for f in cited_from), cited_from
    assert any("repro/market/" in f for f in cited_from), cited_from


def test_observer_tier_contract_is_cross_referenced():
    """Same rule for the §13 digest-tier observer contract: cited from
    the tick that runs the anti-entropy rounds and bounded-staleness
    serving (`core/step.py`), from the state module that owns the
    digest shapes (`core/state.py`), and from the service whose
    `get_stale` is the host-facing twin (`kvstore/service.py`)."""
    refs = _references()
    cited_from = set(refs.get("13", []))
    assert any("core/step.py" in f for f in cited_from), cited_from
    assert any("core/state.py" in f for f in cited_from), cited_from
    assert any("kvstore/service.py" in f for f in cited_from), cited_from


def test_flight_recorder_contract_is_cross_referenced():
    """Same rule for the §14 flight-recorder contract: cited from every
    instrumented seam (the tick steps that emit, the state module that
    owns the ring leaves, the runtime/fleet that drain, the chaos
    harness that pins the leader timeline) and from every module of the
    trace package itself."""
    refs = _references()
    cited_from = set(refs.get("14", []))
    for seam in ("core/step.py", "core/state.py", "core/runtime.py",
                 "core/fleet.py", "core/multiraft.py", "market/chaos.py",
                 "kvstore/service.py", "trace/ring.py", "trace/metrics.py",
                 "trace/export.py", "trace/timeline.py"):
        assert any(seam in f for f in cited_from), (seam, sorted(cited_from))


def test_serving_contract_is_cross_referenced():
    """Same rule for the §11 serving surface: cited from the tick that
    consumes arrival curves and serves the read-index round
    (`workload_step`/`read_step`), from the workload package that
    produces the plans, and from the service whose `get` runs the
    explicit round."""
    refs = _references()
    cited_from = set(refs.get("11", []))
    assert any("core/step.py" in f for f in cited_from), cited_from
    assert any("repro/workload/" in f for f in cited_from), cited_from
    assert any("kvstore/service.py" in f for f in cited_from), cited_from
