"""The batched fleet contract (DESIGN.md §7): a vmapped B-cluster sweep is
element-wise identical to sequential single-cluster runs at the same
padded shapes and seeds, padding is inert, and one static shape costs one
compile."""
import numpy as np
import pytest

from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim
from repro.core.state import DEAD

_INT_FIELDS = ("reads_arrived", "writes_arrived", "reads_served",
               "writes_committed", "n_secretaries", "n_observers",
               "leader_changes", "no_leader_ticks", "killed")
_FLOAT_FIELDS = ("read_lat_mean", "read_lat_max", "write_lat_mean",
                 "write_lat_p95", "write_lat_p99", "cost")


def _small_cluster(name="small", followers=(2, 2, 1), max_log=1024):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=256, max_secretaries=4,
                         max_observers=8, period_ticks=60)


def _assert_reports_equal(a, b, ctx=""):
    for f in _INT_FIELDS:
        assert getattr(a, f) == getattr(b, f), \
            f"{ctx}: {f}: fleet={getattr(a, f)} solo={getattr(b, f)}"
    for f in _FLOAT_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        if np.isnan(x) and np.isnan(y):
            continue
        assert np.isclose(x, y, rtol=1e-4, equal_nan=True), \
            f"{ctx}: {f}: fleet={x} solo={y}"


def test_batched_equals_sequential():
    """B=3 vmapped sweep == three sequential BWRaftSim runs, same seeds."""
    cfg = _small_cluster()
    knobs = [dict(write_rate=6.0, read_rate=24.0, phi=0.0, seed=0),
             dict(write_rate=12.0, read_rate=12.0, phi=0.05, seed=1),
             dict(write_rate=3.0, read_rate=48.0, phi=0.02, seed=2)]
    fleet = FleetSim([MemberSpec(cfg=cfg, **k) for k in knobs])
    fleet_reports = fleet.run(3)
    for i, k in enumerate(knobs):
        solo_reports = BWRaftSim(cfg, **k).run(3)
        for e, (a, b) in enumerate(zip(fleet_reports[i], solo_reports)):
            _assert_reports_equal(a, b, ctx=f"member {i} epoch {e}")
            # control plane decided identically too
            if a.decision is not None or b.decision is not None:
                assert (a.decision.dk_s, a.decision.dk_o) == \
                    (b.decision.dk_s, b.decision.dk_o)


def test_heterogeneous_fleet_matches_padded_solo():
    """A small cluster batched next to a bigger one (so it gets padded on
    every axis) reproduces a solo run at the same padded shapes."""
    small = _small_cluster("padded-small", followers=(2, 1), max_log=512)
    big = _small_cluster("big", followers=(3, 3, 2, 2), max_log=1024)
    fleet = FleetSim([
        MemberSpec(cfg=small, write_rate=6.0, read_rate=24.0, seed=4),
        MemberSpec(cfg=big, write_rate=12.0, read_rate=24.0, seed=5,
                   mode="raft"),
    ])
    pads = fleet.pads_for(0)
    assert pads["pad_nodes"] > 0 and pads["pad_sites"] > 0 \
        and pads["pad_log"] > 0
    fleet_reports = fleet.run(2)
    solo = BWRaftSim(small, write_rate=6.0, read_rate=24.0, seed=4,
                     **pads).run(2)
    for e, (a, b) in enumerate(zip(fleet_reports[0], solo)):
        _assert_reports_equal(a, b, ctx=f"epoch {e}")


def test_padding_and_masking_inert():
    """Padded slots never wake up, padded sites never host instances, and
    the padded cluster still does its job."""
    small = _small_cluster("inert-small", followers=(2, 1), max_log=512)
    big = _small_cluster("inert-big", followers=(3, 3, 2, 2))
    fleet = FleetSim([
        MemberSpec(cfg=small, write_rate=6.0, read_rate=24.0, seed=7),
        MemberSpec(cfg=big, write_rate=6.0, read_rate=24.0, seed=8),
    ])
    reports = fleet.run(2)
    st = {k: np.asarray(v) for k, v in fleet.state.items()}
    n_real = small.max_nodes
    assert (st["role"][0, n_real:] == DEAD).all(), \
        "padded slots must stay DEAD"
    assert not st["alive"][0, n_real:].any(), \
        "padded slots must never come alive"
    site = fleet.members[0].static["site"]
    assert (site < small.num_sites).all(), \
        "no node may map to a padded site"
    last = reports[0][-1]
    assert last.no_leader_ticks == 0 and last.writes_committed > 0, \
        "padded cluster must still reach steady state"

    # padding shifts the RNG sample path but not the regime: an unpadded
    # solo run of the same cluster lands in the same goodput band
    unpadded = BWRaftSim(small, write_rate=6.0, read_rate=24.0,
                         seed=7).run(2)[-1]
    assert unpadded.writes_committed > 0
    ratio = last.goodput / max(unpadded.goodput, 1)
    assert 0.5 < ratio < 2.0, (last.goodput, unpadded.goodput)


def test_one_compile_per_static_shape():
    """Different sweep grids at one static shape share one compilation."""
    cfg = _small_cluster("compile", followers=(1, 1), max_log=256)
    a = FleetSim.from_sweep(cfg, {"phi": [0.0, 0.1]}, write_rate=4.0,
                            read_rate=8.0, seed=0)
    a.run(2)
    assert a.compile_count == 1
    b = FleetSim.from_sweep(cfg, {"write_rate": [2.0, 16.0]},
                            read_rate=8.0, seed=3)
    b.run(1)
    # same shapes -> same cached program; new knobs are just jit arguments
    assert b._epoch_fn is a._epoch_fn
    assert b.compile_count == 1


def test_sweep_cross_product_order():
    cfg = _small_cluster("order", followers=(1, 1), max_log=256)
    fleet = FleetSim.from_sweep(cfg, {"phi": [0.0, 0.1],
                                      "write_rate": [2.0, 4.0]},
                                read_rate=8.0)
    assert fleet.shapes.B == 4
    got = [(m.spec.phi, m.spec.write_rate) for m in fleet.members]
    assert got == [(0.0, 2.0), (0.0, 4.0), (0.1, 2.0), (0.1, 4.0)]
    with pytest.raises(AssertionError):
        FleetSim.from_sweep(cfg, {"not_a_knob": [1]})
