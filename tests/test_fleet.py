"""The batched fleet contract (DESIGN.md §7): a vmapped B-cluster sweep is
element-wise identical to sequential single-cluster runs at the same
padded shapes and seeds, padding is inert, and one static shape costs one
compile.  Plus the §7.1 epoch-digest contract: the device-resident
(fused/donated) pipeline reproduces the PR-1 host-marshalling reports,
the multi-epoch scan equals the epoch-by-epoch loop, and per-epoch
device→host traffic stays O(digest)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim, CountingJit, hist_percentile
from repro.core.state import DEAD

_INT_FIELDS = ("reads_arrived", "writes_arrived", "reads_served",
               "writes_committed", "n_secretaries", "n_observers",
               "leader_changes", "no_leader_ticks", "killed")
_FLOAT_FIELDS = ("read_lat_mean", "read_lat_max", "write_lat_mean",
                 "write_lat_p95", "write_lat_p99", "cost")


def _small_cluster(name="small", followers=(2, 2, 1), max_log=1024):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=256, max_secretaries=4,
                         max_observers=8, period_ticks=60)


def _assert_reports_equal(a, b, ctx=""):
    for f in _INT_FIELDS:
        assert getattr(a, f) == getattr(b, f), \
            f"{ctx}: {f}: fleet={getattr(a, f)} solo={getattr(b, f)}"
    for f in _FLOAT_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        if np.isnan(x) and np.isnan(y):
            continue
        assert np.isclose(x, y, rtol=1e-4, equal_nan=True), \
            f"{ctx}: {f}: fleet={x} solo={y}"


def test_batched_equals_sequential():
    """B=3 vmapped sweep == three sequential BWRaftSim runs, same seeds."""
    cfg = _small_cluster()
    knobs = [dict(write_rate=6.0, read_rate=24.0, phi=0.0, seed=0),
             dict(write_rate=12.0, read_rate=12.0, phi=0.05, seed=1),
             dict(write_rate=3.0, read_rate=48.0, phi=0.02, seed=2)]
    fleet = FleetSim([MemberSpec(cfg=cfg, **k) for k in knobs])
    fleet_reports = fleet.run(3)
    for i, k in enumerate(knobs):
        solo_reports = BWRaftSim(cfg, **k).run(3)
        for e, (a, b) in enumerate(zip(fleet_reports[i], solo_reports)):
            _assert_reports_equal(a, b, ctx=f"member {i} epoch {e}")
            # control plane decided identically too
            if a.decision is not None or b.decision is not None:
                assert (a.decision.dk_s, a.decision.dk_o) == \
                    (b.decision.dk_s, b.decision.dk_o)


def test_heterogeneous_fleet_matches_padded_solo():
    """A small cluster batched next to a bigger one (so it gets padded on
    every axis) reproduces a solo run at the same padded shapes."""
    small = _small_cluster("padded-small", followers=(2, 1), max_log=512)
    big = _small_cluster("big", followers=(3, 3, 2, 2), max_log=1024)
    fleet = FleetSim([
        MemberSpec(cfg=small, write_rate=6.0, read_rate=24.0, seed=4),
        MemberSpec(cfg=big, write_rate=12.0, read_rate=24.0, seed=5,
                   mode="raft"),
    ])
    pads = fleet.pads_for(0)
    assert pads["pad_nodes"] > 0 and pads["pad_sites"] > 0 \
        and pads["pad_log"] > 0
    fleet_reports = fleet.run(2)
    solo = BWRaftSim(small, write_rate=6.0, read_rate=24.0, seed=4,
                     **pads).run(2)
    for e, (a, b) in enumerate(zip(fleet_reports[0], solo)):
        _assert_reports_equal(a, b, ctx=f"epoch {e}")


def test_padding_and_masking_inert():
    """Padded slots never wake up, padded sites never host instances, and
    the padded cluster still does its job."""
    small = _small_cluster("inert-small", followers=(2, 1), max_log=512)
    big = _small_cluster("inert-big", followers=(3, 3, 2, 2))
    fleet = FleetSim([
        MemberSpec(cfg=small, write_rate=6.0, read_rate=24.0, seed=7),
        MemberSpec(cfg=big, write_rate=6.0, read_rate=24.0, seed=8),
    ])
    reports = fleet.run(2)
    st = {k: np.asarray(v) for k, v in fleet.state.items()}
    n_real = small.max_nodes
    assert (st["role"][0, n_real:] == DEAD).all(), \
        "padded slots must stay DEAD"
    assert not st["alive"][0, n_real:].any(), \
        "padded slots must never come alive"
    site = fleet.members[0].static["site"]
    assert (site < small.num_sites).all(), \
        "no node may map to a padded site"
    last = reports[0][-1]
    assert last.no_leader_ticks == 0 and last.writes_committed > 0, \
        "padded cluster must still reach steady state"

    # padding shifts the RNG sample path but not the regime: an unpadded
    # solo run of the same cluster lands in the same goodput band
    unpadded = BWRaftSim(small, write_rate=6.0, read_rate=24.0,
                         seed=7).run(2)[-1]
    assert unpadded.writes_committed > 0
    ratio = last.goodput / max(unpadded.goodput, 1)
    assert 0.5 < ratio < 2.0, (last.goodput, unpadded.goodput)


def test_one_compile_per_static_shape():
    """Different sweep grids at one static shape share one compilation."""
    cfg = _small_cluster("compile", followers=(1, 1), max_log=256)
    a = FleetSim.from_sweep(cfg, {"phi": [0.0, 0.1]}, write_rate=4.0,
                            read_rate=8.0, seed=0)
    a.run(2)
    assert a.compile_count == 1
    b = FleetSim.from_sweep(cfg, {"write_rate": [2.0, 16.0]},
                            read_rate=8.0, seed=3)
    b.run(1)
    # same shapes -> same cached program; new knobs are just jit arguments
    assert b._epoch_fn is a._epoch_fn
    assert b.compile_count == 1


def test_digest_pipeline_matches_host_pipeline():
    """§7.1 equivalence: the fused/donated digest epoch reproduces the
    PR-1 host-marshalling EpochReports — exact counters, histogram-exact
    latency stats — including the control-plane decisions of a managing
    member."""
    cfg = _small_cluster("digest")
    specs = [MemberSpec(cfg=cfg, write_rate=6.0, read_rate=24.0, phi=0.02,
                        seed=0),
             MemberSpec(cfg=cfg, mode="raft", write_rate=12.0,
                        read_rate=12.0, seed=1, manage_resources=False)]
    dev = FleetSim(specs)                       # pipeline="device" default
    host = FleetSim(specs, pipeline="host")
    dev_reports, host_reports = dev.run(3), host.run(3)
    for i in range(len(specs)):
        for e, (a, b) in enumerate(zip(dev_reports[i], host_reports[i])):
            _assert_reports_equal(a, b, ctx=f"member {i} epoch {e}")
            if a.decision is not None or b.decision is not None:
                assert (a.decision.dk_s, a.decision.dk_o) == \
                    (b.decision.dk_s, b.decision.dk_o)
    # the point of the digest: per-epoch D2H is O(digest), not O(B*N*(L+K))
    assert dev.d2h_bytes < host.d2h_bytes / 100, \
        (dev.d2h_bytes, host.d2h_bytes)


def test_multi_epoch_scan_equals_epoch_by_epoch():
    """§7.1 fast path: a fixed-role fleet run as ONE scan-of-scans
    dispatch equals the same fleet stepped epoch by epoch at the same
    seeds/shapes."""
    cfg = _small_cluster("scan")
    specs = [MemberSpec(cfg=cfg, write_rate=6.0, read_rate=24.0, phi=0.02,
                        seed=3, manage_resources=False, prelease=(2, 4)),
             MemberSpec(cfg=cfg, mode="raft", write_rate=8.0,
                        read_rate=16.0, seed=4, manage_resources=False)]
    fast = FleetSim(specs)
    slow = FleetSim(specs)
    assert fast.single_dispatch_eligible
    fast_reports = fast.run(4)                  # auto single dispatch
    slow_reports = slow.run(4, single_dispatch=False)
    for i in range(len(specs)):
        for e, (a, b) in enumerate(zip(fast_reports[i], slow_reports[i])):
            _assert_reports_equal(a, b, ctx=f"member {i} epoch {e}")

    # a managing fleet must refuse the forced fast path
    with pytest.raises(AssertionError):
        FleetSim([MemberSpec(cfg=cfg, seed=0)]).run(2, single_dispatch=True)


def test_preleased_fleet_matches_solo():
    """Fixed-role members (prelease) stay trajectory-equal to a solo
    BWRaftSim wired the same way at the same seed."""
    cfg = _small_cluster("pre")
    spec = dict(write_rate=6.0, read_rate=24.0, phi=0.0, seed=5,
                manage_resources=False, prelease=(2, 4))
    fleet_reports = FleetSim([MemberSpec(cfg=cfg, **spec)]).run(3)
    solo_reports = BWRaftSim(cfg, **spec).run(3)
    for e, (a, b) in enumerate(zip(fleet_reports[0], solo_reports)):
        _assert_reports_equal(a, b, ctx=f"epoch {e}")
    # observers survive a fixed-role run; preleased secretaries are
    # stopped by the FIRST election (paper Step 1) and — manager off —
    # never re-provisioned, so only the observer complement persists
    assert fleet_reports[0][-1].n_observers > 0


def test_lease_fixed_matches_solo_recipe():
    """The fixed-role sweep recipe (stabilize -> lease_fixed -> single
    dispatch, as in fig12/fig13) equals the sequential run/_lease/run."""
    cfg = _small_cluster("fixed")
    spec = dict(write_rate=6.0, read_rate=24.0, phi=0.02, seed=9,
                manage_resources=False)
    fleet = FleetSim([MemberSpec(cfg=cfg, **spec)])
    fleet.run(1)
    fleet.lease_fixed(2, 4)
    fleet_reports = fleet.run(3)                # ONE dispatch
    solo = BWRaftSim(cfg, **spec)
    solo.run(1)
    solo.lease_fixed(2, 4)
    solo_reports = solo.run(3)
    for e, (a, b) in enumerate(zip(fleet_reports[0], solo_reports)):
        _assert_reports_equal(a, b, ctx=f"epoch {e}")
    assert fleet_reports[0][0].n_secretaries + \
        fleet_reports[0][0].n_observers > 0


def test_hist_percentile_matches_numpy():
    """The digest recovers np.percentile exactly: integer latencies in
    unit bins fully determine the sorted sample."""
    rng = np.random.default_rng(0)
    for size in (1, 2, 7, 100):
        sample = rng.integers(0, 60, size)
        hist = np.bincount(sample, minlength=61)
        for q in (50, 95, 99):
            assert np.isclose(hist_percentile(hist, q),
                              np.percentile(sample, q)), (size, q)
    assert np.isnan(hist_percentile(np.zeros(5, int), 95))


def test_apply_step_last_wins_scatter():
    """The vectorized apply scatter preserves log order: for duplicate
    keys inside one apply window the LAST committed entry wins."""
    N, L, K, A = 2, 8, 4, 4
    state = {
        "log_term": jnp.zeros((N, L), jnp.int32),
        "log_key": jnp.asarray([[1, 1, 2, 1, 0, 0, 0, 0],
                                [3, 3, 3, 3, 0, 0, 0, 0]], jnp.int32),
        "log_val": jnp.asarray([[10, 20, 30, 40, 0, 0, 0, 0],
                                [5, 6, 7, 8, 0, 0, 0, 0]], jnp.int32),
        "applied_len": jnp.zeros((N,), jnp.int32),
        "commit_len": jnp.asarray([4, 3], jnp.int32),
        "alive": jnp.asarray([True, True]),
        "kv": jnp.full((N, K), -1, jnp.int32),
    }
    out = step_mod.apply_step(state, {"max_apply": A}, {})
    kv = np.asarray(out["kv"])
    # row 0 commits keys [1,1,2,1]: key1 -> 40 (last), key2 -> 30
    assert kv[0, 1] == 40 and kv[0, 2] == 30 and kv[0, 0] == -1
    # row 1 commits only 3 of the 4 entries for key3 -> third value wins
    assert kv[1, 3] == 7
    assert np.asarray(out["applied_len"]).tolist() == [4, 3]


def test_compile_count_fallback_without_cache_size():
    """CountingJit keeps counting compilations when the installed jax has
    no private `_cache_size` on jitted functions."""
    fn = CountingJit(lambda x: x * 2)
    fn(jnp.zeros((4,)))
    fn(jnp.ones((4,)))                  # same shape: no new compile
    fn(jnp.zeros((8,)))                 # new shape: second compile
    assert fn.cache_size() == 2
    fn.fn = lambda *a: None             # a jax without _cache_size()
    assert fn.cache_size() == 2, "must fall back to signature counting"


def test_sweep_cross_product_order():
    cfg = _small_cluster("order", followers=(1, 1), max_log=256)
    fleet = FleetSim.from_sweep(cfg, {"phi": [0.0, 0.1],
                                      "write_rate": [2.0, 4.0]},
                                read_rate=8.0)
    assert fleet.shapes.B == 4
    got = [(m.spec.phi, m.spec.write_rate) for m in fleet.members]
    assert got == [(0.0, 2.0), (0.0, 4.0), (0.1, 2.0), (0.1, 4.0)]
    with pytest.raises(AssertionError):
        FleetSim.from_sweep(cfg, {"not_a_knob": [1]})
