"""Coordinator, elastic pool, straggler mitigation."""
import numpy as np
import pytest

from repro.configs.bwraft_kv import CONFIG as CC
from repro.coord.coordinator import ConsensusCoordinator
from repro.coord.elastic import ElasticObserverPool
from repro.coord.stragglers import StragglerMitigator


def test_leader_failover_preserves_committed_record():
    coord = ConsensusCoordinator(CC, seed=4)
    lid = coord.wait_for_leader()
    coord.commit_checkpoint(10, "abc123def4567890")
    before = coord.last_committed_checkpoint()
    coord.kill_pod(lid)
    new_lid = coord.wait_for_leader()
    assert new_lid != lid
    coord.kv._step(100)   # let the new leader re-establish + apply
    after = coord.last_committed_checkpoint()
    assert after == before, "committed checkpoint must survive failover"


def test_membership_record():
    coord = ConsensusCoordinator(CC, seed=5)
    coord.wait_for_leader()
    coord.commit_membership(0b1011)
    coord.kv._step(80)
    assert coord.membership() == 0b1011


def test_elastic_pool_routing_and_revocation():
    pool = ElasticObserverPool(CC, capacity_per_replica=8, seed=0)
    pool.set_committed(5)
    pool.add_replicas(4)
    routed = pool.route(32)
    assert sum(routed.values()) == 32
    served = pool.serve_tick()
    assert served == 32
    killed = pool.revoke_random(1.0)       # revoke everything
    assert killed == 4
    routed = pool.route(16)
    assert routed == {} and pool.rerouted >= 16, \
        "requests reroute when all observers are revoked (Property 3.4)"


def test_elastic_autoscale_uses_algorithm1():
    pool = ElasticObserverPool(CC, seed=1)
    pool.set_committed(0)
    pool.reads_prev = 100
    dec = pool.autoscale(reads_now=1000, writes_now=10, budget=2.0,
                         spot_price=0.0125, on_demand_price=0.0416)
    assert dec.dk_o > 0 and len(pool.alive) == dec.dk_o


def test_straggler_detection_and_resharding():
    sm = StragglerMitigator(4, threshold=1.5, patience=2)
    for _ in range(5):
        sm.heartbeat({0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0})
    assert 3 not in sm.active_pods
    assert sm.shard_assignment() == {0: 0, 1: 1, 2: 2}
    assert sm.membership_bitmap() == 0b0111


def test_data_resharding_exact():
    """Elastic DP: shards of the same step reassemble the global batch."""
    from repro.data.pipeline import DataConfig, TokenPipeline
    pipe = TokenPipeline(DataConfig(vocab_size=128, seq_len=16,
                                    global_batch=8))
    whole = pipe.batch_at(5)
    parts = [pipe.batch_at(5, shard=i, num_shards=4) for i in range(4)]
    got = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(got, np.asarray(whole["tokens"]))
