"""Revocation-robustness semantics (DESIGN.md §12): the W=0/static-bid
golden gate against the frozen reference step, the advance-warning
timer contract (sustained signal kills after exactly W ticks; an early
drop is a reprieve), per-node trace columns killing nodes not sites,
chaos drills replayed through the paper's safety properties, and bids
as recompile-free cfg_c data."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fleet as fleet_mod
from repro.core import invariants
from repro.core import state as state_mod
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim, make_cfg_arrays
from repro.market import (FaultSchedule, HazardAwareBid, MarketTrace,
                          export_walk_trace, kill_nodes, load, mass_kill,
                          run_chaos, sliding_window_rates,
                          warning_then_reprieve)


def _small_cluster(name="flt", followers=(2, 2, 1), max_log=1024):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=256, max_secretaries=4,
                         max_observers=8, period_ticks=60)


def _reports_equal(a, b):
    keys = ("reads_arrived", "writes_arrived", "reads_served",
            "writes_committed", "killed", "n_secretaries", "n_observers",
            "leader_changes", "no_leader_ticks", "n_warned")
    return all(getattr(a, k) == getattr(b, k) for k in keys) \
        and a.cost == b.cost


# --------------------------------------------------------------------- #
# the §12 golden gate: W=0 + static bid == the frozen reference step
# --------------------------------------------------------------------- #
def _drive(stepfn, cfg, cfg_c, *, ticks=80, seed=0):
    static = state_mod.build_static(cfg)
    state = state_mod.init_state(cfg, static)
    rng = jax.random.PRNGKey(seed)
    out = []
    for t in range(ticks):
        rng, sub = jax.random.split(rng)
        state = dict(state, tick=jnp.int32(t))
        state, killed = stepfn(state, static, cfg_c, sub)
        out.append((np.asarray(state["spot_price"]).copy(),
                    np.asarray(state["alive"]).copy(),
                    np.asarray(state["role"]).copy(),
                    np.asarray(killed).copy()))
    return out


@pytest.mark.parametrize("market", ["process", "trace"])
def test_w0_static_bid_bit_identical_to_reference(market):
    """At warn_ticks=0 with no chaos schedule and the init-time bid,
    `spot_step` is bit-identical to the frozen pre-§12
    `spot_step_reference` — prices, kills, roles, every tick, on both
    market paths (the W=0 golden gate, DESIGN.md §12)."""
    cfg = _small_cluster()
    kw = {}
    if market == "trace":
        kw = dict(market="trace",
                  trace=export_walk_trace(cfg, seed=4, epochs=2))
    cfg_c = make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0,
                            phi=0.05, **kw)
    ref = _drive(step_mod.spot_step_reference, cfg, cfg_c, seed=9)
    new = _drive(step_mod.spot_step, cfg, cfg_c, seed=9)
    for t, (r, n) in enumerate(zip(ref, new)):
        for name, a, b in zip(("price", "alive", "role", "killed"), r, n):
            assert np.array_equal(a, b), f"tick {t}: {name} diverged"


def test_warn_timer_stays_inert_at_w0():
    """With W=0 the timer leaf never arms: every tick ends at -1
    everywhere, so recording it in goldens is shape-only."""
    cfg = _small_cluster()
    cfg_c = make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0, phi=0.1)
    static = state_mod.build_static(cfg)
    state = state_mod.init_state(cfg, static)
    rng = jax.random.PRNGKey(2)
    for t in range(40):
        rng, sub = jax.random.split(rng)
        state = dict(state, tick=jnp.int32(t))
        state, _ = step_mod.spot_step(state, static, cfg_c, sub)
        assert (np.asarray(state["warn_timer"]) == -1).all(), t


# --------------------------------------------------------------------- #
# the warning contract, tick by tick
# --------------------------------------------------------------------- #
def _fault_cfg(cfg, faults, *, warning_ticks, ticks, phi=0.0):
    return make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0, phi=phi,
                           warning_ticks=warning_ticks, spot_bid=10.0,
                           faults=faults, fault_ticks=ticks)


def test_sustained_signal_kills_after_exactly_w_ticks():
    """A signal that rises at tick `a` and holds kills the node at tick
    ``a + W`` — not before, not after — with the timer counting
    W, W-1, ..., 0 in between (DESIGN.md §12)."""
    cfg = _small_cluster()
    W, at, node = 3, 5, 2
    faults = kill_nodes([node], at, n_nodes=cfg.max_nodes, ticks=40,
                        warning_ticks=W)
    cfg_c = _fault_cfg(cfg, faults, warning_ticks=W, ticks=40)
    static = state_mod.build_static(cfg)
    state = state_mod.init_state(cfg, static)
    alive0 = np.asarray(state["alive"]).copy()
    rng = jax.random.PRNGKey(0)
    for t in range(40):
        rng, sub = jax.random.split(rng)
        state = dict(state, tick=jnp.int32(t))
        state, killed = step_mod.spot_step(state, static, cfg_c, sub)
        timer = int(np.asarray(state["warn_timer"])[node])
        dead = bool(np.asarray(killed)[node])
        if t < at:
            assert timer == -1 and not dead, t
        elif t < at + W:
            assert timer == W - (t - at) and not dead, (t, timer)
        elif t == at + W:
            assert dead and timer == -1, (t, timer)
        else:
            assert not dead and not np.asarray(state["alive"])[node], t
    others = np.arange(cfg.max_nodes) != node
    assert np.array_equal(np.asarray(state["alive"])[others],
                          alive0[others]), "only the drilled node dies"


def test_warning_then_reprieve_resumes_node():
    """A signal that drops before the window elapses is a reprieve: the
    timer resets to -1, nothing dies, and the node is a full citizen
    again (DESIGN.md §12)."""
    cfg = _small_cluster()
    W, at, node = 5, 4, 1
    faults = warning_then_reprieve([node], at, n_nodes=cfg.max_nodes,
                                   ticks=30, warning_ticks=W)   # hold = W
    cfg_c = _fault_cfg(cfg, faults, warning_ticks=W, ticks=30)
    static = state_mod.build_static(cfg)
    state = state_mod.init_state(cfg, static)
    rng = jax.random.PRNGKey(1)
    timers = []
    for t in range(30):
        rng, sub = jax.random.split(rng)
        state = dict(state, tick=jnp.int32(t))
        state, killed = step_mod.spot_step(state, static, cfg_c, sub)
        assert not np.asarray(killed).any(), t
        timers.append(int(np.asarray(state["warn_timer"])[node]))
    # armed at `at` with W, counts down while the signal holds (W ticks),
    # resets to -1 the tick it drops — one tick short of landing
    assert timers[at:at + W] == [W, W - 1, W - 2, W - 3, W - 4]
    assert timers[at + W] == -1 and np.asarray(state["alive"])[node]


def test_fault_schedule_hits_voters_market_does_not():
    """Chaos columns kill ANY node — voters included (that's the
    leader-kill drill) — while market revocations only ever touch spot
    nodes."""
    cfg = _small_cluster()
    voter = 0
    assert bool(state_mod.build_static(cfg)["is_voter"][voter])
    faults = kill_nodes([voter], 2, n_nodes=cfg.max_nodes, ticks=10)
    cfg_c = _fault_cfg(cfg, faults, warning_ticks=0, ticks=10)
    static = state_mod.build_static(cfg)
    state = state_mod.init_state(cfg, static)
    rng = jax.random.PRNGKey(3)
    for t in range(4):
        rng, sub = jax.random.split(rng)
        state = dict(state, tick=jnp.int32(t))
        state, killed = step_mod.spot_step(state, static, cfg_c, sub)
    assert not np.asarray(state["alive"])[voter], "drill must kill voter"
    # market path (no faults): price far above every bid kills all spot
    # nodes but never a voter (everyone forced alive first — init only
    # wakes voters)
    cfg_c = make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0,
                            spot_bid=1e-6)
    state = state_mod.init_state(cfg, static)
    state = dict(state, alive=jnp.ones(cfg.max_nodes, bool))
    state, killed = step_mod.spot_step(dict(state, tick=jnp.int32(0)),
                                       static, cfg_c,
                                       jax.random.PRNGKey(4))
    is_voter = np.asarray(static["is_voter"])
    assert np.asarray(killed)[~is_voter].all()
    assert not np.asarray(killed)[is_voter].any()


def test_per_node_trace_kills_single_node_not_site():
    """A trace carrying `revoked_node` columns kills exactly the mapped
    node; the site-level broadcast (which would take every spot node at
    the site) is replaced, not added to (DESIGN.md §12)."""
    cfg = _small_cluster()
    static = state_mod.build_static(cfg)
    N = cfg.max_nodes
    spot = np.where(~np.asarray(static["is_voter"]))[0]
    target = int(spot[0])
    T = 8
    node_cols = np.zeros((N, T), bool)
    node_cols[target, 0] = True
    # site columns scream "revoke everything" — they must be ignored
    trace = MarketTrace("unit", np.full((cfg.num_sites, T), 0.0125,
                                        np.float32),
                        np.ones((cfg.num_sites, T), bool), node_cols)
    cfg_c = make_cfg_arrays(cfg, write_rate=8.0, read_rate=16.0,
                            market="trace", trace=trace)
    state = state_mod.init_state(cfg, static)
    state = dict(state, alive=jnp.ones(N, bool))
    state, killed = step_mod.spot_step(dict(state, tick=jnp.int32(0)),
                                       static, cfg_c,
                                       jax.random.PRNGKey(0))
    killed = np.asarray(killed)
    assert killed[target] and killed.sum() == 1, np.where(killed)


def test_node_columns_fit_rules():
    """`MarketTrace.node_columns` tiles node rows round-robin (n % M)
    and wraps time (t % T) — the §10 rules at machine granularity —
    while `FaultSchedule.fit_to` pads False: drills are one-shot."""
    node = np.array([[1, 0, 1], [0, 1, 0]], bool)
    tr = MarketTrace("u", np.ones((1, 3), np.float32),
                     np.zeros((1, 3), bool), node)
    out = tr.node_columns(5, 7)
    assert out.shape == (5, 7)
    assert np.array_equal(out[2], out[0]) and np.array_equal(out[3], out[1])
    assert np.array_equal(out[0, 3:6], out[0, :3])
    fs = FaultSchedule("u", node)
    fit = fs.fit_to(5, 7)
    assert fit.shape == (5, 7) and fit.sum() == node.sum()
    assert not fit[2:].any() and not fit[:, 3:].any()
    assert np.array_equal(fs.fit_to(1, 2), node[:1, :2])


# --------------------------------------------------------------------- #
# chaos drills through the paper's safety properties
# --------------------------------------------------------------------- #
def test_leader_kill_recovery_and_safety():
    """Killing node 0 (a voter) mid-run forces an election; the cluster
    recovers a leader and every §3 safety property holds over the full
    per-tick trace (run_chaos raises otherwise)."""
    from repro.configs.bwraft_kv import CONFIG
    faults = kill_nodes([0], 20, n_nodes=CONFIG.max_nodes, ticks=120)
    rep = run_chaos(CONFIG, faults, ticks=120, seed=0, spot_bid=10.0)
    assert rep.first_kill_tick == 20 and rep.safety_error is None
    assert rep.recovery_ticks > 0, "the kill must cost leaderless ticks"
    assert rep.recovery_ticks < 120, "a leader must come back"


def test_mass_kill_election_safety_with_warning():
    """Correlated mass revocation (every node but a voter quorum, warned
    W=3) stays safe: one leader per term, logs match, committed entries
    never change."""
    from repro.configs.bwraft_kv import CONFIG
    faults = mass_kill(30, n_nodes=CONFIG.max_nodes, ticks=120,
                       spare=(0, 1, 2), warning_ticks=3)
    rep = run_chaos(CONFIG, faults, warning_ticks=3, ticks=120, seed=0,
                    spot_bid=10.0)
    assert rep.safety_error is None
    assert rep.first_kill_tick == 33, "kill lands W ticks after signal"
    assert rep.alive_end >= 3, "the spared quorum survives"


def test_phi_one_mass_kill_election_safety(sim_trace_factory):
    """phi=1 — every spot node dies every tick, unwarned — and election
    safety + log matching still hold (the §12 chaos harness replays the
    same invariants the hypothesis suite checks)."""
    trace, _ = sim_trace_factory(seed=5, ticks=180, every=1, phi=1.0)
    invariants.check_all(trace)


# --------------------------------------------------------------------- #
# warned degradation keeps the pipeline moving
# --------------------------------------------------------------------- #
def test_permanently_warned_cluster_still_commits():
    """A schedule that warns every spot node forever (signal up for the
    whole run, W longer than the run) kills nothing — and the §12
    degradation rules (leader reclaims fan-out, observers drain) keep
    writes committing and reads serving."""
    cfg = _small_cluster()
    static = state_mod.build_static(cfg)
    is_spot = ~np.asarray(static["is_voter"])
    T = 2 * cfg.period_ticks
    kill = np.zeros((cfg.max_nodes, T), bool)
    kill[is_spot, 10:] = True
    sim = BWRaftSim(cfg, write_rate=8.0, read_rate=16.0, seed=0,
                    warning_ticks=10 * T, spot_bid=10.0,
                    faults=FaultSchedule("warn-all", kill), fault_ticks=T)
    reports = sim.run(2)
    assert reports[-1].n_warned > 0, "census must see the warned nodes"
    assert reports[-1].killed == 0, "W > run length never lands a kill"
    assert sum(r.writes_committed for r in reports) > 0
    assert sum(r.reads_served for r in reports) > 0
    # the census is warned ⊆ spot ∧ alive (a node leased at the final
    # epoch boundary hasn't ticked yet, so it may be alive but unarmed)
    warned = np.asarray(sim.state["warn_timer"]) >= 0
    assert warned.any()
    assert (warned <= (is_spot & np.asarray(sim.state["alive"]))).all()


def test_fleet_member_with_faults_equals_solo():
    """The whole §12 surface — warning window, chaos schedule, bid
    override — lands identically through the fleet batch and the solo
    runtime: a fleet member's reports (n_warned included) equal the
    solo run bit for bit."""
    cfg = _small_cluster("feq", followers=(1, 1), max_log=256)
    T = 2 * cfg.period_ticks
    # the signal spans the epoch-1 boundary (ticks 57..61, W=4: kill
    # lands at 61) so the end-of-epoch census catches the warned node
    faults = kill_nodes([1], 57, n_nodes=cfg.max_nodes, ticks=T,
                        warning_ticks=4)
    spec = dict(write_rate=6.0, read_rate=12.0, seed=3,
                manage_resources=False, prelease=(1, 2),
                warning_ticks=4)
    fleet = FleetSim([
        MemberSpec(cfg=cfg, **spec, faults=faults),
        MemberSpec(cfg=cfg, write_rate=9.0, read_rate=12.0, seed=7,
                   manage_resources=False, prelease=(1, 2))])
    fleet_reports = fleet.run(2)
    solo = BWRaftSim(cfg, **spec, faults=faults, fault_ticks=T)
    for e, (a, b) in enumerate(zip(fleet_reports[0], solo.run(2))):
        assert _reports_equal(a, b), f"epoch {e}"
    assert any(r.n_warned for r in fleet_reports[0]), \
        "the drill must produce a nonzero warning census"


# --------------------------------------------------------------------- #
# bids are data: per-epoch policy updates, zero recompiles
# --------------------------------------------------------------------- #
def test_set_bid_shapes_and_effect():
    cfg = _small_cluster()
    sim = BWRaftSim(cfg, write_rate=8.0, read_rate=16.0, seed=0)
    S = cfg.num_sites
    sim.set_bid(0.5)
    assert np.asarray(sim.cfg_c["spot_bid"]).tolist() == [0.5] * S
    sim.set_bid([0.1, 0.2])                      # short: repeat-last pad
    assert np.asarray(sim.cfg_c["spot_bid"]).tolist() == \
        pytest.approx([0.1, 0.2] + [0.2] * (S - 2))
    sim.set_bid(np.arange(S + 3, dtype=np.float32))   # long: truncate
    assert np.asarray(sim.cfg_c["spot_bid"]).tolist() == \
        pytest.approx(list(range(S)))


def test_bid_policy_updates_never_recompile():
    """A managed fleet running `HazardAwareBid` per-epoch updates (bids
    re-derived against the replayed AWS trace via `bid_on_trace`)
    compiles exactly ONE tick program — bids are cfg_c data, not part
    of the program (the §12 satellite fix: the bid used to be frozen at
    `site_price_init` forever)."""
    cfg = _small_cluster("bids", followers=(1, 1), max_log=256)
    epochs = 3
    trace = load("aws-us-east", ticks=epochs * cfg.period_ticks,
                 ).fit_to(cfg.num_sites, epochs * cfg.period_ticks)
    mean = trace.price.mean(axis=1)

    def member(seed, policy):
        return MemberSpec(
            cfg=cfg, write_rate=6.0, read_rate=12.0, seed=seed,
            market="trace", trace=trace, bid_on_trace=True,
            bid_policy=policy)
    before = fleet_mod.total_compile_count()
    # disjoint mult ranges so the two policies MUST land on different
    # bids whatever the hazard (AWS hazard saturates hazard_ref)
    fleet = FleetSim([
        member(0, HazardAwareBid(mean_price=mean)),
        member(1, HazardAwareBid(mean_price=mean, low_mult=0.6,
                                 high_mult=0.9,
                                 window_ticks=cfg.period_ticks))])
    fleet.run(epochs)
    assert fleet_mod.total_compile_count() - before == 1, \
        "per-epoch bid updates must not recompile"
    bids = np.asarray(fleet._cfg_c["spot_bid"])
    assert not np.array_equal(bids[0], bids[1]), \
        "different policies must land different bids"


def test_sliding_window_rates_pinned():
    revoked = np.array([[1, 1, 0, 0, 1, 0]], bool)
    tr = MarketTrace("u", np.ones((1, 6), np.float32), revoked)
    assert sliding_window_rates(tr, 4, 2).tolist() == [0.0]   # cols 2,3
    assert sliding_window_rates(tr, 5, 4).tolist() == [0.5]   # cols 1..4
    # the window slides through the time wrap: end 1, width 3 -> 4,5,0
    assert sliding_window_rates(tr, 1, 3).tolist() == \
        pytest.approx([2 / 3])
    # degenerate windows degrade to the full-trace empirical rates
    assert sliding_window_rates(tr, 0, 2).tolist() == [0.5]
    assert sliding_window_rates(tr, 4, 6).tolist() == [0.5]


def test_hazard_aware_bid_interpolates():
    pol = HazardAwareBid(mean_price=[1.0], low_mult=1.1, high_mult=2.5,
                         hazard_ref=0.1)
    assert pol.bids([0.0]).tolist() == pytest.approx([2.5])   # calm: up
    assert pol.bids([0.1]).tolist() == pytest.approx([1.1])   # hot: shed
    assert pol.bids([0.5]).tolist() == pytest.approx([1.1])   # clamped
    assert pol.bids([0.05]).tolist() == pytest.approx([1.8])  # midpoint
