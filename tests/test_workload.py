"""The open-loop workload contract (DESIGN.md §11): arrival curves are
conserved against the host generator, inert at zero rate, Zipfian key
popularity matches `scipy.stats.zipfian`, and swapping plans at one
shape never recompiles (CountingJit-asserted).

Randomized sweeps run through hypothesis when it is installed
(requirements-dev.txt) and fall back to fixed-seed sweeps otherwise
(the `test_raft_tick_kernels.py` convention)."""
import numpy as np
import pytest
import scipy.stats

from repro.configs.bwraft_kv import CONFIG
from repro.core.runtime import BWRaftSim
from repro.workload import (ConstantRate, DiurnalRate, FlashCrowd, OpenLoop,
                            ZipfianKeys, host_poisson_totals,
                            materialize_curve, uniform_key_cdf)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

T = CONFIG.period_ticks


# --------------------------------------------------------------------- #
# curve materialization
# --------------------------------------------------------------------- #
def test_constant_rate_curve():
    c = ConstantRate(7.5).materialize(40)
    assert c.shape == (40,) and c.dtype == np.float32
    assert np.all(c == np.float32(7.5))


def test_diurnal_curve_bounds_and_period():
    c = DiurnalRate(10.0, amplitude=0.5, period_ticks=50).materialize(100)
    assert c.min() >= 4.9 and c.max() <= 15.1
    assert np.allclose(c[:50], c[50:], atol=1e-4)     # one period repeats
    # amplitude > 1 floors at zero instead of going negative
    deep = DiurnalRate(10.0, amplitude=2.0).materialize(100)
    assert deep.min() == 0.0


def test_flash_crowd_burst_windows():
    c = FlashCrowd(ConstantRate(2.0), mult=8.0, every_ticks=20,
                   burst_ticks=3, offset=5).materialize(60)
    burst = (np.arange(60) - 5) % 20 < 3
    assert np.all(c[burst] == np.float32(16.0))
    assert np.all(c[~burst] == np.float32(2.0))


def test_materialize_curve_validates():
    with pytest.raises(AssertionError):
        materialize_curve(np.ones((5,)), 6)           # wrong length
    with pytest.raises(AssertionError):
        materialize_curve(-np.ones((6,)), 6)          # negative rate


def _check_fit_to_wraps(ticks, width):
    plan = OpenLoop(write=DiurnalRate(5.0, period_ticks=ticks),
                    read=FlashCrowd(ConstantRate(8.0), every_ticks=7),
                    ticks=ticks)
    w0, r0 = plan.materialize()
    w, r, alen = plan.fit_to(width)
    assert w.shape == (width,) and r.shape == (width,)
    assert alen == min(ticks, width)
    # replay-neutral widening: the wrapped lookup on the widened curve
    # equals the lookup on the original plan at its own length
    idx = np.arange(width) % alen
    assert np.array_equal(w[idx % w.shape[0]][:alen], w0[:alen])
    assert np.array_equal(w[:alen], w0[:alen])
    assert np.array_equal(r[:alen], r0[:alen])


@pytest.mark.parametrize("ticks,width", [(10, 25), (25, 10), (16, 16)])
def test_fit_to_wraps(ticks, width):
    _check_fit_to_wraps(ticks, width)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 64))
    def test_fit_to_wraps_hypothesis(ticks, width):
        _check_fit_to_wraps(ticks, width)


# --------------------------------------------------------------------- #
# arrival totals: device path vs host generator
# --------------------------------------------------------------------- #
def _run_open_loop(plan, *, seed=0, epochs=1, keypop=None):
    sim = BWRaftSim(CONFIG, write_rate=0.0, read_rate=0.0, seed=seed,
                    manage_resources=False, arrivals=plan, keypop=keypop)
    return sim, sim.run(epochs)


def _check_totals_conserved(seed):
    """Device Poisson totals match the host generator's expected totals
    within sampling error (total ~ Poisson(M) => sd = sqrt(M))."""
    epochs = 2
    plan = OpenLoop(write=DiurnalRate(6.0, amplitude=0.5),
                    read=FlashCrowd(ConstantRate(20.0), mult=4.0,
                                    every_ticks=30, burst_ticks=4),
                    ticks=T)
    w, r = plan.materialize()
    sim, reps = _run_open_loop(plan, seed=seed, epochs=epochs)
    got_w = sum(rep.writes_arrived for rep in reps)
    got_r = sum(rep.reads_arrived for rep in reps)
    want_w = host_poisson_totals(w, plan.ticks, epochs * T)
    want_r = host_poisson_totals(r, plan.ticks, epochs * T)
    assert abs(got_w - want_w) <= 6 * np.sqrt(want_w) + 1, (got_w, want_w)
    assert abs(got_r - want_r) <= 6 * np.sqrt(want_r) + 1, (got_r, want_r)


@pytest.mark.parametrize("seed", [0, 7])
def test_arrival_totals_conserved(seed):
    _check_totals_conserved(seed)


def test_zero_rate_curves_inert():
    """An all-zero plan generates nothing: no arrivals, no serves, no
    latency samples — open-loop zero == closed-loop zero."""
    plan = OpenLoop(write=ConstantRate(0.0), read=ConstantRate(0.0),
                    ticks=T)
    sim, reps = _run_open_loop(plan, seed=3, epochs=2)
    assert all(rep.reads_arrived == 0 and rep.writes_arrived == 0 and
               rep.reads_served == 0 and rep.writes_committed == 0
               for rep in reps)
    assert all(np.isnan(rep.read_lat_p95) for rep in reps)


def test_short_plan_wraps_across_epochs():
    """A plan shorter than the epoch wraps at its OWN length: expected
    totals follow the wrapped schedule, not zero-padding."""
    short = OpenLoop(write=ConstantRate(4.0), read=ConstantRate(12.0),
                     ticks=T // 4)
    w, _ = short.materialize()
    want = host_poisson_totals(w, short.ticks, T)
    assert want == pytest.approx(4.0 * T)
    _, reps = _run_open_loop(short, seed=5)
    got = reps[0].writes_arrived
    assert abs(got - want) <= 6 * np.sqrt(want) + 1


# --------------------------------------------------------------------- #
# Zipfian key popularity vs scipy.stats.zipfian
# --------------------------------------------------------------------- #
def _check_zipf_cdf(s, K):
    cdf = ZipfianKeys(s).materialize(K)
    want = scipy.stats.zipfian(a=s, n=K).cdf(np.arange(1, K + 1))
    assert cdf.shape == (K,)
    assert float(cdf[-1]) == 1.0
    np.testing.assert_allclose(cdf, want, atol=1e-6)


@pytest.mark.parametrize("s,K", [(1.1, 64), (0.8, 256), (1.5, 1024)])
def test_zipf_cdf_matches_scipy(s, K):
    _check_zipf_cdf(s, K)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.3, 2.5), st.integers(2, 512))
    def test_zipf_cdf_matches_scipy_hypothesis(s, K):
        _check_zipf_cdf(s, K)


def test_zipf_sampler_frequency_ranks():
    """Inverse-transform draws off the materialized CDF (the exact
    `step.leader_step` formula) reproduce `scipy.stats.zipfian`
    frequencies: rank order on well-separated ranks, and total
    variation within sampling tolerance."""
    s, K, n = 1.2, 64, 200_000
    cdf = ZipfianKeys(s).materialize(K)
    rng = np.random.default_rng(0)
    keys = np.clip(np.searchsorted(cdf, rng.random(n), side="left"),
                   0, K - 1)
    freq = np.bincount(keys, minlength=K) / n
    pmf = scipy.stats.zipfian(a=s, n=K).pmf(np.arange(1, K + 1))
    assert 0.5 * np.abs(freq - pmf).sum() < 0.01          # TVD
    assert freq[0] > freq[4] > freq[16] > freq[48]        # rank order


def test_zipf_padded_tail_never_sampled():
    cdf = ZipfianKeys(1.1).materialize(16, pad_keys=8)
    assert cdf.shape == (24,)
    assert np.all(cdf[16:] == 1.0)
    u = np.random.default_rng(1).random(10_000)
    keys = np.searchsorted(cdf, u, side="left")
    assert keys.max() < 16


def test_uniform_cdf_is_uniform():
    cdf = uniform_key_cdf(8, pad_keys=4)
    np.testing.assert_allclose(np.diff(cdf[:8]), 1 / 8, atol=1e-6)
    assert np.all(cdf[8:] == 1.0)


def test_zipf_skews_device_write_keys():
    """End to end through the jitted tick: a Zipfian member's committed
    writes concentrate on the hot head of the key space."""
    plan = OpenLoop(write=ConstantRate(8.0), read=ConstantRate(0.0),
                    ticks=T)
    sim, _ = _run_open_loop(plan, seed=2, epochs=2,
                            keypop=ZipfianKeys(1.5))
    kv = np.asarray(sim.state["kv"])
    touched = np.where((kv != 0).any(axis=0))[0]
    assert touched.size > 0
    # with s=1.5 over 1024 keys, most writes land in the first decile
    assert np.median(touched) < CONFIG.key_space // 8


# --------------------------------------------------------------------- #
# plan swaps never recompile (CountingJit)
# --------------------------------------------------------------------- #
def test_plan_swap_triggers_no_recompile():
    """Arrival curves are jit arguments: swapping the plan (same width)
    and flipping open-loop on a running sim reuses the compiled epoch
    program — the §11 twin of the market-trace no-recompile contract."""
    plan_a = OpenLoop(write=DiurnalRate(6.0), read=ConstantRate(24.0),
                      ticks=T)
    plan_b = OpenLoop(write=FlashCrowd(ConstantRate(3.0), mult=6.0),
                      read=DiurnalRate(20.0, amplitude=0.8), ticks=T)
    sim = BWRaftSim(CONFIG, write_rate=5.0, read_rate=15.0, seed=8,
                    manage_resources=False, arrivals=plan_a)
    sim.run(1)
    compiled = sim._epoch_fn.cache_size()
    sim.set_arrivals(plan_b)
    sim.run(1)
    assert sim._epoch_fn.cache_size() == compiled
    # swapping back is free too, and a second sim at the same curve
    # width shares the cached program outright
    sim.set_arrivals(plan_a)
    sim.run(1)
    twin = BWRaftSim(CONFIG, write_rate=5.0, read_rate=15.0, seed=9,
                     manage_resources=False, arrivals=plan_b)
    twin.run(1)
    assert twin._epoch_fn is sim._epoch_fn
    assert sim._epoch_fn.cache_size() == compiled
