"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
only launch/dryrun.py forces 512 host devices."""
import jax
import numpy as np
import pytest

from repro.configs.bwraft_kv import CONFIG as PAPER_CLUSTER
from repro.core import state as SM
from repro.core import step as step_mod
from repro.core import runtime as RT
from repro.core.invariants import snapshot


@pytest.fixture(scope="session")
def paper_cluster():
    return PAPER_CLUSTER


@pytest.fixture(scope="session")
def sim_trace_factory(paper_cluster):
    """Run a sim for `ticks` with given knobs, snapshotting every k ticks."""
    static = SM.build_static(paper_cluster)
    cfg_c = RT.make_cfg_arrays(paper_cluster, write_rate=8.0, read_rate=16.0)
    tickfn = jax.jit(lambda s, r, c: step_mod.tick(s, static, c, r))

    def run(*, seed=0, ticks=300, every=5, phi=0.0, write_rate=8.0,
            lease_spot=True):
        import dataclasses
        import jax.numpy as jnp
        c = dict(cfg_c)
        c["phi"] = jnp.float32(phi)
        c["write_rate"] = jnp.float32(write_rate)
        state = SM.init_state(paper_cluster, static)
        if lease_spot:
            sim = RT.BWRaftSim(paper_cluster, seed=seed)
            sim._lease(4, 6)
            state = dict(state, role=sim.state["role"],
                         alive=sim.state["alive"],
                         sec_of=sim.state["sec_of"],
                         obs_of=sim.state["obs_of"])
        rng = jax.random.PRNGKey(seed)
        trace = []
        for t in range(ticks):
            rng, sub = jax.random.split(rng)
            state, _ = tickfn(state, sub, c)
            if t % every == 0:
                trace.append(snapshot(state))
        return trace, state

    return run
