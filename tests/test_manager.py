"""Algorithm 1 ("peek"), eq. 1/2, MCSA ("peak") properties."""
import math
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property-based tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.bwraft_kv import CONFIG as CC
from repro.core import manager as mgr
from repro.core import mcsa


@settings(max_examples=100, deadline=None)
@given(reads_prev=st.integers(0, 10_000), reads_now=st.integers(0, 10_000),
       writes=st.integers(0, 10_000), k_s=st.integers(0, 16),
       k_o=st.integers(0, 64), budget=st.floats(0, 10))
def test_algorithm1_invariants(reads_prev, reads_now, writes, k_s, k_o,
                               budget):
    stats = mgr.PeekStats(
        reads_prev=reads_prev, reads_now=reads_now, writes_now=writes,
        followers_per_site=[s.followers for s in CC.sites],
        k_s=k_s, k_o=k_o, budget=budget, spot_price=0.0125,
        on_demand_price=0.0416)
    d = mgr.algorithm1(CC, stats)
    assert d.budget_left >= 0, "budget never goes negative (lines 13-20)"
    assert d.k_s == k_s + d.dk_s and d.k_o == k_o + d.dk_o
    assert d.k == max(d.dk_s, 0) + max(d.dk_o, 0)
    assert d.k_s >= 0
    assert d.dk_o <= CC.num_sites, "at most one new observer per site"
    # spend respects budget: new leases cost <= initial budget PLUS budget
    # freed by released observers (paper line 13: theta -= rho*dk_o with
    # dk_o<0 reinvests the released spend)
    freed = max(-d.dk_o, 0) * 0.0125
    assert (max(d.dk_s, 0) + max(d.dk_o, 0)) * 0.0125 <= \
        budget + freed + 0.0126


def test_priority_by_write_ratio():
    base = dict(reads_prev=100, followers_per_site=[2, 2, 2, 1],
                k_s=0, k_o=0, budget=1.0, spot_price=0.0125,
                on_demand_price=0.0416)
    read_heavy = mgr.algorithm1(CC, mgr.PeekStats(
        reads_now=1000, writes_now=10, **base))
    write_heavy = mgr.algorithm1(CC, mgr.PeekStats(
        reads_now=100, writes_now=1000, **base))
    assert read_heavy.dk_o > 0, "read growth -> lease observers"
    assert write_heavy.dk_s > 0, "write heavy -> secretaries first"


def test_deadband_no_churn():
    d = mgr.algorithm1(CC, mgr.PeekStats(
        reads_prev=1000, reads_now=1050, writes_now=10,
        followers_per_site=[2, 2, 2, 1], k_s=2, k_o=4, budget=1.0,
        spot_price=0.0125, on_demand_price=0.0416))
    assert d.dk_o == 0, "|A| <= 10% must not churn observers"


def test_cost_model_monotonic():
    c0 = mgr.estimated_cost(CC, 0, 0)
    c1 = mgr.estimated_cost(CC, 4, 8)
    assert c1 > c0
    # eq 1 structure: beta*F + beta + rho*(ks+ko) + C
    assert abs((c1 - c0) - (0.0125 * 12 + 0.001 * 12)) < 0.05


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 8), st.integers(16, 128))
def test_mcsa_valid_and_competitive(seed, k, n):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0, 100, n)
    picked = mcsa.mcsa_topk(scores, k, rng)
    assert len(picked) <= k
    assert len(set(picked)) == len(picked)
    assert all(0 <= i < n for i in picked)


def test_mcsa_competitive_ratio_on_average():
    """MCSA should capture a decent fraction of the offline top-k sum."""
    rng = np.random.default_rng(0)
    ratios = []
    for trial in range(200):
        scores = rng.uniform(0, 1, 64)
        k = 4
        picked = mcsa.mcsa_topk(scores, k, rng)
        best = sum(sorted(scores)[-k:])
        ratios.append(sum(scores[i] for i in picked) / best)
    assert np.mean(ratios) > 0.55, np.mean(ratios)


def test_secretary_stream_beats_random():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    wins = 0
    for _ in range(100):
        s = rng.uniform(0, 1, 50).astype(np.float32)
        idx = int(mcsa.secretary_1e_stream(jnp.asarray(s)))
        if s[idx] >= np.quantile(s, 0.6):
            wins += 1
    assert wins > 55


def test_revocation_predictor_converges():
    p = mgr.RevocationPredictor(2, alpha=0.5)
    for _ in range(20):
        p.update(np.array([5.0, 0.0]), np.array([10.0, 10.0]))
    rate = p.predict()
    assert rate[0] > 0.4 and rate[1] < 0.05
    # trace-driven predictor unit tests (EWMA -> empirical trace rate,
    # leased == 0 untouched, calibrated seeding) live in test_market.py,
    # which runs without the hypothesis dependency this module needs
