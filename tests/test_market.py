"""The market provider contract (DESIGN.md §10): every market compiles to
(S, T) price/revocation arrays riding in cfg_c as jit arguments; the
synthetic walk exported as a trace replays **bit-identically** through
the trace path (solo and fleet); a B-member trace sweep is ONE compiled
program and ONE dispatch per run; resampling follows the zero-order-hold
/ event-bucketing rules; and `market.calibrate` fits the
RevocationPredictor and walk parameters against a trace."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fleet as fleet_mod
from repro.core import step as step_mod
from repro.core.cluster_config import ClusterConfig, SiteConfig
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim
from repro.market import (MarketTrace, CorrelatedSiteShocks,
                          RegimeSwitchingWalk, available_traces,
                          bucket_events, calibrate_predictor,
                          epoch_revocation_rates, export_walk_trace,
                          fit_walk, load, resample_price,
                          walk_params_from_cluster, walk_price_update)


def _small_cluster(name="mkt", followers=(2, 2, 1), max_log=1024):
    sites = tuple(
        SiteConfig(f"{name}-s{i}", followers=f, rtt_intra=1,
                   rtt_inter=6 + 2 * i, on_demand_price=0.0416,
                   spot_price_mean=0.0125)
        for i, f in enumerate(followers))
    return ClusterConfig(name=name, sites=sites, max_log=max_log,
                         key_space=256, max_secretaries=4,
                         max_observers=8, period_ticks=60)


def _states_equal(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


def _reports_equal(a, b):
    keys = ("reads_arrived", "writes_arrived", "reads_served",
            "writes_committed", "killed", "n_secretaries", "n_observers",
            "leader_changes", "no_leader_ticks")
    ok = all(getattr(a, k) == getattr(b, k) for k in keys)
    return ok and a.cost == b.cost


# --------------------------------------------------------------------- #
# §10 replay invariant
# --------------------------------------------------------------------- #
def test_walk_export_replays_bit_identically_solo():
    """A synthetic walk exported as a trace and replayed through the
    trace path reproduces today's process-path trajectory bit for bit —
    management, phi kills, reports and all (same seed => same RNG
    schedule, market values replayed verbatim)."""
    cfg = _small_cluster()
    epochs = 3
    kw = dict(write_rate=6.0, read_rate=24.0, phi=0.03, seed=11)
    process = BWRaftSim(cfg, **kw)
    process_reports = process.run(epochs)

    trace = export_walk_trace(cfg, seed=11, epochs=epochs)
    replay = BWRaftSim(cfg, **kw, market="trace", trace=trace)
    replay_reports = replay.run(epochs)

    assert _states_equal(process.state, replay.state)
    for e, (a, b) in enumerate(zip(process_reports, replay_reports)):
        assert _reports_equal(a, b), f"epoch {e}"
        if a.decision is not None or b.decision is not None:
            assert (a.decision.dk_s, a.decision.dk_o) == \
                (b.decision.dk_s, b.decision.dk_o)


def test_walk_export_replays_bit_identically_fleet():
    """Same invariant across a batched fleet: a B=3 process fleet and the
    B=3 trace-replay fleet (each member its own exported walk) land on
    bit-identical states and equal reports — including through the
    single-dispatch multi-epoch scan both fleets take."""
    cfg = _small_cluster()
    epochs = 2
    knobs = [dict(write_rate=6.0, seed=0), dict(write_rate=12.0, seed=1),
             dict(write_rate=3.0, seed=2)]
    base = dict(read_rate=24.0, phi=0.02, manage_resources=False,
                prelease=(2, 4))
    process = FleetSim([MemberSpec(cfg=cfg, **base, **k) for k in knobs])
    assert process.single_dispatch_eligible
    process_reports = process.run(epochs)

    replay = FleetSim([
        MemberSpec(cfg=cfg, **base, **k, market="trace",
                   trace=export_walk_trace(cfg, seed=k["seed"],
                                           epochs=epochs))
        for k in knobs])
    replay_reports = replay.run(epochs)

    assert _states_equal(process.state, replay.state)
    for i in range(len(knobs)):
        for a, b in zip(process_reports[i], replay_reports[i]):
            assert _reports_equal(a, b), f"member {i}"


def test_trace_sweep_one_compile_one_dispatch():
    """An (S, T)-trace sweep across B fleet members costs ONE compiled
    program for the whole run (the multi-epoch scan), and swapping in
    different traces at the same shapes reuses it — traces are jit
    arguments, never part of the program (DESIGN.md §10)."""
    cfg = _small_cluster("sweep", followers=(1, 1), max_log=256)
    epochs = 3
    providers = [
        lambda s: export_walk_trace(cfg, seed=s, epochs=epochs),
        lambda s: RegimeSwitchingWalk.from_cluster(cfg).materialize(
            epochs * cfg.period_ticks, seed=s),
        lambda s: CorrelatedSiteShocks.from_cluster(cfg).materialize(
            epochs * cfg.period_ticks, seed=s),
    ]

    def build(seed0):
        return FleetSim([
            MemberSpec(cfg=cfg, write_rate=4.0 + 2 * i, read_rate=8.0,
                       seed=seed0 + i, manage_resources=False,
                       market="trace", trace=mk(seed0 + i))
            for i, mk in enumerate(providers)])

    before = fleet_mod.total_compile_count()
    fleet = build(0)
    assert fleet.single_dispatch_eligible
    fleet.run(epochs)
    assert fleet_mod.total_compile_count() - before == 1, \
        "a B-trace sweep must compile exactly one program"
    build(7).run(epochs)                    # new traces, same shapes
    assert fleet_mod.total_compile_count() - before == 1, \
        "swapping traces must not recompile"


def test_mixed_market_fleet_one_program():
    """Process and trace members mix in ONE fleet program (the market
    flag is per-member data): the process member's trajectory is
    unaffected by its traced neighbor."""
    cfg = _small_cluster("mixed", followers=(1, 1), max_log=256)
    epochs = 2
    trace = export_walk_trace(cfg, seed=5, epochs=epochs)
    spec = dict(write_rate=6.0, read_rate=12.0, seed=3,
                manage_resources=False, prelease=(1, 2))
    mixed = FleetSim([
        MemberSpec(cfg=cfg, **spec),
        MemberSpec(cfg=cfg, write_rate=6.0, read_rate=12.0, seed=5,
                   manage_resources=False, market="trace", trace=trace)])
    mixed_reports = mixed.run(epochs)
    # the process member's placeholder is widened to the fleet's trace
    # width, but the select discards the trace operand, so a plain solo
    # run (default (S, 1) placeholder) must still match bit for bit
    solo_reports = BWRaftSim(cfg, **spec).run(epochs)
    for a, b in zip(mixed_reports[0], solo_reports):
        assert _reports_equal(a, b)


# --------------------------------------------------------------------- #
# spot_step edge cases — pinned on BOTH market paths
# --------------------------------------------------------------------- #
def _edge_state(S=2, price=(0.0125, 0.0125), tick=0):
    # three nodes per site: voter, spot-alive, spot-dead
    N = 3 * S
    role = jnp.asarray([0, 3, 5] * S, jnp.int32)
    alive = jnp.asarray([True, True, False] * S)
    return {
        "spot_price": jnp.asarray(price, jnp.float32),
        "alive": alive, "role": role,
        "warn_timer": jnp.full((N,), -1, jnp.int32),
        "tick": jnp.int32(tick),
    }, {
        "site": np.repeat(np.arange(S), 3).astype(np.int32),
        "is_voter": np.asarray([True, False, False] * S),
    }


def _edge_cfg(S=2, *, mean=0.0125, vol=0.0, phi=0.0, bid=None,
              warn_ticks=0, price_trace=None, revoke_trace=None,
              node_trace=None, fault_trace=None, bid_on_trace=False):
    use_trace = price_trace is not None
    if price_trace is None:
        price_trace = np.zeros((S, 1), np.float32)
    if revoke_trace is None:
        revoke_trace = np.zeros_like(np.asarray(price_trace), bool)
    if bid is None:
        bid = np.full((S,), mean * 1.5, np.float32)
    N = 3 * S                       # matches _edge_state's node layout
    if node_trace is None:
        node_cols = np.zeros((N, np.asarray(price_trace).shape[1]), bool)
    else:
        node_cols = np.asarray(node_trace, bool)
    if fault_trace is None:
        fault_cols = np.zeros((N, 1), bool)
    else:
        fault_cols = np.asarray(fault_trace, bool)
    return {
        "spot_price_mean": jnp.full((S,), mean, jnp.float32),
        "spot_price_vol": jnp.float32(vol),
        "phi": jnp.float32(phi),
        "market_trace": jnp.asarray(use_trace),
        "price_trace": jnp.asarray(price_trace, jnp.float32),
        "revoke_trace": jnp.asarray(revoke_trace, bool),
        "trace_len": jnp.int32(np.asarray(price_trace).shape[1]),
        "spot_bid": jnp.asarray(bid, jnp.float32),
        "warn_ticks": jnp.int32(warn_ticks),
        "bid_on_trace": jnp.asarray(bool(bid_on_trace)),
        "node_trace": jnp.asarray(node_trace is not None),
        "revoke_node_trace": jnp.asarray(node_cols, bool),
        "fault_on": jnp.asarray(fault_trace is not None),
        "fault_trace": jnp.asarray(fault_cols, bool),
        "fault_len": jnp.int32(fault_cols.shape[1]),
    }


def test_spot_bid_boundary_both_paths():
    """Price exactly AT the bid revokes nothing (the rule is strictly
    `price > bid`); one ulp above revokes — on both market sources."""
    bid = 0.0125 * 1.5
    above = float(np.nextafter(np.float32(bid), np.float32(np.inf)))
    # synthetic: vol=0 and price already at the mean => new price == mean
    for mean, expect_kill in ((bid, False), (above, True)):
        st, static = _edge_state(price=(mean, mean))
        cfg_c = _edge_cfg(mean=mean, vol=0.0, bid=(bid, bid))
        out, killed = step_mod.spot_step(st, static, cfg_c,
                                         jax.random.PRNGKey(0))
        assert bool(np.asarray(killed).any()) == expect_kill, mean
    # trace: replayed price at/above the bid, revocation FROM THE TRACE
    for price, expect_kill in ((bid, False), (above, True)):
        tr_price = np.full((2, 4), price, np.float32)
        tr_rev = tr_price > bid                     # the §10 bid rule
        st, static = _edge_state()
        cfg_c = _edge_cfg(bid=(bid, bid), price_trace=tr_price,
                          revoke_trace=tr_rev)
        out, killed = step_mod.spot_step(st, static, cfg_c,
                                         jax.random.PRNGKey(0))
        assert bool(np.asarray(killed).any()) == expect_kill, price
        assert (np.asarray(out["spot_price"]) == np.float32(price)).all()


def test_phi_one_kills_all_spot_in_one_tick_both_paths():
    """phi=1.0 revokes every alive spot node in a single tick (uniform
    draws land in [0, 1)), voters untouched — on both market sources."""
    for cfg_c in (_edge_cfg(phi=1.0),
                  _edge_cfg(phi=1.0,
                            price_trace=np.full((2, 3), 0.01, np.float32))):
        st, static = _edge_state()
        out, killed = step_mod.spot_step(st, static, cfg_c,
                                         jax.random.PRNGKey(1))
        killed = np.asarray(killed)
        is_spot_alive = ~static["is_voter"] & np.asarray(st["alive"])
        assert (killed == is_spot_alive).all()
        assert not np.asarray(out["alive"])[~static["is_voter"]].any()
        assert np.asarray(out["alive"])[static["is_voter"]].all()


def test_price_floor_clamp_both_paths():
    """The walk clamps at 0.1x mean in-step; traces carry the floor in
    the data (generation-time clamp) and replay verbatim —
    `export_walk_trace` of a high-vol walk therefore never dips below
    the floor, and the replayed in-step price equals the trace exactly."""
    mean, vol = 0.0125, 50.0                    # vol huge => clamp active
    keys = jax.random.split(jax.random.PRNGKey(2), 64)
    prices = np.stack([
        np.asarray(walk_price_update(jnp.full((2,), mean, jnp.float32),
                                     jnp.full((2,), mean, jnp.float32),
                                     jnp.float32(vol), k))
        for k in keys])
    floor = np.float32(0.1) * np.float32(mean)    # f32 mult, as in-step
    assert (prices >= floor).all(), "clamp must bound the walk below"
    assert (prices == floor).any(), "vol=50 must actually hit the floor"

    cfg = _small_cluster("floor", followers=(1, 1), max_log=256)
    trace = export_walk_trace(cfg, seed=0, epochs=2, spot_price_vol=50.0)
    mean_arr, _, _, _ = walk_params_from_cluster(cfg, spot_price_vol=50.0)
    assert (trace.price >= 0.1 * mean_arr[:, None]).all()
    # replay is verbatim: the in-step price equals the trace column
    st, static = _edge_state()
    cfg_c = _edge_cfg(price_trace=trace.price[:, :4],
                      revoke_trace=trace.revoked[:, :4])
    out, _ = step_mod.spot_step(st, static, cfg_c, jax.random.PRNGKey(3))
    assert np.array_equal(np.asarray(out["spot_price"]),
                          trace.price[:, 0])


def test_trace_lookup_wraps_modulo():
    """Tick t reads trace column t % trace_len (the §10 time-wrap rule),
    so short traces loop instead of running off the end — and the wrap
    uses the member's OWN period even when the array was widened to a
    fleet-shared width."""
    tr = np.asarray([[1.0, 2.0, 3.0]], np.float32)
    st, static = _edge_state(S=1, price=(1.0,), tick=5)
    cfg_c = _edge_cfg(S=1, bid=(9.0,), price_trace=tr)
    out, _ = step_mod.spot_step(st, static, cfg_c, jax.random.PRNGKey(0))
    assert float(np.asarray(out["spot_price"])[0]) == 3.0   # 5 % 3 == 2
    # widened to width 5 next to a longer neighbor: trace_len stays 3,
    # so tick 5 still reads source column 2 (not widened column 0)
    wide = MarketTrace("w", tr, np.zeros_like(tr, bool)).fit_to(1, 5)
    cfg_c = dict(_edge_cfg(S=1, price_trace=wide.price),
                 trace_len=jnp.int32(3))
    out, _ = step_mod.spot_step(st, static, cfg_c, jax.random.PRNGKey(0))
    assert float(np.asarray(out["spot_price"])[0]) == 3.0


def test_mixed_length_traces_replay_neutral():
    """A short trace widened to a longer neighbor's width replays its
    own columns exactly: the fleet member equals a solo run on the
    unwidened trace, past the point where the widths diverge."""
    sites = tuple(SiteConfig(f"ml-s{i}", followers=1, rtt_intra=1,
                             rtt_inter=6, on_demand_price=0.0416,
                             spot_price_mean=0.0125) for i in range(2))
    cfg = ClusterConfig(name="ml", sites=sites, max_log=256, key_space=128,
                        max_secretaries=2, max_observers=4,
                        period_ticks=40)
    epochs = 3                                   # run 120 ticks
    short = export_walk_trace(cfg, seed=6, epochs=1)        # 40 ticks
    long_tr = RegimeSwitchingWalk.from_cluster(cfg).materialize(
        90, seed=7)                              # 90: not a multiple of 40
    spec = dict(write_rate=6.0, read_rate=12.0, manage_resources=False,
                prelease=(1, 2))
    fleet = FleetSim([
        MemberSpec(cfg=cfg, **spec, seed=6, market="trace", trace=short),
        MemberSpec(cfg=cfg, **spec, seed=7, market="trace",
                   trace=long_tr)])
    assert fleet.trace_ticks == 90
    fleet_reports = fleet.run(epochs)
    solo = BWRaftSim(cfg, **spec, seed=6, market="trace", trace=short)
    for a, b in zip(fleet_reports[0], solo.run(epochs)):
        assert _reports_equal(a, b)


# --------------------------------------------------------------------- #
# loaders / resampling
# --------------------------------------------------------------------- #
def test_resample_zero_order_hold_pinned():
    times = np.array([0.0, 10.0, 20.0])
    values = np.array([1.0, 2.0, 3.0])
    out = resample_price(times, values, 5, (0.0, 20.0))
    # grid = 0, 5, 10, 15, 20 -> last obs at or before each instant
    assert out.tolist() == [1.0, 1.0, 2.0, 2.0, 3.0]
    # ticks before the first observation hold the first value
    assert resample_price(times, values, 3, (-10.0, 0.0)).tolist() == \
        [1.0, 1.0, 1.0]


def test_bucket_events_pinned():
    out = bucket_events(np.array([0.0, 9.99, 5.0]), 10, (0.0, 10.0))
    assert out.tolist() == [True, False, False, False, False, True,
                            False, False, False, True]


def test_bundled_traces_load_and_fit():
    assert set(available_traces()) == {"aws-us-east", "google-evict"}
    for name in available_traces():
        tr = load(name, ticks=120)
        assert tr.ticks == 120 and tr.sites >= 2
        assert (tr.price > 0).all()
        fitted = tr.fit_to(5, 300)
        assert fitted.price.shape == (5, 300)
        # site tiling: row s reads source row s % S0
        assert np.array_equal(fitted.price[tr.sites], fitted.price[0])
        # time wrap: column t reads source column t % T0
        assert np.array_equal(fitted.price[:, 120:240],
                              fitted.price[:, :120])
    aws = load("aws-us-east", ticks=200)
    # derived revocations follow the §10 bid rule
    bid = 1.5 * aws.price.mean(axis=1, keepdims=True)
    assert np.array_equal(aws.revoked, aws.price > bid)
    assert aws.revoked.any(), "sample trace must contain revocations"
    google = load("google-evict", ticks=200)
    assert google.revoked.any()
    assert (google.price == google.price[0, 0]).all(), "flat price rows"


# --------------------------------------------------------------------- #
# RevocationPredictor (unit) + calibration
# --------------------------------------------------------------------- #
def test_revocation_predictor_converges_to_trace_empirical_rate():
    """Fed a market trace's per-epoch revocation observations, the EWMA
    converges to the trace's empirical per-site hazard (DESIGN.md §10)."""
    from repro.core import manager as mgr

    rng = np.random.default_rng(3)
    hazard = np.array([0.15, 0.03])
    revoked = rng.random((2, 1800)) < hazard[:, None]
    trace = MarketTrace("unit", np.full((2, 1800), 0.0125), revoked)
    obs = epoch_revocation_rates(trace, 60)                  # (E, S)
    p = mgr.RevocationPredictor(2, alpha=0.3)
    for e in range(obs.shape[0]):
        p.update(obs[e], np.ones(2))
    empirical = trace.empirical_revocation_rates()
    assert np.abs(p.predict() - empirical).max() < 0.05
    assert np.abs(p.predict() - hazard).max() < 0.05


def test_revocation_predictor_leased_zero_untouched():
    """Sites with leased == 0 made no observation this period — `update`
    must leave their rate estimate exactly as it was."""
    from repro.core import manager as mgr

    p = mgr.RevocationPredictor(3, alpha=0.5, prior=0.02)
    p.update(np.array([4.0, 0.0, 7.0]), np.array([8.0, 0.0, 0.0]))
    rate = p.predict()
    assert rate[0] != 0.02, "leased site must update"
    assert rate[1] == 0.02 and rate[2] == 0.02, \
        "unleased sites must be untouched (even with nonzero revoked)"


def test_revocation_predictor_calibrated_seed():
    from repro.core import manager as mgr

    p = mgr.RevocationPredictor.calibrated([0.2, 0.0], alpha=0.4)
    assert p.predict().tolist() == [0.2, 0.0] and p.alpha == 0.4


def test_calibrate_predictor_converges_to_empirical_rates():
    """The fitted EWMA lands on the trace's per-site empirical hazard
    (heterogeneous sites, incl. a zero-revocation site) and beats the
    uncalibrated flat prior by a wide margin."""
    rng = np.random.default_rng(0)
    hazard = np.array([0.2, 0.05, 0.0])
    revoked = rng.random((3, 1200)) < hazard[:, None]
    trace = MarketTrace("unit", np.full((3, 1200), 0.0125), revoked)
    predictor, report = calibrate_predictor(trace, period_ticks=60)
    empirical = trace.empirical_revocation_rates()
    assert report.mae < 0.02
    assert np.abs(predictor.predict() - empirical).max() < 0.05
    prior_mae = float(np.mean(np.abs(0.02 - empirical)))
    assert report.mae < prior_mae / 3
    assert report.alpha in (0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9)


def test_epoch_revocation_rates_shape_and_values():
    revoked = np.zeros((2, 120), bool)
    revoked[0, :60] = True                      # site 0: epoch 0 only
    trace = MarketTrace("unit", np.ones((2, 120)), revoked)
    obs = epoch_revocation_rates(trace, 60)
    assert obs.shape == (2, 2)
    assert obs[0].tolist() == [1.0, 0.0] and obs[1].tolist() == [0.0, 0.0]


def test_fit_walk_recovers_walk_parameters():
    """Moment-matching inverts the exported walk: fitted means land on
    the sites' reversion targets and the pooled vol recovers the true
    volatility within sampling error."""
    cfg = _small_cluster("fit", followers=(1, 1), max_log=256)
    mean, vol, _, _ = walk_params_from_cluster(cfg)
    trace = export_walk_trace(cfg, seed=1, epochs=40)     # 2400 ticks
    fit = fit_walk(trace)
    assert np.abs(fit.mean - mean).max() / mean.max() < 0.1
    assert abs(fit.vol - vol) / vol < 0.25
    assert fit.vol_per_site.shape == (cfg.num_sites,)
    # the true walk IS mean-reverting: the fitted reversion must explain
    # one-step variance beyond hold-last-price...
    assert fit.reversion_r2 > 0.02, fit.reversion_r2
    # ...and a driftless random walk (no reversion) must score ~0
    rng = np.random.default_rng(0)
    rw = 0.0125 + 0.001 * np.cumsum(rng.standard_normal((2, 2400)),
                                    axis=1)
    null = fit_walk(MarketTrace("rw", np.maximum(rw, 1e-4),
                                np.zeros((2, 2400), bool)))
    assert null.reversion_r2 < fit.reversion_r2
