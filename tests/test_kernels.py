"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_ref

TOLS = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (1, 128, 2, 2, 32, 64, 64),
    (2, 256, 4, 2, 64, 128, 64),
    (2, 192, 6, 3, 32, 64, 32),     # uneven head group
    (1, 64, 8, 1, 16, 32, 16),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, bq, bk, dtype):
    rng = jax.random.PRNGKey(B * S + H)
    q = jax.random.normal(rng, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd), dtype)
    out = flash_attention_kernel(q, k, v, block_q=bq, block_k=bk,
                                 interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


def test_flash_attention_noncausal():
    rng = jax.random.PRNGKey(9)
    q = jax.random.normal(rng, (1, 128, 2, 32), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 128, 2, 32))
    out = flash_attention_kernel(q, k, v, block_q=64, block_k=64,
                                 causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 2, 16, 2, 8, 8),
    (2, 4, 16, 3, 8, 16),
    (2, 8, 32, 4, 16, 32),
])
def test_ssd_scan_sweep(B, nc, Q, H, P, N):
    rng = jax.random.PRNGKey(nc * Q)
    x = jax.random.normal(rng, (B, nc, Q, H, P), jnp.float32) * 0.5
    Bm = jax.random.normal(jax.random.fold_in(rng, 1), (B, nc, Q, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(rng, 2), (B, nc, Q, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 3),
                                           (B, nc, Q, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(rng, 4), (H,)) * 0.3)
    y, st = ssd_scan_kernel(x, Bm, Cm, dt, A, interpret=True)
    y_ref, st_ref = ssd_ref(x, Bm, Cm, dt, A)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("B,T,H,KV,hd,bk", [
    (2, 256, 4, 2, 32, 64),
    (3, 512, 8, 4, 64, 128),
    (1, 128, 2, 1, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, T, H, KV, hd, bk, dtype):
    rng = jax.random.PRNGKey(T + H)
    q = jax.random.normal(rng, (B, 1, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, T, KV, hd), dtype)
    clen = jnp.asarray(np.random.RandomState(0).randint(1, T + 1, B),
                       jnp.int32)
    out = decode_attention_kernel(q, k, v, clen, block_k=bk, interpret=True)
    ref = decode_ref(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


def test_kernel_matches_model_attention_path():
    """The Pallas kernel and the model's chunked-jnp path agree."""
    from repro.models import attention as A
    rng = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 128, 4, 32
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, H, hd))
    o_kernel = flash_attention_kernel(q, k, v, block_q=64, block_k=64,
                                      interpret=True)
    o_jnp = A.causal_blocked_attention(q, k, v, chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(o_kernel, o_jnp, rtol=2e-4, atol=2e-4)
