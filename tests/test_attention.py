"""Chunked/blocked attention == full attention, all variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


@pytest.mark.parametrize("S,T,ck,unroll", [
    (64, 64, 16, False), (64, 64, 16, True),
    (128, 128, 32, True), (96, 96, 32, False),
])
def test_chunked_causal_matches_full(S, T, ck, unroll):
    rng = jax.random.PRNGKey(0)
    B, H, hd = 2, 4, 32
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, T, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1 = A.chunked_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                             chunk_k=ck, unroll=unroll)
    o2 = A.full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("unroll", [False, True])
def test_blocked_causal_q_chunks(unroll):
    rng = jax.random.PRNGKey(3)
    B, S, H, hd = 2, 128, 2, 16
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, H, hd))
    o1 = A.causal_blocked_attention(q, k, v, chunk_q=32, chunk_k=32,
                                    unroll=unroll)
    o2 = A.full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_noncausal_chunked():
    rng = jax.random.PRNGKey(4)
    B, S, T, H, hd = 2, 48, 80, 2, 16
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, T, H, hd))
    qp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kp = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    o1 = A.chunked_attention(q, k, v, q_pos=qp, k_pos=kp, causal=False,
                             chunk_k=32)
    o2 = A.full_attention(q, k, v, causal=False)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_decode_attention_masks_cache_tail():
    rng = jax.random.PRNGKey(5)
    B, T, H, KV, hd = 3, 64, 4, 2, 16
    q = jax.random.normal(rng, (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, KV, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, T, KV, hd))
    clen = jnp.array([10, 32, 64], jnp.int32)
    o = A.decode_attention(q, k, v, clen)
    # oracle: full attention over the valid prefix per example
    for b in range(B):
        kk = jnp.repeat(k[b:b+1, :clen[b]], H // KV, axis=2)
        vv = jnp.repeat(v[b:b+1, :clen[b]], H // KV, axis=2)
        ref = A.full_attention(q[b:b+1], kk, vv, causal=False)
        np.testing.assert_allclose(o[b:b+1], ref, rtol=2e-4, atol=2e-4)


def test_gqa_repeat_equivalence():
    rng = jax.random.PRNGKey(6)
    B, S, H, KV, hd = 1, 32, 8, 2, 16
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd))
    o1 = A.full_attention(q, A.repeat_kv(k, H), A.repeat_kv(v, H))
    # manual per-group
    for h in range(H):
        g = h // (H // KV)
        o_ref = A.full_attention(q[:, :, h:h+1], k[:, :, g:g+1],
                                 v[:, :, g:g+1])
        np.testing.assert_allclose(o1[:, :, h:h+1], o_ref, rtol=1e-5,
                                   atol=1e-5)
