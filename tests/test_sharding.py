"""Logical-axis rules: divisibility pruning, profile merging."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import axes as ax
from repro.launch.mesh import make_host_mesh


def _mesh22():
    import numpy as np
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_prune_uneven_dim():
    mesh = _mesh22()
    log = ax.PruneLog()
    spec = ax.logical_to_spec(("heads", "head_dim"), (15, 64),
                              {"heads": "model", "head_dim": None}, mesh,
                              name="wq", prune_log=log)
    # 15 % 1 == 0 on the 1x1 test mesh -> no prune; simulate a 16-way axis
    import repro.sharding.axes as axes_mod

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = ax.logical_to_spec(("heads", "head_dim"), (15, 64),
                              {"heads": "model", "head_dim": None},
                              FakeMesh(), name="wq", prune_log=log)
    assert spec == P(None, None)
    assert log.entries, "fallback must be recorded"


def test_tuple_axes_prefix_prune():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    # 32 % (2*16*16) != 0 -> falls back to ("pod","data") = 32
    spec = ax.logical_to_spec(
        ("batch",), (32,), {"batch": ("pod", "data", "model")}, FakeMesh())
    assert spec == P(("pod", "data"))


def test_axis_used_once_per_tensor():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = ax.logical_to_spec(
        ("kv_seq", "kv_heads"), (512, 16),
        {"kv_seq": ("data", "model"), "kv_heads": "model"}, FakeMesh())
    assert spec == P(("data", "model"), None), spec


def test_profiles_complete():
    needed = {"batch", "embed", "heads", "kv_heads", "mlp", "vocab",
              "experts", "ssm_inner", "kv_seq"}
    for name, prof in ax.PROFILES.items():
        assert needed <= set(prof), (name, needed - set(prof))


def test_constrainer_noop_off_mesh():
    mesh = _mesh22()
    cn = ax.make_constrainer(ax.TRAIN_RULES, mesh)
    x = jnp.ones((4, 8))
    y = cn(x, "batch", "embed")
    assert y.shape == x.shape
