"""The serving-surface invariants (DESIGN.md §11):

1. *closed-loop bit-identity* — the open-loop plumbing is strictly
   additive: pre-PR closed-loop configs replay the golden trajectories
   captured before the serving surface landed, report-for-report and
   state-leaf-for-state-leaf (sha256).
2. *goodput math pin* — the device-resident digest histograms (read AND
   write) equal a numpy recomputation over the raw per-request
   latencies collected tick by tick on the host path, and
   `goodput_under_deadline` equals the naive `(latency <= D).sum()`.
"""
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.step as step_mod
from repro.configs.bwraft_kv import CONFIG
from repro.core.fleet import FleetSim, MemberSpec
from repro.core.runtime import BWRaftSim, goodput_under_deadline
from repro.core.state import hist_bins
from repro.workload import ConstantRate, DiurnalRate, FlashCrowd, OpenLoop

GOLDEN = pathlib.Path(__file__).parent / "data" / "closed_loop_golden.json"


def _check_golden(name, sim_state, reports, g):
    """Reports: ints compare exactly, floats by repr round-trip; state
    leaves by sha256 over the raw bytes.  Only keys recorded in the
    golden are compared — fields/leaves ADDED by this PR (read
    percentiles, `read_lat_hist`) are allowed to exist, but nothing the
    pre-PR code produced may change."""
    for i, grep in enumerate(g["reports"]):
        rep = reports[i]
        for k, v in grep.items():
            got = getattr(rep, k)
            if isinstance(v, str):
                assert repr(float(got)) == v, \
                    f"{name} epoch {i}: {k} = {float(got)!r}, golden {v}"
            else:
                assert int(got) == v, \
                    f"{name} epoch {i}: {k} = {int(got)}, golden {v}"
    for k, leaf in g["state"].items():
        arr = np.asarray(sim_state[k])
        assert list(arr.shape) == leaf["shape"], (name, k)
        assert str(arr.dtype) == leaf["dtype"], (name, k)
        got = hashlib.sha256(arr.tobytes()).hexdigest()
        assert got == leaf["sha256"], \
            f"{name}: state leaf {k!r} diverged from pre-PR trajectory"


def test_closed_loop_solo_bit_identical_to_golden():
    """A managed solo run (control plane + synthetic market on) replays
    the pre-PR trajectory exactly: the open-loop path is compiled in but
    `open_loop=False` selects the scalar knob, same lam -> same draws."""
    golden = json.loads(GOLDEN.read_text())
    sim = BWRaftSim(CONFIG, write_rate=8.0, read_rate=32.0, phi=0.02,
                    seed=0)
    reps = sim.run(2)
    _check_golden("solo_managed", sim.state, reps, golden["solo_managed"])


def test_closed_loop_fleet_bit_identical_to_golden():
    """The fixed-role fleet scan (batched members, one of them plain
    Raft) replays its pre-PR trajectory through the widened cfg_c."""
    golden = json.loads(GOLDEN.read_text())
    specs = [MemberSpec(cfg=CONFIG, write_rate=6.0, read_rate=24.0, seed=1,
                        manage_resources=False, prelease=(2, 6)),
             MemberSpec(cfg=CONFIG, mode="raft", write_rate=12.0,
                        read_rate=12.0, seed=2, manage_resources=False)]
    fleet = FleetSim(specs)
    fleet.run(3)
    g = golden["fleet_fixed"]
    for m, (member_reports, gm) in enumerate(
            zip(fleet.reports, g["reports"])):
        _check_golden(f"fleet_fixed[{m}]", {}, member_reports,
                      {"reports": gm, "state": {}})
    _check_golden("fleet_fixed", fleet.state, [],
                  {"reports": [], "state": g["state"]})


# ------------------------------------------------------------------ #
# goodput math pin: device digest == numpy over raw latencies
# ------------------------------------------------------------------ #
P95_DEADLINE = 30


@pytest.fixture(scope="module")
def digest_and_raw():
    """Run ONE epoch twice from the same (state, rng): once on the
    device digest path, once tick-by-tick on the host collecting the
    raw per-request latency samples the digest histograms summarize."""
    plan = OpenLoop(write=DiurnalRate(6.0, amplitude=0.6),
                    read=FlashCrowd(ConstantRate(30.0), mult=5.0,
                                    every_ticks=25, burst_ticks=4),
                    ticks=CONFIG.period_ticks)
    sim = BWRaftSim(CONFIG, write_rate=0.0, read_rate=0.0, seed=4,
                    manage_resources=False, arrivals=plan)
    sim._lease(1, 5)
    # snapshot before run_epoch: the jitted epoch donates its buffers
    state0 = jax.tree.map(jnp.array, sim.state)
    _, sub = jax.random.split(sim.rng)
    sim.run_epoch()
    dg = sim.last_digest

    T = CONFIG.period_ticks
    H = hist_bins(CONFIG)
    static, cfg_c = sim.static, sim.cfg_c
    tickfn = jax.jit(lambda s, r: step_mod.tick(s, static, cfg_c, r))
    st = state0
    read_raw = []
    # device_epoch splits the epoch key into T per-tick keys; mirroring
    # the split reproduces the scan trajectory tick for tick
    for r in jax.random.split(sub, T):
        st, m = tickfn(st, r)
        served = np.asarray(m["read_served_tick"])
        lat = np.asarray(m["read_lat_tick"])
        for n in np.where(served > 0)[0]:
            read_raw.extend([int(lat[n])] * int(served[n]))
    sub_t = np.asarray(st["entry_submit_t"])
    com_t = np.asarray(st["entry_commit_t"])
    done = (sub_t >= 0) & (com_t >= 0)
    write_raw = (com_t[done] - sub_t[done]).astype(np.int64)
    return dg, np.asarray(read_raw, np.int64), write_raw, H


def test_read_histogram_equals_numpy_recomputation(digest_and_raw):
    dg, read_raw, _, H = digest_and_raw
    assert read_raw.size > 0, "epoch served no reads — workload too thin"
    want = np.bincount(np.clip(read_raw, 0, H - 1), minlength=H)
    np.testing.assert_array_equal(np.asarray(dg["read_lat_hist"]), want)
    assert int(dg["reads_served"]) == read_raw.size


def test_write_histogram_equals_numpy_recomputation(digest_and_raw):
    dg, _, write_raw, H = digest_and_raw
    assert write_raw.size > 0, "epoch committed no writes"
    want = np.bincount(np.clip(write_raw, 0, H - 1), minlength=H)
    np.testing.assert_array_equal(np.asarray(dg["write_lat_hist"]), want)


def test_goodput_equals_raw_latency_count(digest_and_raw):
    """`goodput_under_deadline` off the device histograms == the naive
    numpy count over the raw latencies, for BOTH read and write — the
    arithmetic `benchmarks/perf_serving.py` builds its SLO rows on."""
    dg, read_raw, write_raw, H = digest_and_raw
    assert P95_DEADLINE < H - 1          # deadline clear of the clip bin
    got_r = goodput_under_deadline(dg["read_lat_hist"], P95_DEADLINE)
    got_w = goodput_under_deadline(dg["write_lat_hist"], P95_DEADLINE)
    assert got_r == int((read_raw <= P95_DEADLINE).sum())
    assert got_w == int((write_raw <= P95_DEADLINE).sum())
    # edge cases: negative deadline is empty; a deadline past the last
    # bin is total throughput
    assert goodput_under_deadline(dg["read_lat_hist"], -1) == 0
    assert goodput_under_deadline(dg["read_lat_hist"], 10 * H) == \
        read_raw.size
