"""Regenerate the EXPERIMENTS.md roofline summary table from JSON.

  PYTHONPATH=src python results/make_tables.py [results/roofline_baseline.json]
"""
import json
import sys


def main(path="results/roofline_baseline.jsonl"):
    if path.endswith(".jsonl"):
        recs = [json.loads(l) for l in open(path)]
    else:
        recs = json.load(open(path))
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "SKIP":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP "
                  f"(full-attention @500k) | | |")
            continue
        if r["status"] != "OK":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                  f"FAIL: {r.get('error','')[:40]} | | |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['bottleneck']} | {r['useful_flops_frac']:.2f} | "
              f"{r['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
